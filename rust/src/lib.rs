//! # pw2v — Parallelizing Word2Vec in Shared and Distributed Memory
//!
//! A production-grade reproduction of Ji, Satish, Li & Dubey (2016),
//! *"Parallelizing Word2Vec in Shared and Distributed Memory"* (cs.DC,
//! arXiv:1604.04661), built as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: corpus pipeline,
//!   vocabulary, negative sampling, the shared Hogwild model store, four
//!   trainer back-ends (original scalar Hogwild, BIDMach-style level-2,
//!   the paper's batched shared-negative GEMM scheme, and the same scheme
//!   through an AOT-compiled XLA executable), the distributed runtime
//!   (model replicas + sub-model synchronization + learning-rate scaling),
//!   evaluation, metrics, and the calibrated performance model used to
//!   regenerate the paper's scaling figures.
//! * **Layer 2** — `python/compile/model.py`: the SGNS superbatch step in
//!   JAX, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 1** — `python/compile/kernels/sgns.py`: the fused
//!   three-GEMM SGNS Pallas kernel the step calls.
//!
//! Python never runs at train time; the rust binary consumes only
//! `artifacts/*.hlo.txt` via the PJRT CPU client (`xla` crate).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod bench;
pub mod cli;
pub mod config;
pub mod corpus;
pub mod dist;
pub mod eval;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod stream;
pub mod train;
pub mod util;

// ---------------------------------------------------------------------
// Curated facade: the stable public surface.  Examples, integration
// tests and downstream users should reach for these re-exports; the
// module paths above remain public for the adventurous but may be
// reorganised between versions.
// ---------------------------------------------------------------------

/// All training hyperparameters and execution knobs (`TrainConfig::default`
/// matches the paper's shared-memory setup; `apply_args` layers CLI flags).
pub use config::TrainConfig;

/// The `.pw2v.u32` encoded-corpus cache: tokenized sentences as ids,
/// built once, mmap-shared by every worker (`EncodedCorpus::ensure`
/// reuses / appends / rebuilds as the source file evolves).
pub use corpus::encoded::EncodedCorpus;

/// Frequency-sorted vocabulary with streaming admission support.
pub use corpus::vocab::Vocab;

/// The shared Hogwild model store (two embedding matrices, racy rows).
pub use model::SharedModel;

/// Serve-side: mmap-able unit-row store and the query engine behind the
/// `serve` subcommand.
pub use serve::{RowStore, ServeEngine};

/// Streaming ingest: tail a growing corpus and train continuously
/// (the `stream` subcommand).
pub use stream::{StreamOptions, StreamOutcome, StreamTrainer};

/// Batch trainer entry point: `train(&cfg, &corpus_path)` runs the full
/// vocabulary → superbatch → backend pipeline and returns the model.
pub use train::{train, TrainOutcome};
