//! # pw2v — Parallelizing Word2Vec in Shared and Distributed Memory
//!
//! A production-grade reproduction of Ji, Satish, Li & Dubey (2016),
//! *"Parallelizing Word2Vec in Shared and Distributed Memory"* (cs.DC,
//! arXiv:1604.04661), built as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: corpus pipeline,
//!   vocabulary, negative sampling, the shared Hogwild model store, four
//!   trainer back-ends (original scalar Hogwild, BIDMach-style level-2,
//!   the paper's batched shared-negative GEMM scheme, and the same scheme
//!   through an AOT-compiled XLA executable), the distributed runtime
//!   (model replicas + sub-model synchronization + learning-rate scaling),
//!   evaluation, metrics, and the calibrated performance model used to
//!   regenerate the paper's scaling figures.
//! * **Layer 2** — `python/compile/model.py`: the SGNS superbatch step in
//!   JAX, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 1** — `python/compile/kernels/sgns.py`: the fused
//!   three-GEMM SGNS Pallas kernel the step calls.
//!
//! Python never runs at train time; the rust binary consumes only
//! `artifacts/*.hlo.txt` via the PJRT CPU client (`xla` crate).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod bench;
pub mod config;
pub mod corpus;
pub mod dist;
pub mod eval;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod train;
pub mod util;

pub use config::TrainConfig;
