//! The serve query engine: SIMD-scored exhaustive scan over the row
//! store, answering `topk` / `analogy` requests.
//!
//! Steady-state discipline: every buffer a request touches lives in a
//! caller-owned [`Scratch`] — parse scratch, query vector, int8 query
//! codes, the hit heap and the response string.  After warm-up a
//! request allocates NOTHING (pinned by the serve leg of
//! `tests/alloc_steadystate.rs`), so p99 latency is not at the mercy of
//! the allocator.
//!
//! Scoring:
//! - f32 path: rows are unit-normalised, so `topk` similarity is a
//!   plain [`simd::dot`] against the query word's unit row — under
//!   scalar dispatch this is bit-for-bit the arithmetic of
//!   [`crate::eval::similarity::cosine`]'s ranking and of
//!   [`crate::eval::analogy::eval_analogy`]'s 3CosAdd argmax.
//! - int8 path: the quantized scan of [`super::quant`], gated at
//!   recall@10 ≥ 0.95 by `tests/serve_parity.rs`.
//!
//! Ranking is total and deterministic: score descending, ties broken
//! toward the LOWER row id (matching `eval_analogy`'s first-wins strict
//! `>` argmax); unservable rows (zero-norm / non-finite at build time)
//! and the query's own id(s) never appear.

use std::fmt::Write as _;

use crate::config::QuantMode;
use crate::linalg::simd;
use crate::util::json::{write_json_str, JsonEscaper};

use super::quant::{quantize_into, QuantStore};
use super::request::{parse_request, Op, ReqScratch};
use super::store::RowStore;

/// Default result count when a request omits `k`.
pub const DEFAULT_K: usize = 10;
/// Hard cap on `k`: bounds response size and the hit buffer.
pub const MAX_K: usize = 64;

/// One ranked result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub id: u32,
    pub score: f32,
}

/// Caller-owned request-lifetime buffers; capacity is retained across
/// requests so the steady-state request path performs no allocation.
#[derive(Default)]
pub struct Scratch {
    pub req: ReqScratch,
    query: Vec<f32>,
    qcodes: Vec<i8>,
    hits: Vec<Hit>,
    /// Raw request line buffer for the I/O loops.
    pub line: Vec<u8>,
    /// Response JSON (one line, no trailing newline).
    pub out: String,
}

/// A loaded model ready to answer queries.
pub struct ServeEngine {
    store: RowStore,
    quant: Option<QuantStore>,
}

impl ServeEngine {
    /// Wrap a row store, optionally building the int8 shadow copy.
    /// Errors (a checked result, not a panic — a store with over-bound
    /// dims must fail THIS load, not kill the process) only when the
    /// int8 build rejects the store's geometry.
    pub fn from_store(store: RowStore, mode: QuantMode) -> anyhow::Result<Self> {
        let quant = match mode {
            QuantMode::Off => None,
            QuantMode::Int8 => {
                Some(QuantStore::build(store.rows(), store.dim())?)
            }
        };
        Ok(Self { store, quant })
    }

    pub fn store(&self) -> &RowStore {
        &self.store
    }

    /// Replace the row store in place (hot-swap to a newer export
    /// without dropping the connection).  The int8 shadow copy is
    /// rebuilt iff the engine was quantized, so the scan mode the
    /// operator chose survives the swap.  On error the OLD store keeps
    /// serving untouched — a bad export must never take down a healthy
    /// engine.
    pub fn swap_store(&mut self, store: RowStore) -> anyhow::Result<()> {
        let quant = match &self.quant {
            None => None,
            Some(_) => Some(QuantStore::build(store.rows(), store.dim())?),
        };
        self.store = store;
        self.quant = quant;
        Ok(())
    }

    /// Is the int8 scan active?
    pub fn quantized(&self) -> bool {
        self.quant.is_some()
    }

    /// Nearest neighbours of `id` by cosine, excluding `id` itself.
    pub fn topk<'s>(&self, id: u32, k: usize, s: &'s mut Scratch) -> &'s [Hit] {
        s.query.clear();
        s.query.extend_from_slice(self.store.row(id));
        self.scan([id, u32::MAX, u32::MAX], k, s)
    }

    /// 3CosAdd analogy `a:b :: c:?` — the exact query vector and
    /// exclusion set of [`crate::eval::analogy::eval_analogy`].
    pub fn analogy<'s>(
        &self,
        ia: u32,
        ib: u32,
        ic: u32,
        k: usize,
        s: &'s mut Scratch,
    ) -> &'s [Hit] {
        let d = self.store.dim();
        let (ua, ub, uc) = (self.store.row(ia), self.store.row(ib), self.store.row(ic));
        s.query.clear();
        s.query.reserve(d);
        for l in 0..d {
            s.query.push(ub[l] - ua[l] + uc[l]);
        }
        self.scan([ia, ib, ic], k, s)
    }

    /// Score every servable, non-excluded row against `s.query`, keeping
    /// the best `k` (score desc, tie → lower id).
    fn scan<'s>(&self, exclude: [u32; 3], k: usize, s: &'s mut Scratch) -> &'s [Hit] {
        let k = k.min(MAX_K);
        s.hits.clear();
        s.hits.reserve(MAX_K);
        if k == 0 {
            return &s.hits;
        }
        let n = self.store.n_rows() as u32;
        if let Some(q) = &self.quant {
            s.qcodes.resize(self.store.dim(), 0);
            let qscale = quantize_into(&s.query, &mut s.qcodes);
            for id in 0..n {
                if exclude.contains(&id) || !self.store.servable(id) {
                    continue;
                }
                push_hit(
                    &mut s.hits,
                    k,
                    Hit {
                        id,
                        score: q.score(&s.qcodes, qscale, id),
                    },
                );
            }
        } else {
            for id in 0..n {
                if exclude.contains(&id) || !self.store.servable(id) {
                    continue;
                }
                push_hit(
                    &mut s.hits,
                    k,
                    Hit {
                        id,
                        score: simd::dot(self.store.row(id), &s.query),
                    },
                );
            }
        }
        &s.hits
    }

    /// Answer one request line, writing the full JSON response (no
    /// trailing newline) into `s.out`.  Never panics on hostile input;
    /// every outcome is a one-line JSON object with an `"ok"` field.
    pub fn handle_line(&self, line: &[u8], s: &mut Scratch) {
        s.out.clear();
        let parsed = match parse_request(line, &mut s.req) {
            Ok(p) => p,
            Err(e) => {
                s.out.push_str("{\"ok\":false,\"error\":\"");
                let _ = write!(JsonEscaper(&mut s.out), "{e}");
                s.out.push_str("\"}");
                return;
            }
        };
        let k = parsed.k.unwrap_or(DEFAULT_K).min(MAX_K);
        match parsed.op {
            Op::TopK => {
                let Some(id) = self.lookup(0, s) else {
                    return;
                };
                self.topk(id, k, s);
                s.out.push_str("{\"ok\":true,\"op\":\"topk\",\"word\":");
                let _ = write_json_str(&mut s.out, &s.req.word);
                let _ = write!(s.out, ",\"k\":{k},");
                self.write_hits(s);
            }
            Op::Analogy => {
                let (Some(ia), Some(ib), Some(ic)) =
                    (self.lookup(1, s), self.lookup(2, s), self.lookup(3, s))
                else {
                    return;
                };
                self.analogy(ia, ib, ic, k, s);
                s.out.push_str("{\"ok\":true,\"op\":\"analogy\",\"a\":");
                let _ = write_json_str(&mut s.out, &s.req.a);
                s.out.push_str(",\"b\":");
                let _ = write_json_str(&mut s.out, &s.req.b);
                s.out.push_str(",\"c\":");
                let _ = write_json_str(&mut s.out, &s.req.c);
                let _ = write!(s.out, ",\"k\":{k},");
                self.write_hits(s);
            }
            Op::Stats => {
                let _ = write!(
                    s.out,
                    "{{\"ok\":true,\"op\":\"stats\",\"vocab\":{},\"dim\":{},\
                     \"quant\":\"{}\",\"generation\":{}",
                    self.store.n_rows(),
                    self.store.dim(),
                    if self.quant.is_some() { "int8" } else { "off" },
                    self.store.generation()
                );
            }
        }
        s.out.push('}');
    }

    /// Resolve one scratch word slot (0=word, 1=a, 2=b, 3=c) to a row
    /// id; on the FIRST miss, write the error response (naming the
    /// offending word) and return `None`.
    fn lookup(&self, slot: u8, s: &mut Scratch) -> Option<u32> {
        let w = match slot {
            0 => &s.req.word,
            1 => &s.req.a,
            2 => &s.req.b,
            _ => &s.req.c,
        };
        if let Some(id) = self.store.id(w) {
            return Some(id);
        }
        if s.out.is_empty() {
            s.out.push_str("{\"ok\":false,\"error\":\"unknown word\",\"word\":");
            let _ = write_json_str(&mut s.out, w);
            s.out.push('}');
        }
        None
    }

    /// Append `"hits":[{"word":…,"score":…},…]` to `s.out`.
    fn write_hits(&self, s: &mut Scratch) {
        s.out.push_str("\"hits\":[");
        for (i, h) in s.hits.iter().enumerate() {
            if i > 0 {
                s.out.push(',');
            }
            s.out.push_str("{\"word\":");
            let _ = write_json_str(&mut s.out, self.store.word(h.id));
            let _ = write!(s.out, ",\"score\":{}}}", h.score);
        }
        s.out.push(']');
    }
}

/// Keep `hits` sorted (score desc, tie → lower id) and capped at `k`.
fn push_hit(hits: &mut Vec<Hit>, k: usize, h: Hit) {
    let better = |x: &Hit, y: &Hit| x.score > y.score || (x.score == y.score && x.id < y.id);
    if hits.len() == k {
        match hits.last() {
            Some(last) if better(&h, last) => {
                hits.pop();
            }
            _ => return,
        }
    }
    let end = hits.len();
    let pos = hits.iter().position(|e| better(&h, e)).unwrap_or(end);
    hits.insert(pos, h);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;
    use crate::eval::analogy::{eval_analogy, AnalogyQuestion};
    use crate::model::Embedding;
    use crate::util::json::Json;

    /// Planted store: the analogy fixture from `eval::analogy::tests`
    /// plus a zero (unservable) row.
    fn planted() -> ServeEngine {
        engine_with(QuantMode::Off)
    }

    fn engine_with(mode: QuantMode) -> ServeEngine {
        let (words, emb) = planted_model();
        ServeEngine::from_store(RowStore::from_model(words, &emb).unwrap(), mode)
            .unwrap()
    }

    fn planted_model() -> (Vec<String>, Embedding) {
        let words: Vec<String> = ["king", "queen", "man", "woman", "x", "y", "dead"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut emb = Embedding::zeros(7, 3);
        emb.row_mut(0).copy_from_slice(&[1.0, 0.0, 1.0]);
        emb.row_mut(1).copy_from_slice(&[1.0, 1.0, 1.0]);
        emb.row_mut(2).copy_from_slice(&[1.0, 0.0, -1.0]);
        emb.row_mut(3).copy_from_slice(&[1.0, 1.0, -1.0]);
        emb.row_mut(4).copy_from_slice(&[-1.0, -1.0, 0.0]);
        emb.row_mut(5).copy_from_slice(&[-1.0, 0.5, -0.5]);
        // row 6 ("dead") stays zero: unservable.
        (words, emb)
    }

    #[test]
    fn topk_ranks_by_cosine_excluding_self_and_unservable() {
        let eng = planted();
        let mut s = Scratch::default();
        let hits = eng.topk(0, 10, &mut s).to_vec();
        assert!(!hits.iter().any(|h| h.id == 0), "query id excluded");
        assert!(!hits.iter().any(|h| h.id == 6), "unservable excluded");
        assert_eq!(hits.len(), 5);
        // Scores descending; ranking matches a brute-force unit-dot scan.
        for w in hits.windows(2) {
            assert!(
                w[0].score > w[1].score || (w[0].score == w[1].score && w[0].id < w[1].id),
                "order violated: {w:?}"
            );
        }
        assert_eq!(hits[0].id, 1, "queen is nearest to king in this geometry");
    }

    #[test]
    fn analogy_top1_matches_eval_oracle() {
        let eng = planted();
        let mut s = Scratch::default();
        let hits = eng.analogy(0, 1, 2, 5, &mut s);
        assert_eq!(hits[0].id, 3, "king:queen :: man:woman");
        // Cross-check against eval_analogy on the same geometry.
        let (words, emb) = planted_model();
        let text = words
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let n = words.len() - i;
                format!("{w} ").repeat(n)
            })
            .collect::<String>();
        let vocab = Vocab::build(text.split_whitespace(), 1);
        let q = vec![AnalogyQuestion {
            a: "king".into(),
            b: "queen".into(),
            c: "man".into(),
            d: "woman".into(),
            section: "s".into(),
        }];
        let r = eval_analogy(&q, &vocab, &emb);
        assert_eq!(r.correct, 1, "oracle agrees the planted answer is woman");
    }

    #[test]
    fn k_zero_and_k_clamp() {
        let eng = planted();
        let mut s = Scratch::default();
        assert!(eng.topk(0, 0, &mut s).is_empty());
        let n = eng.topk(0, 10_000, &mut s).len();
        assert_eq!(n, 5, "clamped k still returns every candidate");
    }

    #[test]
    fn tie_breaks_to_lower_id() {
        // Two identical rows: both appear, lower id first.
        let words: Vec<String> = ["q", "t1", "t2"].iter().map(|s| s.to_string()).collect();
        let mut emb = Embedding::zeros(3, 2);
        emb.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        emb.row_mut(1).copy_from_slice(&[0.5, 0.5]);
        emb.row_mut(2).copy_from_slice(&[0.5, 0.5]);
        let eng = ServeEngine::from_store(
            RowStore::from_model(words, &emb).unwrap(),
            QuantMode::Off,
        )
        .unwrap();
        let mut s = Scratch::default();
        let hits = eng.topk(0, 2, &mut s);
        assert_eq!(hits[0].score.to_bits(), hits[1].score.to_bits());
        assert_eq!((hits[0].id, hits[1].id), (1, 2));
    }

    #[test]
    fn int8_engine_agrees_on_large_margins() {
        let f32_eng = engine_with(QuantMode::Off);
        let int8_eng = engine_with(QuantMode::Int8);
        assert!(int8_eng.quantized());
        let mut s = Scratch::default();
        let f: Vec<u32> = f32_eng.topk(0, 3, &mut s).iter().map(|h| h.id).collect();
        let q: Vec<u32> = int8_eng.topk(0, 3, &mut s).iter().map(|h| h.id).collect();
        assert_eq!(f, q, "planted margins are far beyond int8 noise");
    }

    #[test]
    fn handle_line_json_contract() {
        let eng = planted();
        let mut s = Scratch::default();

        eng.handle_line(br#"{"op":"topk","word":"king","k":3}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("word").unwrap().as_str(), Some("king"));
        assert_eq!(j.get("k").unwrap().as_usize(), Some(3));
        let hits = j.get("hits").unwrap().as_arr().unwrap();
        assert_eq!(hits.len(), 3);
        assert_eq!(hits[0].get("word").unwrap().as_str(), Some("queen"));
        assert!(hits[0].get("score").unwrap().as_f64().is_some());

        eng.handle_line(br#"{"op":"analogy","a":"king","b":"queen","c":"man"}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let hits = j.get("hits").unwrap().as_arr().unwrap();
        assert_eq!(hits[0].get("word").unwrap().as_str(), Some("woman"));

        eng.handle_line(br#"{"op":"topk","word":"zzz"}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("error").unwrap().as_str(), Some("unknown word"));
        assert_eq!(j.get("word").unwrap().as_str(), Some("zzz"));

        eng.handle_line(br#"{"op":"frobnicate"}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert!(
            j.get("error").unwrap().as_str().unwrap().contains("bad request"),
            "{}",
            s.out
        );

        // Hostile bytes still get a JSON answer, never a panic.
        eng.handle_line(&[0xFF, 0xFE, b'{'], &mut s);
        assert!(Json::parse(&s.out).is_ok());
    }

    #[test]
    fn stats_reports_shape_quant_and_generation() {
        let (words, emb) = planted_model();
        let mut store = RowStore::from_model(words, &emb).unwrap();
        store.set_generation(9);
        let eng = ServeEngine::from_store(store, QuantMode::Int8).unwrap();
        let mut s = Scratch::default();
        eng.handle_line(br#"{"op":"stats"}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("op").unwrap().as_str(), Some("stats"));
        assert_eq!(j.get("vocab").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("dim").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("quant").unwrap().as_str(), Some("int8"));
        assert_eq!(j.get("generation").unwrap().as_usize(), Some(9));
    }

    #[test]
    fn swap_store_serves_new_rows_and_keeps_quant_mode() {
        let eng_plain = engine_with(QuantMode::Off);
        assert!(!eng_plain.quantized());
        let mut eng = engine_with(QuantMode::Int8);
        // Swap in a 2-word store with a bumped generation.
        let words: Vec<String> = ["late", "word"].iter().map(|s| s.to_string()).collect();
        let mut emb = Embedding::zeros(2, 3);
        emb.row_mut(0).copy_from_slice(&[1.0, 0.0, 0.0]);
        emb.row_mut(1).copy_from_slice(&[0.8, 0.6, 0.0]);
        let mut st = RowStore::from_model(words, &emb).unwrap();
        st.set_generation(3);
        eng.swap_store(st).unwrap();
        assert!(eng.quantized(), "quant mode survives the swap");
        let mut s = Scratch::default();
        eng.handle_line(br#"{"op":"topk","word":"late","k":1}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        eng.handle_line(br#"{"op":"stats"}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("vocab").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("generation").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn handle_line_unknown_analogy_word_names_it() {
        let eng = planted();
        let mut s = Scratch::default();
        eng.handle_line(br#"{"op":"analogy","a":"king","b":"gone","c":"man"}"#, &mut s);
        let j = Json::parse(&s.out).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("word").unwrap().as_str(), Some("gone"));
    }
}
