//! Embedding-serving engine (the `serve` subcommand).
//!
//! Turns a trained model into a query service: load vectors (hardened
//! `model::io::load_text`, or the mmap-able binary [`store::RowStore`]),
//! optionally build an int8 shadow copy ([`quant`]), then answer
//! line-delimited JSON `topk` / `analogy` requests over stdin/stdout or
//! a TCP socket.
//!
//! Layering mirrors the training side:
//! - [`store`] — scan-ready unit rows, binary format, mmap open path
//!   (shared `util::mmap` substrate with the corpus cache);
//! - [`quant`] — per-row symmetric int8 codes + scales;
//! - [`request`] — zero-allocation pull parser for request lines;
//! - [`engine`] — SIMD-dispatched scored scan + response writer;
//! - this module — the blocking I/O loops.
//!
//! The serve loop is allocation-free at steady state (request scratch,
//! hit buffer and response string are all reused), pinned by
//! `tests/alloc_steadystate.rs`; answer parity against the eval oracles
//! is pinned by `tests/serve_parity.rs`.

pub mod engine;
pub mod quant;
pub mod request;
pub mod store;

pub use engine::{Hit, Scratch, ServeEngine, DEFAULT_K, MAX_K};
pub use store::RowStore;

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpListener;

/// Serve requests from `stdin`, one JSON object per line, writing one
/// JSON response line each.  Returns at EOF.
pub fn run_stdio(eng: &ServeEngine) -> anyhow::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut r = stdin.lock();
    let mut w = BufWriter::new(stdout.lock());
    serve_stream(eng, &mut r, &mut w)
}

/// Accept TCP connections on `addr` and serve each to completion,
/// sequentially (the scan is memory-bandwidth-bound; interleaving
/// clients would only thrash the row cache).  A per-connection error
/// is logged and the accept loop continues; only accept failures and
/// bind failures abort.
pub fn run_listen(eng: &ServeEngine, addr: &str) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("serve: cannot listen on {addr}: {e}"))?;
    eprintln!(
        "serve: listening on {} ({} rows, dim {})",
        listener.local_addr()?,
        eng.store().n_rows(),
        eng.store().dim()
    );
    loop {
        let (sock, peer) = listener.accept()?;
        sock.set_nodelay(true).ok();
        let mut r = BufReader::new(sock.try_clone()?);
        let mut w = BufWriter::new(sock);
        if let Err(e) = serve_stream(eng, &mut r, &mut w) {
            eprintln!("serve: connection {peer}: {e}");
        }
    }
}

/// The shared request/response loop: `read_until(b'\n')` into the
/// scratch line buffer, answer, write + flush.  Flushing per line keeps
/// a pipelined client from deadlocking against a buffered response.
fn serve_stream<R: BufRead, W: Write>(
    eng: &ServeEngine,
    r: &mut R,
    w: &mut W,
) -> anyhow::Result<()> {
    let mut s = Scratch::default();
    loop {
        s.line.clear();
        let n = r.read_until(b'\n', &mut s.line)?;
        if n == 0 {
            return Ok(());
        }
        // The line buffer lives inside the scratch the engine mutates,
        // so move it out for the call (a Vec move, no copy/alloc) and
        // put it back after — capacity is retained either way.
        let line = std::mem::take(&mut s.line);
        let req = trim_line(&line);
        if !req.is_empty() {
            eng.handle_line(req, &mut s);
            w.write_all(s.out.as_bytes())?;
            w.write_all(b"\n")?;
            w.flush()?;
        }
        s.line = line;
    }
}

/// Strip the trailing newline (and optional CR) from a raw line.
fn trim_line(line: &[u8]) -> &[u8] {
    let mut end = line.len();
    while end > 0 && (line[end - 1] == b'\n' || line[end - 1] == b'\r') {
        end -= 1;
    }
    &line[..end]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantMode;
    use crate::model::Embedding;

    #[test]
    fn stream_loop_answers_per_line_and_stops_at_eof() {
        let words: Vec<String> = ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        let mut emb = Embedding::zeros(3, 2);
        emb.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        emb.row_mut(1).copy_from_slice(&[0.9, 0.1]);
        emb.row_mut(2).copy_from_slice(&[0.0, 1.0]);
        let eng = ServeEngine::from_store(
            RowStore::from_model(words, &emb).unwrap(),
            QuantMode::Off,
        );
        let input = b"{\"op\":\"topk\",\"word\":\"a\",\"k\":1}\n\r\n\nnot json\n";
        let mut out = Vec::new();
        serve_stream(&eng, &mut &input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "blank lines are skipped: {text:?}");
        assert!(lines[0].contains("\"ok\":true"), "{}", lines[0]);
        assert!(lines[0].contains("\"word\":\"a\""), "{}", lines[0]);
        assert!(lines[1].contains("\"ok\":false"), "{}", lines[1]);
    }
}
