//! Embedding-serving engine (the `serve` subcommand).
//!
//! Turns a trained model into a query service: load vectors (hardened
//! `model::io::load_text`, or the mmap-able binary [`store::RowStore`]),
//! optionally build an int8 shadow copy ([`quant`]), then answer
//! line-delimited JSON `topk` / `analogy` requests over stdin/stdout or
//! a TCP socket.
//!
//! Layering mirrors the training side:
//! - [`store`] — scan-ready unit rows, binary format, mmap open path
//!   (shared `util::mmap` substrate with the corpus cache);
//! - [`quant`] — per-row symmetric int8 codes + scales;
//! - [`request`] — zero-allocation pull parser for request lines;
//! - [`engine`] — SIMD-dispatched scored scan + response writer;
//! - this module — the blocking I/O loops.
//!
//! The serve loop is allocation-free at steady state (request scratch,
//! hit buffer and response string are all reused), pinned by
//! `tests/alloc_steadystate.rs`; answer parity against the eval oracles
//! is pinned by `tests/serve_parity.rs`.

pub mod engine;
pub mod quant;
pub mod request;
pub mod store;

pub use engine::{Hit, Scratch, ServeEngine, DEFAULT_K, MAX_K};
pub use store::RowStore;

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::time::SystemTime;

/// Polls a row-store file for replacement by a newer export (the
/// `stream` trainer rewrites it atomically at every checkpoint) so a
/// long-lived server hot-swaps without dropping its connection.
///
/// Change detection is (mtime, length); the check runs between request
/// lines, so an idle connection costs nothing and a busy one pays one
/// `stat(2)` per request.
pub struct StoreWatcher {
    path: PathBuf,
    seen: Option<(SystemTime, u64)>,
}

impl StoreWatcher {
    /// Watch `path`; the file as it exists NOW counts as already
    /// served (the caller just loaded it).
    pub fn new(path: &Path) -> Self {
        Self {
            seen: Self::stat(path),
            path: path.to_path_buf(),
        }
    }

    fn stat(path: &Path) -> Option<(SystemTime, u64)> {
        let m = std::fs::metadata(path).ok()?;
        Some((m.modified().ok()?, m.len()))
    }

    /// Reload when the file changed since the last look.  An unreadable
    /// or invalid file is logged and skipped — the exporter writes via
    /// atomic rename, so this only fires on genuine corruption, and the
    /// current store keeps serving.
    pub fn poll(&mut self) -> Option<RowStore> {
        let now = Self::stat(&self.path)?;
        if self.seen == Some(now) {
            return None;
        }
        // Mark seen even on failure: retrying the same bad bytes every
        // request line would only spam the log.
        self.seen = Some(now);
        match RowStore::open(&self.path) {
            Ok(st) => Some(st),
            Err(e) => {
                eprintln!("serve: watch {}: {e:#}; keeping current store", self.path.display());
                None
            }
        }
    }
}

/// Serve requests from `stdin`, one JSON object per line, writing one
/// JSON response line each.  Returns at EOF.  With a watcher, the
/// store hot-swaps between request lines.
pub fn run_stdio(eng: &mut ServeEngine, watcher: Option<&mut StoreWatcher>) -> anyhow::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut r = stdin.lock();
    let mut w = BufWriter::new(stdout.lock());
    serve_stream(eng, watcher, &mut r, &mut w)
}

/// Accept TCP connections on `addr` and serve each to completion,
/// sequentially (the scan is memory-bandwidth-bound; interleaving
/// clients would only thrash the row cache).  A per-connection error
/// is logged and the accept loop continues; only accept failures and
/// bind failures abort.  With a watcher, the store hot-swaps between
/// request lines — mid-connection included.
pub fn run_listen(
    eng: &mut ServeEngine,
    addr: &str,
    mut watcher: Option<&mut StoreWatcher>,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("serve: cannot listen on {addr}: {e}"))?;
    eprintln!(
        "serve: listening on {} ({} rows, dim {})",
        listener.local_addr()?,
        eng.store().n_rows(),
        eng.store().dim()
    );
    loop {
        let (sock, peer) = listener.accept()?;
        sock.set_nodelay(true).ok();
        let mut r = BufReader::new(sock.try_clone()?);
        let mut w = BufWriter::new(sock);
        if let Err(e) = serve_stream(eng, watcher.as_deref_mut(), &mut r, &mut w) {
            eprintln!("serve: connection {peer}: {e}");
        }
    }
}

/// The shared request/response loop: `read_until(b'\n')` into the
/// scratch line buffer, answer, write + flush.  Flushing per line keeps
/// a pipelined client from deadlocking against a buffered response.
/// The watcher (if any) is polled between lines, never mid-answer.
fn serve_stream<R: BufRead, W: Write>(
    eng: &mut ServeEngine,
    mut watcher: Option<&mut StoreWatcher>,
    r: &mut R,
    w: &mut W,
) -> anyhow::Result<()> {
    let mut s = Scratch::default();
    loop {
        s.line.clear();
        let n = r.read_until(b'\n', &mut s.line)?;
        if n == 0 {
            return Ok(());
        }
        if let Some(wt) = watcher.as_deref_mut() {
            if let Some(st) = wt.poll() {
                let (generation, rows) = (st.generation(), st.n_rows());
                // A bad export must not kill a healthy engine: log and
                // keep serving the old store (swap_store leaves it
                // untouched on error).
                match eng.swap_store(st) {
                    Ok(()) => eprintln!(
                        "serve: hot-swapped store (generation \
                         {generation}, {rows} rows)"
                    ),
                    Err(e) => eprintln!(
                        "serve: REJECTED store swap (generation \
                         {generation}): {e}; keeping current store"
                    ),
                }
            }
        }
        // The line buffer lives inside the scratch the engine mutates,
        // so move it out for the call (a Vec move, no copy/alloc) and
        // put it back after — capacity is retained either way.
        let line = std::mem::take(&mut s.line);
        let req = trim_line(&line);
        if !req.is_empty() {
            eng.handle_line(req, &mut s);
            w.write_all(s.out.as_bytes())?;
            w.write_all(b"\n")?;
            w.flush()?;
        }
        s.line = line;
    }
}

/// Strip the trailing newline (and optional CR) from a raw line.
fn trim_line(line: &[u8]) -> &[u8] {
    let mut end = line.len();
    while end > 0 && (line[end - 1] == b'\n' || line[end - 1] == b'\r') {
        end -= 1;
    }
    &line[..end]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantMode;
    use crate::model::Embedding;

    #[test]
    fn stream_loop_answers_per_line_and_stops_at_eof() {
        let words: Vec<String> = ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        let mut emb = Embedding::zeros(3, 2);
        emb.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        emb.row_mut(1).copy_from_slice(&[0.9, 0.1]);
        emb.row_mut(2).copy_from_slice(&[0.0, 1.0]);
        let mut eng = ServeEngine::from_store(
            RowStore::from_model(words, &emb).unwrap(),
            QuantMode::Off,
        )
        .unwrap();
        let input = b"{\"op\":\"topk\",\"word\":\"a\",\"k\":1}\n\r\n\nnot json\n";
        let mut out = Vec::new();
        serve_stream(&mut eng, None, &mut &input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "blank lines are skipped: {text:?}");
        assert!(lines[0].contains("\"ok\":true"), "{}", lines[0]);
        assert!(lines[0].contains("\"word\":\"a\""), "{}", lines[0]);
        assert!(lines[1].contains("\"ok\":false"), "{}", lines[1]);
    }

    fn tiny_store(words: &[&str], generation: u64) -> RowStore {
        let words: Vec<String> = words.iter().map(|s| s.to_string()).collect();
        let mut emb = Embedding::zeros(words.len(), 2);
        for id in 0..words.len() as u32 {
            emb.row_mut(id).copy_from_slice(&[1.0, id as f32]);
        }
        let mut st = RowStore::from_model(words, &emb).unwrap();
        st.set_generation(generation);
        st
    }

    #[test]
    fn watcher_hot_swaps_store_between_lines() {
        let path = std::env::temp_dir().join(format!(
            "pw2v_watch_{}.rst",
            std::process::id()
        ));
        tiny_store(&["a", "b"], 1).save(&path).unwrap();
        let mut eng =
            ServeEngine::from_store(RowStore::open(&path).unwrap(), QuantMode::Off)
                .unwrap();
        let mut watcher = StoreWatcher::new(&path);
        // Unchanged file: no reload.
        assert!(watcher.poll().is_none());
        // A newer export lands (longer word list changes the length, so
        // detection never depends on mtime granularity).
        tiny_store(&["a", "b", "late-arrival"], 2).save(&path).unwrap();
        let input = b"{\"op\":\"stats\"}\n{\"op\":\"topk\",\"word\":\"late-arrival\",\"k\":1}\n";
        let mut out = Vec::new();
        serve_stream(&mut eng, Some(&mut watcher), &mut &input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(
            lines[0].contains("\"generation\":2") && lines[0].contains("\"vocab\":3"),
            "stats must see the swapped store: {}",
            lines[0]
        );
        assert!(
            lines[1].contains("\"ok\":true"),
            "word existing only in the new export must resolve: {}",
            lines[1]
        );
        std::fs::remove_file(&path).ok();
    }
}
