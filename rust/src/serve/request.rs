//! Pull-parsed serve requests: one flat JSON object per line.
//!
//! In the style of picojson-rs: a hand-rolled, iterative (no recursion),
//! panic-free scanner over the raw line bytes that writes string values
//! into CALLER-OWNED scratch buffers — at steady state a request parse
//! allocates nothing (the `alloc_steadystate` gate covers the whole
//! serve loop).  This is deliberately NOT `util::json::Json::parse`,
//! which builds an owned tree per document; the response side reuses
//! `util::json`'s escaping writer instead.
//!
//! Accepted grammar (flat object, known keys, any order):
//!
//! ```text
//! {"op":"topk","word":W,"k":K}
//! {"op":"analogy","a":A,"b":B,"c":C,"k":K}
//! {"op":"stats"}
//! ```
//!
//! `k` is optional (the engine applies its default and cap).  Unknown
//! keys, nested values, duplicate keys, or missing required keys are
//! errors — a serving endpoint should reject what it does not
//! understand, not guess.  String escapes match `util::json`'s parser
//! (`\" \\ \/ \b \f \n \r \t \uXXXX`, no surrogate pairs).

use std::fmt;

/// Request verb.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    TopK,
    Analogy,
    /// Engine introspection: vocab size, dim, quant mode, store
    /// generation.  Takes no other field.
    Stats,
}

/// Parse outcome: the op plus the requested `k`.  String fields live
/// in the [`ReqScratch`] the parser filled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParsedRequest {
    pub op: Op,
    /// Requested k; `None` means "engine default".
    pub k: Option<usize>,
}

/// Caller-owned string scratch: buffers are cleared and refilled per
/// request, retaining capacity across requests.
#[derive(Default)]
pub struct ReqScratch {
    pub word: String,
    pub a: String,
    pub b: String,
    pub c: String,
}

/// Parse error: byte position + static message (no allocation on the
/// error path either — a hostile client must not make the server
/// allocate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReqError {
    pub pos: usize,
    pub msg: &'static str,
}

impl fmt::Display for ReqError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bad request at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ReqError {}

struct Scanner<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Scanner<'a> {
    fn err(&self, msg: &'static str) -> ReqError {
        ReqError { pos: self.pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8, msg: &'static str) -> Result<(), ReqError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(msg))
        }
    }

    /// Scan a JSON string, unescaping into `out` (cleared first).
    fn string_into(&mut self, out: &mut String) -> Result<(), ReqError> {
        out.clear();
        self.eat(b'"', "expected '\"'")?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let mut cp = 0u32;
                            for i in 0..4 {
                                let d = self.b[self.pos + i];
                                cp = cp * 16
                                    + match d {
                                        b'0'..=b'9' => (d - b'0') as u32,
                                        b'a'..=b'f' => (d - b'a' + 10) as u32,
                                        b'A'..=b'F' => (d - b'A' + 10) as u32,
                                        _ => return Err(self.err("bad \\u")),
                                    };
                            }
                            self.pos += 4;
                            // Surrogate pairs unsupported, matching
                            // util::json: map to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // Copy a maximal run of plain bytes; the line must be
                    // UTF-8 for the value to be accepted.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    let run = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| ReqError {
                            pos: start,
                            msg: "string is not UTF-8",
                        })?;
                    out.push_str(run);
                }
            }
        }
    }

    /// Scan a small non-negative integer (the only number the grammar
    /// holds is `k`).
    fn small_uint(&mut self) -> Result<usize, ReqError> {
        let start = self.pos;
        let mut v: usize = 0;
        while let Some(c) = self.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            v = v
                .checked_mul(10)
                .and_then(|v| v.checked_add((c - b'0') as usize))
                .ok_or(ReqError {
                    pos: start,
                    msg: "k out of range",
                })?;
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected a non-negative integer"));
        }
        Ok(v)
    }
}

/// Key slots the grammar knows, for duplicate detection.
const K_OP: u8 = 0;
const K_WORD: u8 = 1;
const K_A: u8 = 2;
const K_B: u8 = 3;
const K_C: u8 = 4;
const K_K: u8 = 5;

/// Parse one request line into `scratch`, returning the op and `k`.
pub fn parse_request(line: &[u8], scratch: &mut ReqScratch) -> Result<ParsedRequest, ReqError> {
    let mut s = Scanner { b: line, pos: 0 };
    let mut op: Option<Op> = None;
    let mut k: Option<usize> = None;
    let mut seen = [false; 6];
    scratch.word.clear();
    scratch.a.clear();
    scratch.b.clear();
    scratch.c.clear();
    s.ws();
    s.eat(b'{', "expected '{'")?;
    s.ws();
    if s.peek() == Some(b'}') {
        return Err(s.err("empty request"));
    }
    loop {
        s.ws();
        // Keys are short known ASCII literals: match them without an
        // unescape buffer.
        let kslot = key_slot(&mut s)?;
        if seen[kslot as usize] {
            return Err(s.err("duplicate key"));
        }
        seen[kslot as usize] = true;
        s.ws();
        s.eat(b':', "expected ':'")?;
        s.ws();
        match kslot {
            K_OP => op = Some(op_value(&mut s)?),
            K_WORD => s.string_into(&mut scratch.word)?,
            K_A => s.string_into(&mut scratch.a)?,
            K_B => s.string_into(&mut scratch.b)?,
            K_C => s.string_into(&mut scratch.c)?,
            _ => k = Some(s.small_uint()?),
        }
        s.ws();
        match s.peek() {
            Some(b',') => s.pos += 1,
            Some(b'}') => {
                s.pos += 1;
                break;
            }
            _ => return Err(s.err("expected ',' or '}'")),
        }
    }
    s.ws();
    if s.pos != s.b.len() {
        return Err(s.err("trailing data after request"));
    }
    let op = op.ok_or(ReqError {
        pos: 0,
        msg: "missing \"op\"",
    })?;
    match op {
        Op::TopK => {
            if !seen[K_WORD as usize] {
                return Err(ReqError {
                    pos: 0,
                    msg: "topk requires \"word\"",
                });
            }
            if seen[K_A as usize] || seen[K_B as usize] || seen[K_C as usize] {
                return Err(ReqError {
                    pos: 0,
                    msg: "topk takes \"word\", not \"a\"/\"b\"/\"c\"",
                });
            }
        }
        Op::Analogy => {
            if !(seen[K_A as usize] && seen[K_B as usize] && seen[K_C as usize]) {
                return Err(ReqError {
                    pos: 0,
                    msg: "analogy requires \"a\", \"b\" and \"c\"",
                });
            }
            if seen[K_WORD as usize] {
                return Err(ReqError {
                    pos: 0,
                    msg: "analogy takes \"a\"/\"b\"/\"c\", not \"word\"",
                });
            }
        }
        Op::Stats => {
            if seen[K_WORD as usize]
                || seen[K_A as usize]
                || seen[K_B as usize]
                || seen[K_C as usize]
                || seen[K_K as usize]
            {
                return Err(ReqError {
                    pos: 0,
                    msg: "stats takes no field besides \"op\"",
                });
            }
        }
    }
    Ok(ParsedRequest { op, k })
}

/// Match one of the known keys (a quoted ASCII literal) in place.
fn key_slot(s: &mut Scanner) -> Result<u8, ReqError> {
    s.eat(b'"', "expected a key")?;
    let start = s.pos;
    while let Some(c) = s.peek() {
        if c == b'"' {
            break;
        }
        if c == b'\\' {
            return Err(s.err("escapes not allowed in keys"));
        }
        s.pos += 1;
    }
    let name = &s.b[start..s.pos];
    s.eat(b'"', "unterminated key")?;
    match name {
        b"op" => Ok(K_OP),
        b"word" => Ok(K_WORD),
        b"a" => Ok(K_A),
        b"b" => Ok(K_B),
        b"c" => Ok(K_C),
        b"k" => Ok(K_K),
        _ => Err(ReqError {
            pos: start,
            msg: "unknown key (op|word|a|b|c|k)",
        }),
    }
}

/// Match the `"topk"` / `"analogy"` op literal in place.
fn op_value(s: &mut Scanner) -> Result<Op, ReqError> {
    s.eat(b'"', "op must be a string")?;
    let start = s.pos;
    while let Some(c) = s.peek() {
        if c == b'"' {
            break;
        }
        s.pos += 1;
    }
    let name = &s.b[start..s.pos];
    s.eat(b'"', "unterminated op")?;
    match name {
        b"topk" => Ok(Op::TopK),
        b"analogy" => Ok(Op::Analogy),
        b"stats" => Ok(Op::Stats),
        _ => Err(ReqError {
            pos: start,
            msg: "unknown op (topk|analogy|stats)",
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(line: &str) -> Result<(ParsedRequest, ReqScratch), ReqError> {
        let mut s = ReqScratch::default();
        parse_request(line.as_bytes(), &mut s).map(|r| (r, s))
    }

    #[test]
    fn parses_topk() {
        let (r, s) = parse(r#"{"op":"topk","word":"king","k":5}"#).unwrap();
        assert_eq!(r.op, Op::TopK);
        assert_eq!(r.k, Some(5));
        assert_eq!(s.word, "king");
    }

    #[test]
    fn parses_analogy_any_key_order() {
        let (r, s) =
            parse(r#" { "c" : "man" , "op" : "analogy" , "a" : "king" , "b" : "queen" } "#)
                .unwrap();
        assert_eq!(r.op, Op::Analogy);
        assert_eq!(r.k, None);
        assert_eq!((s.a.as_str(), s.b.as_str(), s.c.as_str()), ("king", "queen", "man"));
    }

    #[test]
    fn parses_stats() {
        let (r, _) = parse(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(r.op, Op::Stats);
        assert_eq!(r.k, None);
    }

    #[test]
    fn unescapes_values() {
        let (_, s) = parse(r#"{"op":"topk","word":"a\tbé\"q\""}"#).unwrap();
        assert_eq!(s.word, "a\tbé\"q\"");
    }

    #[test]
    fn rejects_malformed() {
        for (line, want) in [
            ("", "expected '{'"),
            ("{}", "empty request"),
            (r#"{"op":"topk"}"#, "topk requires \"word\""),
            (r#"{"word":"x"}"#, "missing \"op\""),
            (r#"{"op":"frob","word":"x"}"#, "unknown op (topk|analogy|stats)"),
            (r#"{"op":"stats","word":"x"}"#, "stats takes no field besides \"op\""),
            (r#"{"op":"stats","k":3}"#, "stats takes no field besides \"op\""),
            (r#"{"op":"topk","word":"x","word":"y"}"#, "duplicate key"),
            (r#"{"op":"topk","word":"x","zzz":1}"#, "unknown key (op|word|a|b|c|k)"),
            (r#"{"op":"topk","word":"x"} extra"#, "trailing data after request"),
            (r#"{"op":"topk","word":"x","k":-1}"#, "expected a non-negative integer"),
            (r#"{"op":"topk","word":"x","k":99999999999999999999}"#, "k out of range"),
            (r#"{"op":"analogy","a":"x","b":"y"}"#, "analogy requires \"a\", \"b\" and \"c\""),
            (
                r#"{"op":"analogy","a":"x","b":"y","c":"z","word":"w"}"#,
                "analogy takes \"a\"/\"b\"/\"c\", not \"word\"",
            ),
            (r#"{"op":"topk","word":"x","a":"y"}"#, "topk takes \"word\", not \"a\"/\"b\"/\"c\""),
            (r#"{"op":"topk","word":"x"#, "unterminated string"),
        ] {
            let err = parse(line).unwrap_err();
            assert_eq!(err.msg, want, "line {line:?} -> {err}");
        }
    }

    #[test]
    fn scratch_capacity_is_reused() {
        let mut s = ReqScratch::default();
        parse_request(br#"{"op":"topk","word":"a-rather-long-word-here"}"#, &mut s).unwrap();
        let cap = s.word.capacity();
        let p = s.word.as_ptr();
        parse_request(br#"{"op":"topk","word":"short"}"#, &mut s).unwrap();
        assert_eq!(s.word, "short");
        assert_eq!(s.word.capacity(), cap, "no shrink");
        assert_eq!(s.word.as_ptr(), p, "no realloc");
    }
}
