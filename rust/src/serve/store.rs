//! The serve-side row store: unit-normalised embedding rows in a binary
//! file the server can `mmap(2)` and scan without parsing.
//!
//! Loading a text vector file means float-parsing `V·D` decimals at
//! every server start; the row store does that ONCE (`build` + `save`)
//! and afterwards `open` is O(header + vocab) — the row payload maps
//! straight into the scan loop through the PR-3 raw-mmap discipline
//! (`util::mmap`, shared with the corpus cache).
//!
//! ## File format (version 2)
//!
//! ```text
//! offset  size        field
//! 0       8           magic "PW2VRST\0"
//! 8       4           version (u32 LE) = 2
//! 12      4           dim (u32 LE)
//! 16      8           n_rows (u64 LE)
//! 24      8           word-table length in bytes (u64 LE)
//! 32      8           FNV-1a over [word table ‖ flag bytes] (u64 LE)
//! 40      8           generation (u64 LE) — producer's export counter
//! 48      …           word table: per row u16 LE length + UTF-8 bytes
//! …       n_rows      servable flags (1 byte each, 0/1)
//! …       0–63        zero padding to a 64-byte multiple offset
//! …       4·n·dim     unit rows (f32 LE, row-major, packed)
//! ```
//!
//! Version 1 (no generation field, word table at offset 40) is still
//! accepted by `open` and reads as generation 0.  The generation lets a
//! hot-swapping server (`serve --watch` fed by the `stream` trainer's
//! periodic exports) report WHICH export it is serving — the `stats`
//! op exposes it on the wire.
//!
//! Rows are stored UNIT-NORMALISED (exactly
//! [`crate::eval::analogy::normalized_matrix`]'s arithmetic), so the
//! scan's score is a plain dot product and bitwise-matches the eval
//! oracles.  Servable flags bake in the
//! [`crate::eval::similarity::row_servable`] policy at build time:
//! zero-norm and non-finite rows never enter ranked results.
//!
//! The word table and flags are FNV-checksummed (they are small and
//! parsed eagerly); the row payload is validated by SIZE only, like the
//! corpus cache — opening a multi-GB store must stay O(1), not a
//! full-file scan.  The f32 payload starts at a 64-byte-multiple file
//! offset, so a page-aligned mapping lets the scan cast the bytes in
//! place; misaligned or big-endian configurations fall back to one
//! parsed copy.

use std::collections::HashMap;
use std::path::Path;

use crate::eval::analogy::normalized_matrix;
use crate::eval::similarity::row_servable;
use crate::model::io::atomic_write;
use crate::model::Embedding;
use crate::util::fnv::Fnv1a;
use crate::util::mmap::{load_bytes, Bytes};

/// Identifies the file as a pw2v serve row store.
pub const MAGIC: [u8; 8] = *b"PW2VRST\0";
/// Current format version.
pub const VERSION: u32 = 2;

/// Version-1 header (no generation field); still readable.
const V1_HEADER_LEN: usize = 40;
const HEADER_LEN: usize = 48;
/// Row payload alignment (file offset); also covers any SIMD width.
const ROW_ALIGN: usize = 64;
/// Dimension cap: keeps `simd::dot_i8`'s i32 accumulation overflow-free
/// and rejects absurd headers before any allocation sizing.
pub const MAX_DIM: usize = 1 << 17;

/// Where the unit rows live after `open`.
enum RowsData {
    /// Parsed/copied into memory (text-model builds, misaligned or
    /// big-endian fallbacks).
    Owned(Vec<f32>),
    /// Borrowed in place from the file bytes (mmap fast path): `off` is
    /// the byte offset of the payload, `n` its length in f32s.
    #[cfg(target_endian = "little")]
    Raw { bytes: Bytes, off: usize, n: usize },
}

/// A validated, scan-ready set of unit rows with their vocabulary.
pub struct RowStore {
    words: Vec<String>,
    /// First-occurrence word → row id (duplicate words in a hostile
    /// input resolve to the lowest id, deterministically).
    index: HashMap<String, u32>,
    servable: Vec<bool>,
    dim: usize,
    /// Producer's export counter (0 for batch builds and v1 files).
    generation: u64,
    data: RowsData,
}

impl RowStore {
    /// Build from an in-memory model: rows are unit-normalised with the
    /// analogy oracle's exact arithmetic and flagged through the serve
    /// scan policy ([`row_servable`] on the ORIGINAL rows).
    pub fn from_model(words: Vec<String>, emb: &Embedding) -> anyhow::Result<Self> {
        anyhow::ensure!(
            words.len() == emb.vocab(),
            "word list ({}) and matrix ({}) disagree",
            words.len(),
            emb.vocab()
        );
        anyhow::ensure!(
            emb.vocab() > 0 && emb.dim() > 0 && emb.dim() <= MAX_DIM,
            "unservable model shape {}x{}",
            emb.vocab(),
            emb.dim()
        );
        let servable = (0..emb.vocab() as u32)
            .map(|id| row_servable(emb.row(id)))
            .collect();
        let unit = normalized_matrix(emb);
        let index = build_index(&words);
        Ok(Self {
            words,
            index,
            servable,
            dim: emb.dim(),
            generation: 0,
            data: RowsData::Owned(unit),
        })
    }

    /// Stamp the export counter (streaming checkpoint exports; batch
    /// builds stay at 0).
    pub fn set_generation(&mut self, generation: u64) {
        self.generation = generation;
    }

    /// Producer's export counter this store was written with.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Serialise to the binary format via the atomic tmp+rename+fsync
    /// discipline.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut names = Vec::new();
        for w in &self.words {
            let b = w.as_bytes();
            anyhow::ensure!(b.len() <= u16::MAX as usize, "word longer than 64KiB");
            names.extend_from_slice(&(b.len() as u16).to_le_bytes());
            names.extend_from_slice(b);
        }
        let flags: Vec<u8> = self.servable.iter().map(|&s| s as u8).collect();
        let mut h = Fnv1a::new();
        h.update(&names);
        h.update(&flags);
        let digest = h.digest();
        let rows = self.rows();
        atomic_write(path, |w| {
            use std::io::Write as _;
            w.write_all(&MAGIC)?;
            w.write_all(&VERSION.to_le_bytes())?;
            w.write_all(&(self.dim as u32).to_le_bytes())?;
            w.write_all(&(self.words.len() as u64).to_le_bytes())?;
            w.write_all(&(names.len() as u64).to_le_bytes())?;
            w.write_all(&digest.to_le_bytes())?;
            w.write_all(&self.generation.to_le_bytes())?;
            w.write_all(&names)?;
            w.write_all(&flags)?;
            let body = HEADER_LEN + names.len() + flags.len();
            let pad = crate::util::round_up(body, ROW_ALIGN) - body;
            w.write_all(&vec![0u8; pad])?;
            for &x in rows {
                w.write_all(&x.to_le_bytes())?;
            }
            Ok(())
        })
    }

    /// Open and validate a row store.  The row payload is borrowed from
    /// the mapping when alignment and endianness allow, else copied.
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        let bytes = load_bytes(path, true)?;
        anyhow::ensure!(
            bytes.len() >= V1_HEADER_LEN && bytes[..8] == MAGIC,
            "not a pw2v row store (bad magic): {}",
            path.display()
        );
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        anyhow::ensure!(
            version == 1 || version == VERSION,
            "row store version {version} (this build reads 1..={VERSION})"
        );
        let header_len = if version == 1 { V1_HEADER_LEN } else { HEADER_LEN };
        anyhow::ensure!(
            bytes.len() >= header_len,
            "row store header truncated: {}",
            path.display()
        );
        let dim = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let n = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
        let names_len = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
        let digest = u64::from_le_bytes(bytes[32..40].try_into().unwrap());
        let generation = if version == 1 {
            0
        } else {
            u64::from_le_bytes(bytes[40..48].try_into().unwrap())
        };
        anyhow::ensure!(
            n > 0 && dim > 0 && dim <= MAX_DIM && n < u32::MAX as u64,
            "implausible row store header ({n} x {dim})"
        );
        // All size arithmetic in u128: a hostile header must not wrap.
        let body = header_len as u128 + names_len as u128 + n as u128;
        let rows_off = body.div_ceil(ROW_ALIGN as u128) * ROW_ALIGN as u128;
        let want = rows_off + 4 * n as u128 * dim as u128;
        anyhow::ensure!(
            bytes.len() as u128 == want,
            "row store is {} bytes, header implies {want}",
            bytes.len()
        );
        let (n, names_len, rows_off) = (n as usize, names_len as usize, rows_off as usize);
        let names = &bytes[header_len..header_len + names_len];
        let flags = &bytes[header_len + names_len..header_len + names_len + n];
        let mut h = Fnv1a::new();
        h.update(names);
        h.update(flags);
        anyhow::ensure!(
            h.digest() == digest,
            "row store word-table checksum mismatch (corrupt or torn file)"
        );
        let mut words = Vec::with_capacity(n);
        let mut pos = 0usize;
        for i in 0..n {
            anyhow::ensure!(pos + 2 <= names.len(), "word table truncated at row {i}");
            let len = u16::from_le_bytes(names[pos..pos + 2].try_into().unwrap()) as usize;
            pos += 2;
            anyhow::ensure!(pos + len <= names.len(), "word table truncated at row {i}");
            let w = std::str::from_utf8(&names[pos..pos + len])
                .map_err(|e| anyhow::anyhow!("row {i}: word is not UTF-8 ({e})"))?;
            words.push(w.to_string());
            pos += len;
        }
        anyhow::ensure!(
            pos == names.len(),
            "word table has {} trailing bytes",
            names.len() - pos
        );
        let servable: Vec<bool> = flags.iter().map(|&b| b != 0).collect();
        let index = build_index(&words);
        let data = rows_data(bytes, rows_off, n * dim);
        Ok(Self {
            words,
            index,
            servable,
            dim,
            generation,
            data,
        })
    }

    pub fn n_rows(&self) -> usize {
        self.words.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    pub fn words(&self) -> &[String] {
        &self.words
    }

    /// Row id for `word` (first occurrence on duplicates).
    pub fn id(&self, word: &str) -> Option<u32> {
        self.index.get(word).copied()
    }

    /// May `id` appear in ranked results?  (The build-time
    /// [`row_servable`] verdict.)
    pub fn servable(&self, id: u32) -> bool {
        self.servable[id as usize]
    }

    /// The full packed unit-row payload (`n_rows · dim`).
    pub fn rows(&self) -> &[f32] {
        match &self.data {
            RowsData::Owned(v) => v,
            #[cfg(target_endian = "little")]
            RowsData::Raw { bytes, off, n } => {
                let raw = &bytes[*off..*off + 4 * *n];
                // SAFETY: 4-byte alignment was verified when this
                // variant was constructed (and the backing buffer —
                // mapping or Vec — never moves while borrowed); every
                // bit pattern is a valid f32; the slice lives as long
                // as `self.data` holds `bytes`.
                unsafe { std::slice::from_raw_parts(raw.as_ptr() as *const f32, *n) }
            }
        }
    }

    /// One unit row.
    pub fn row(&self, id: u32) -> &[f32] {
        let d = self.dim;
        &self.rows()[id as usize * d..(id as usize + 1) * d]
    }
}

fn build_index(words: &[String]) -> HashMap<String, u32> {
    let mut index = HashMap::with_capacity(words.len());
    for (i, w) in words.iter().enumerate() {
        index.entry(w.clone()).or_insert(i as u32);
    }
    index
}

/// Borrow the row payload in place when the platform and alignment
/// allow, else parse one owned copy.
fn rows_data(bytes: Bytes, rows_off: usize, n: usize) -> RowsData {
    #[cfg(target_endian = "little")]
    {
        if (bytes.as_ptr() as usize + rows_off) % std::mem::align_of::<f32>() == 0 {
            return RowsData::Raw {
                bytes,
                off: rows_off,
                n,
            };
        }
    }
    let raw = &bytes[rows_off..rows_off + 4 * n];
    let mut v = Vec::with_capacity(n);
    for c in raw.chunks_exact(4) {
        v.push(f32::from_le_bytes(c.try_into().unwrap()));
    }
    RowsData::Owned(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Vec<String>, Embedding) {
        let words: Vec<String> = ["alpha", "beta", "gamma", "dead"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut emb = Embedding::zeros(4, 3);
        emb.row_mut(0).copy_from_slice(&[1.0, 0.0, 0.5]);
        emb.row_mut(1).copy_from_slice(&[-0.25, 2.0, 0.0]);
        emb.row_mut(2).copy_from_slice(&[0.5, 0.5, -1.5]);
        // row 3 stays all-zero: must be flagged unservable.
        (words, emb)
    }

    #[test]
    fn build_normalises_and_flags() {
        let (words, emb) = sample();
        let st = RowStore::from_model(words, &emb).unwrap();
        assert_eq!(st.n_rows(), 4);
        assert_eq!(st.dim(), 3);
        assert_eq!(st.id("beta"), Some(1));
        assert_eq!(st.id("zzz"), None);
        assert!(st.servable(0) && st.servable(1) && st.servable(2));
        assert!(!st.servable(3), "zero row must be unservable");
        // Rows equal the analogy oracle's unit matrix bit for bit.
        let unit = normalized_matrix(&emb);
        assert_eq!(st.rows(), &unit[..]);
    }

    #[test]
    fn save_open_roundtrip_is_bitwise() {
        let (words, emb) = sample();
        let st = RowStore::from_model(words.clone(), &emb).unwrap();
        let path = std::env::temp_dir().join("pw2v_rst_rt.rst");
        st.save(&path).unwrap();
        let got = RowStore::open(&path).unwrap();
        assert_eq!(got.words(), st.words());
        assert_eq!(got.dim(), st.dim());
        for id in 0..4u32 {
            assert_eq!(got.servable(id), st.servable(id));
            let (a, b) = (got.row(id), st.row(id));
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "row {id}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_and_truncation_rejected() {
        let (words, emb) = sample();
        let st = RowStore::from_model(words, &emb).unwrap();
        let path = std::env::temp_dir().join("pw2v_rst_bad.rst");
        st.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();

        // Flipped bit in the word table: checksum catches it.
        let mut flipped = full.clone();
        flipped[HEADER_LEN + 3] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        let err = RowStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unhelpful error: {err}");

        // Truncated payload: size check catches it.
        std::fs::write(&path, &full[..full.len() - 4]).unwrap();
        let err = RowStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("bytes"), "unhelpful error: {err}");

        // Wrong magic.
        let mut wrong = full.clone();
        wrong[..8].copy_from_slice(b"NOTASTOR");
        std::fs::write(&path, &wrong).unwrap();
        let err = RowStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "unhelpful error: {err}");

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn generation_roundtrips() {
        let (words, emb) = sample();
        let mut st = RowStore::from_model(words, &emb).unwrap();
        assert_eq!(st.generation(), 0);
        st.set_generation(42);
        let path = std::env::temp_dir().join("pw2v_rst_gen.rst");
        st.save(&path).unwrap();
        assert_eq!(RowStore::open(&path).unwrap().generation(), 42);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version1_files_open_as_generation_zero() {
        // Hand-rolled v1 file: one word "solo", dim 2, unit row.
        let names: Vec<u8> = [&4u16.to_le_bytes()[..], b"solo"].concat();
        let flags = [1u8];
        let mut h = Fnv1a::new();
        h.update(&names);
        h.update(&flags);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&2u32.to_le_bytes()); // dim
        bytes.extend_from_slice(&1u64.to_le_bytes()); // n_rows
        bytes.extend_from_slice(&(names.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&h.digest().to_le_bytes());
        bytes.extend_from_slice(&names);
        bytes.extend_from_slice(&flags);
        while bytes.len() % ROW_ALIGN != 0 {
            bytes.push(0);
        }
        for x in [0.6f32, 0.8] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let path = std::env::temp_dir().join("pw2v_rst_v1.rst");
        std::fs::write(&path, &bytes).unwrap();
        let st = RowStore::open(&path).unwrap();
        assert_eq!(st.generation(), 0);
        assert_eq!(st.n_rows(), 1);
        assert_eq!(st.id("solo"), Some(0));
        assert_eq!(st.row(0), &[0.6, 0.8]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn duplicate_words_resolve_to_first_id() {
        let words: Vec<String> = ["x", "x", "y"].iter().map(|s| s.to_string()).collect();
        let mut emb = Embedding::zeros(3, 2);
        for id in 0..3u32 {
            emb.row_mut(id).copy_from_slice(&[1.0 + id as f32, -1.0]);
        }
        let st = RowStore::from_model(words, &emb).unwrap();
        assert_eq!(st.id("x"), Some(0));
        assert_eq!(st.id("y"), Some(2));
    }

    #[test]
    fn from_model_rejects_mismatched_shapes() {
        let (_, emb) = sample();
        let words: Vec<String> = vec!["only".to_string()];
        assert!(RowStore::from_model(words, &emb).is_err());
    }
}
