//! Symmetric int8 quantization of the row store (the KNL companion
//! paper's `Wo` trick, applied to the query side): each unit row is
//! stored as `dim` i8 codes plus one f32 scale, cutting scan bandwidth
//! ~4× so million-word vocabs stay cache-resident.
//!
//! Scheme: per-row symmetric, scale `s_r = maxabs(row)/127`, code
//! `c = round(x / s_r)` clamped to ±127.  A query is quantized the same
//! way per request, and the scanned score is
//!
//! ```text
//! score ≈ (s_q · s_r) · <q_codes, r_codes>   (i32 integer dot)
//! ```
//!
//! The integer dot goes through `linalg::simd::dot_i8` (AVX2 `madd` or
//! scalar — exactly equal either way), so the int8 scan's RANKING is
//! dispatch-invariant by construction; its agreement with the f32 scan
//! is a measured quantity, gated at recall@10 ≥ 0.95 in
//! `tests/serve_parity.rs` and accounted in EXPERIMENTS.md §Serving.

use crate::linalg::simd;
use crate::serve::store::MAX_DIM;

/// Int8 codes + per-row scales for a packed `n × dim` row matrix.
pub struct QuantStore {
    dim: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
}

/// Quantize one vector into `out`, returning its scale (`maxabs/127`).
/// All-zero or non-finite input yields scale 0.0 with `out` zeroed, so
/// every score such a vector produces is 0.0.  Non-finiteness is
/// tracked per component: `f32::max` IGNORES a NaN operand, so a NaN
/// hiding among finite values would otherwise slip through the maxabs
/// check.
pub fn quantize_into(v: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(v.len(), out.len());
    let mut maxabs = 0.0f32;
    let mut finite = true;
    for &x in v {
        finite &= x.is_finite();
        maxabs = maxabs.max(x.abs());
    }
    if !finite || maxabs <= 0.0 {
        out.fill(0);
        return 0.0;
    }
    let scale = maxabs / 127.0;
    let inv = 127.0 / maxabs;
    for (o, &x) in out.iter_mut().zip(v) {
        *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

impl QuantStore {
    /// Quantize every row of a packed `n × dim` matrix.
    ///
    /// Dimension bounds are checked HERE, once, with a typed error —
    /// `dim ≤ MAX_DIM` is the i32-overflow contract of the `dot_i8`
    /// scan kernel, which itself only `debug_assert`s it (a panicking
    /// hot-loop assert would take the whole serve process down on a
    /// malformed store instead of failing the one load).
    pub fn build(rows: &[f32], dim: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(
            dim > 0 && dim <= MAX_DIM,
            "quant: dim {dim} outside 1..={MAX_DIM} (int8 dot i32 bound)"
        );
        anyhow::ensure!(
            rows.len() % dim == 0,
            "quant: {} row floats not a multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        let mut codes = vec![0i8; rows.len()];
        let mut scales = vec![0.0f32; n];
        for r in 0..n {
            scales[r] = quantize_into(
                &rows[r * dim..(r + 1) * dim],
                &mut codes[r * dim..(r + 1) * dim],
            );
        }
        Ok(Self { dim, codes, scales })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_rows(&self) -> usize {
        self.scales.len()
    }

    /// Codes of one row.
    pub fn row_codes(&self, id: u32) -> &[i8] {
        let d = self.dim;
        &self.codes[id as usize * d..(id as usize + 1) * d]
    }

    /// One row's scale (`maxabs/127`).
    pub fn scale(&self, id: u32) -> f32 {
        self.scales[id as usize]
    }

    /// Approximate dot of a quantized query against row `id`.
    #[inline]
    pub fn score(&self, qcodes: &[i8], qscale: f32, id: u32) -> f32 {
        let acc = simd::dot_i8(qcodes, self.row_codes(id));
        (qscale * self.scales[id as usize]) * acc as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256ss;

    #[test]
    fn quantize_bounds_componentwise_error() {
        let mut rng = Xoshiro256ss::new(0x8B17);
        let d = 96;
        let v: Vec<f32> = (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let mut codes = vec![0i8; d];
        let scale = quantize_into(&v, &mut codes);
        assert!(scale > 0.0);
        for (x, c) in v.iter().zip(&codes) {
            let back = *c as f32 * scale;
            assert!(
                (back - x).abs() <= scale * 0.5 + 1e-7,
                "{x} -> {c} -> {back} (scale {scale})"
            );
        }
        // The max-|x| component hits exactly ±127.
        assert_eq!(codes.iter().map(|c| c.unsigned_abs()).max(), Some(127));
    }

    #[test]
    fn zero_and_nonfinite_vectors_quantize_to_zero() {
        let mut codes = vec![7i8; 4];
        assert_eq!(quantize_into(&[0.0; 4], &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c == 0));
        let mut codes = vec![7i8; 2];
        assert_eq!(quantize_into(&[f32::NAN, 1.0], &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    /// Over-bound or misaligned geometry is a checked error, not a
    /// panic — the serve engine surfaces it per load/swap.
    #[test]
    fn build_rejects_bad_geometry_with_typed_error() {
        let err = QuantStore::build(&[0.0; 8], 0).unwrap_err();
        assert!(err.to_string().contains("dim 0"), "{err}");
        let err = QuantStore::build(&[0.0; 7], 4).unwrap_err();
        assert!(err.to_string().contains("multiple of dim"), "{err}");
        // One past the int8-dot i32 bound (geometry check only — no
        // MAX_DIM-sized allocation needed to trip it).
        let err = QuantStore::build(&[], MAX_DIM + 1).unwrap_err();
        assert!(err.to_string().contains("int8 dot"), "{err}");
        assert!(QuantStore::build(&[0.25; 8], 4).is_ok());
    }

    #[test]
    fn quantized_dot_tracks_f32_dot() {
        let mut rng = Xoshiro256ss::new(0xD07_5EED);
        let (n, d) = (32usize, 64usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
        let qs = QuantStore::build(&rows, d).unwrap();
        assert_eq!(qs.n_rows(), n);
        let q: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
        let mut qcodes = vec![0i8; d];
        let qscale = quantize_into(&q, &mut qcodes);
        for id in 0..n as u32 {
            let exact: f32 = q
                .iter()
                .zip(&rows[id as usize * d..(id as usize + 1) * d])
                .map(|(a, b)| a * b)
                .sum();
            let approx = qs.score(&qcodes, qscale, id);
            // Per-component error ≤ (s_q/2)·|r_i| + (s_r/2)·|q_i| + s_q·s_r/4
            // with |values| ≤ 0.5 here; summed, a loose-but-sound bound is
            // d · (s_q + s_r) / 2.  Enough to catch scheme-level mistakes
            // (wrong scale, sign, clamp) without flaking on rounding.
            let bound = d as f32 * (qscale + qs.scale(id)) * 0.5;
            assert!(
                (approx - exact).abs() <= bound,
                "id {id}: {approx} vs {exact} (bound {bound})"
            );
        }
    }
}
