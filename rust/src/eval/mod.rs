//! Evaluation: word similarity (Spearman ρ against reference judgements,
//! the WS-353 protocol) and word analogy (3CosAdd exact match, the Google
//! analogy-set protocol), plus generation of synthetic test sets with
//! exact ground truth from the latent corpus model (DESIGN.md §3, §6).

pub mod analogy;
pub mod datasets;
pub mod similarity;
pub mod spearman;

pub use analogy::{eval_analogy, AnalogyQuestion, AnalogyReport};
pub use datasets::{
    gen_analogy_set, gen_similarity_set, load_analogy_set, load_similarity_set,
};
pub use similarity::{eval_similarity, SimilarityPair};
pub use spearman::spearman;
