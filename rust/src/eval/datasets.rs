//! Test-set IO + synthetic generation.
//!
//! * Loaders for the STANDARD file formats (WS-353 `word1<TAB>word2<TAB>score`
//!   with optional header, and `questions-words.txt` with `: section`
//!   headers) so real datasets drop in if the user supplies them.
//! * Generators that build equivalent sets from the latent ground-truth
//!   model (DESIGN.md §3): similarity pairs scored by exact latent cosine,
//!   analogy questions from the planted relation pairs.

use std::path::Path;

use super::analogy::AnalogyQuestion;
use super::similarity::SimilarityPair;
use crate::corpus::synthetic::LatentModel;
use crate::util::rng::Xoshiro256ss;

/// Load a WS-353-style TSV (`word1 word2 score`, tab- or comma-separated;
/// lines failing to parse a score are treated as headers and skipped).
pub fn load_similarity_set<P: AsRef<Path>>(path: P) -> anyhow::Result<Vec<SimilarityPair>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines() {
        let fields: Vec<&str> = line
            .split(|c| c == '\t' || c == ',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        if fields.len() < 3 {
            continue;
        }
        if let Ok(score) = fields[2].parse::<f64>() {
            out.push(SimilarityPair {
                a: fields[0].to_lowercase(),
                b: fields[1].to_lowercase(),
                score,
            });
        }
    }
    anyhow::ensure!(!out.is_empty(), "no similarity pairs parsed");
    Ok(out)
}

/// Load a Google-format analogy file (`: section` headers, then
/// `a b c d` lines).
pub fn load_analogy_set<P: AsRef<Path>>(path: P) -> anyhow::Result<Vec<AnalogyQuestion>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    let mut section = "default".to_string();
    for line in text.lines() {
        if let Some(s) = line.strip_prefix(':') {
            section = s.trim().to_string();
            continue;
        }
        let f: Vec<&str> = line.split_ascii_whitespace().collect();
        if f.len() == 4 {
            out.push(AnalogyQuestion {
                a: f[0].to_lowercase(),
                b: f[1].to_lowercase(),
                c: f[2].to_lowercase(),
                d: f[3].to_lowercase(),
                section: section.clone(),
            });
        }
    }
    anyhow::ensure!(!out.is_empty(), "no analogy questions parsed");
    Ok(out)
}

/// Save helpers (round-trip the standard formats).
pub fn save_similarity_set<P: AsRef<Path>>(
    path: P,
    pairs: &[SimilarityPair],
) -> anyhow::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "Word 1\tWord 2\tHuman (mean)")?;
    for p in pairs {
        writeln!(w, "{}\t{}\t{}", p.a, p.b, p.score)?;
    }
    Ok(())
}

pub fn save_analogy_set<P: AsRef<Path>>(
    path: P,
    questions: &[AnalogyQuestion],
) -> anyhow::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    let mut cur = String::new();
    for q in questions {
        if q.section != cur {
            writeln!(w, ": {}", q.section)?;
            cur = q.section.clone();
        }
        writeln!(w, "{} {} {} {}", q.a, q.b, q.c, q.d)?;
    }
    Ok(())
}

/// Generate a WS-353-like pair set from the latent model: `n` pairs
/// stratified across the similarity range, scored 0..10 by exact latent
/// cosine.
pub fn gen_similarity_set(lm: &LatentModel, n: usize, seed: u64) -> Vec<SimilarityPair> {
    let mut rng = Xoshiro256ss::new(seed);
    let v = lm.cfg.vocab;
    // Stratify: half same-cluster pairs (high similarity), half random.
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let a = rng.below(v) as u32;
        let want_same = out.len() % 2 == 0;
        let mut b = rng.below(v) as u32;
        if want_same {
            // Find a same-cluster partner.
            let target = lm.cluster_of[a as usize];
            let mut tries = 0;
            while (lm.cluster_of[b as usize] != target || b == a) && tries < 200 {
                b = rng.below(v) as u32;
                tries += 1;
            }
        }
        if a == b {
            continue;
        }
        let cos = lm.similarity(a, b) as f64;
        out.push(SimilarityPair {
            a: lm.token(a),
            b: lm.token(b),
            // Map [-1,1] -> [0,10] like human judgement scales.
            score: (cos + 1.0) * 5.0,
        });
    }
    out
}

/// Generate the analogy question set from planted relations: all ordered
/// pairs-of-pairs within each relation, like the Google set's structure.
pub fn gen_analogy_set(lm: &LatentModel) -> Vec<AnalogyQuestion> {
    let mut out = Vec::new();
    for (ri, rel) in lm.relations.iter().enumerate() {
        let section = format!("relation-{ri}");
        for (i, &(a, b)) in rel.pairs.iter().enumerate() {
            for (j, &(c, d)) in rel.pairs.iter().enumerate() {
                if i == j {
                    continue;
                }
                out.push(AnalogyQuestion {
                    a: lm.token(a),
                    b: lm.token(b),
                    c: lm.token(c),
                    d: lm.token(d),
                    section: section.clone(),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::SyntheticConfig;

    fn lm() -> LatentModel {
        LatentModel::new(SyntheticConfig::test_tiny())
    }

    #[test]
    fn similarity_set_properties() {
        let m = lm();
        let set = gen_similarity_set(&m, 100, 1);
        assert_eq!(set.len(), 100);
        for p in &set {
            assert!(p.a != p.b);
            assert!((0.0..=10.0).contains(&p.score));
        }
        // Stratification gives a spread of scores.
        let max = set.iter().map(|p| p.score).fold(0.0, f64::max);
        let min = set.iter().map(|p| p.score).fold(10.0, f64::min);
        assert!(max - min > 2.0, "degenerate spread {min}..{max}");
    }

    #[test]
    fn analogy_set_from_relations() {
        let m = lm();
        let qs = gen_analogy_set(&m);
        let p = m.cfg.pairs_per_relation;
        assert_eq!(qs.len(), m.cfg.relations * p * (p - 1));
        // All questions reference planted pairs.
        for q in &qs {
            assert_ne!(q.a, q.c);
        }
    }

    #[test]
    fn similarity_roundtrip() {
        let m = lm();
        let set = gen_similarity_set(&m, 20, 2);
        let path = std::env::temp_dir().join("pw2v_simset_test.tsv");
        save_similarity_set(&path, &set).unwrap();
        let got = load_similarity_set(&path).unwrap();
        assert_eq!(got.len(), set.len());
        assert_eq!(got[0].a, set[0].a);
        assert!((got[0].score - set[0].score).abs() < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn analogy_roundtrip() {
        let m = lm();
        let qs = gen_analogy_set(&m);
        let path = std::env::temp_dir().join("pw2v_anaset_test.txt");
        save_analogy_set(&path, &qs).unwrap();
        let got = load_analogy_set(&path).unwrap();
        assert_eq!(got.len(), qs.len());
        assert_eq!(got[0], qs[0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ws353_header_skipped() {
        let path = std::env::temp_dir().join("pw2v_ws_test.tsv");
        std::fs::write(&path, "Word 1\tWord 2\tHuman (mean)\ncat\tdog\t7.5\n")
            .unwrap();
        let got = load_similarity_set(&path).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].a, "cat");
        std::fs::remove_file(&path).ok();
    }
}
