//! Spearman rank correlation coefficient with average-rank tie handling —
//! the WS-353 scoring statistic.

/// Spearman ρ of two equal-length samples.  Returns `None` for length < 2
/// or zero-variance inputs.
pub fn spearman(a: &[f64], b: &[f64]) -> Option<f64> {
    if a.len() != b.len() || a.len() < 2 {
        return None;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

/// Average ranks (1-based; ties share the mean of their positions).
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

fn pearson(a: &[f64], b: &[f64]) -> Option<f64> {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut num, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (x, y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va <= 0.0 || vb <= 0.0 {
        return None;
    }
    Some(num / (va * vb).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_correlation() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_anticorrelation() {
        let a = [1.0, 2.0, 3.0];
        let b = [3.0, 2.0, 1.0];
        assert!((spearman(&a, &b).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_based_not_linear() {
        // Monotone nonlinear map preserves ρ = 1.
        let a = [1.0f64, 2.0, 3.0, 4.0, 5.0];
        let b: Vec<f64> = a.iter().map(|x| x.exp()).collect();
        assert!((spearman(&a, &b).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ties_averaged() {
        let a = [1.0, 1.0, 2.0];
        let r = ranks(&a);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn known_value_with_ties() {
        // Hand-computed example.
        let a = [1.0, 2.0, 2.0, 3.0];
        let b = [1.0, 3.0, 2.0, 4.0];
        let rho = spearman(&a, &b).unwrap();
        assert!(rho > 0.8 && rho < 1.0, "rho={rho}");
    }

    #[test]
    fn degenerate_cases() {
        assert!(spearman(&[1.0], &[2.0]).is_none());
        assert!(spearman(&[1.0, 1.0], &[1.0, 2.0]).is_none()); // zero variance
        assert!(spearman(&[1.0, 2.0], &[1.0]).is_none()); // length mismatch
    }

    #[test]
    fn noisy_positive_correlation() {
        let mut rng = crate::util::rng::Xoshiro256ss::new(1);
        let a: Vec<f64> = (0..500).map(|i| i as f64).collect();
        let b: Vec<f64> = a
            .iter()
            .map(|x| x + 100.0 * rng.next_gauss())
            .collect();
        let rho = spearman(&a, &b).unwrap();
        assert!(rho > 0.5 && rho < 1.0, "rho={rho}");
    }
}
