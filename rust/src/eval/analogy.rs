//! Word-analogy evaluation (the Google analogy-set protocol, paper
//! Sec. IV-A): for each question `a:b :: c:?` predict the vocabulary word
//! maximising 3CosAdd over unit vectors, excluding the three query words;
//! a question counts only on EXACT match.

use crate::corpus::vocab::Vocab;
use crate::model::Embedding;

#[derive(Clone, Debug, PartialEq)]
pub struct AnalogyQuestion {
    pub a: String,
    pub b: String,
    pub c: String,
    pub d: String,
    /// Section label ("semantic" / "syntactic" / custom relation id).
    pub section: String,
}

#[derive(Clone, Debug, Default)]
pub struct AnalogyReport {
    pub total: usize,
    /// Questions with all four words in vocabulary.
    pub covered: usize,
    pub correct: usize,
}

impl AnalogyReport {
    /// Accuracy ×100 over covered questions (the paper's metric).
    pub fn accuracy100(&self) -> f64 {
        if self.covered == 0 {
            0.0
        } else {
            self.correct as f64 / self.covered as f64 * 100.0
        }
    }
}

/// Unit-normalised copy of the whole matrix (query once, reuse per set).
pub fn normalized_matrix(emb: &Embedding) -> Vec<f32> {
    let (v, d) = (emb.vocab(), emb.dim());
    let mut out = vec![0.0f32; v * d];
    for w in 0..v as u32 {
        let row = emb.row(w);
        let n = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
        for (o, x) in out[w as usize * d..(w as usize + 1) * d]
            .iter_mut()
            .zip(row)
        {
            *o = x / n;
        }
    }
    out
}

/// Evaluate a question set; returns per-section reports plus the overall.
pub fn eval_analogy(
    questions: &[AnalogyQuestion],
    vocab: &Vocab,
    emb: &Embedding,
) -> AnalogyReport {
    let d = emb.dim();
    let v = emb.vocab();
    let unit = normalized_matrix(emb);
    let mut report = AnalogyReport {
        total: questions.len(),
        ..Default::default()
    };
    let mut query = vec![0.0f32; d];
    for q in questions {
        let ids = (
            vocab.id(&q.a),
            vocab.id(&q.b),
            vocab.id(&q.c),
            vocab.id(&q.d),
        );
        let (Some(ia), Some(ib), Some(ic), Some(id_)) = ids else {
            continue;
        };
        report.covered += 1;
        // 3CosAdd: argmax_w cos(w, b - a + c) over unit vectors.
        let (ua, ub, uc) = (
            &unit[ia as usize * d..(ia as usize + 1) * d],
            &unit[ib as usize * d..(ib as usize + 1) * d],
            &unit[ic as usize * d..(ic as usize + 1) * d],
        );
        for l in 0..d {
            query[l] = ub[l] - ua[l] + uc[l];
        }
        let mut best = f32::NEG_INFINITY;
        let mut best_w = u32::MAX;
        for w in 0..v as u32 {
            if w == ia || w == ib || w == ic {
                continue;
            }
            let row = &unit[w as usize * d..(w as usize + 1) * d];
            let score: f32 = crate::linalg::dot(row, &query);
            if score > best {
                best = score;
                best_w = w;
            }
        }
        if best_w == id_ {
            report.correct += 1;
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Construct an embedding with exact linear analogy structure.
    fn planted() -> (Vocab, Embedding, Vec<AnalogyQuestion>) {
        // words: king queen man woman + distractors x y
        let vocab = Vocab::build(
            "king king king queen queen man man woman x y".split_whitespace(),
            1,
        );
        let mut emb = Embedding::zeros(vocab.len(), 3);
        let set = |e: &mut Embedding, w: &str, v: [f32; 3], vc: &Vocab| {
            e.row_mut(vc.id(w).unwrap()).copy_from_slice(&v);
        };
        // queen = king + royal_offset; woman = man + same structure
        set(&mut emb, "king", [1.0, 0.0, 1.0], &vocab);
        set(&mut emb, "queen", [1.0, 1.0, 1.0], &vocab);
        set(&mut emb, "man", [1.0, 0.0, -1.0], &vocab);
        set(&mut emb, "woman", [1.0, 1.0, -1.0], &vocab);
        set(&mut emb, "x", [-1.0, -1.0, 0.0], &vocab);
        set(&mut emb, "y", [-1.0, 0.5, -0.5], &vocab);
        let q = vec![AnalogyQuestion {
            a: "king".into(),
            b: "queen".into(),
            c: "man".into(),
            d: "woman".into(),
            section: "semantic".into(),
        }];
        (vocab, emb, q)
    }

    #[test]
    fn planted_analogy_answered() {
        let (vocab, emb, q) = planted();
        let r = eval_analogy(&q, &vocab, &emb);
        assert_eq!(r.covered, 1);
        assert_eq!(r.correct, 1);
        assert_eq!(r.accuracy100(), 100.0);
    }

    #[test]
    fn query_words_excluded() {
        // Without exclusion, "queen" itself would win the argmax (it is
        // closest to b - a + c in this geometry for b itself).
        let (vocab, emb, _) = planted();
        let q = vec![AnalogyQuestion {
            a: "man".into(),
            b: "woman".into(),
            c: "king".into(),
            d: "queen".into(),
            section: "semantic".into(),
        }];
        let r = eval_analogy(&q, &vocab, &emb);
        assert_eq!(r.correct, 1);
    }

    #[test]
    fn oov_questions_uncovered() {
        let (vocab, emb, mut q) = planted();
        q.push(AnalogyQuestion {
            a: "king".into(),
            b: "zzz".into(),
            c: "man".into(),
            d: "woman".into(),
            section: "semantic".into(),
        });
        let r = eval_analogy(&q, &vocab, &emb);
        assert_eq!(r.total, 2);
        assert_eq!(r.covered, 1);
    }

    #[test]
    fn wrong_geometry_scores_zero() {
        let (vocab, mut emb, q) = planted();
        // Scramble woman's vector: the answer should now be wrong.
        emb.row_mut(vocab.id("woman").unwrap())
            .copy_from_slice(&[-5.0, -5.0, 5.0]);
        let r = eval_analogy(&q, &vocab, &emb);
        assert_eq!(r.correct, 0);
    }
}
