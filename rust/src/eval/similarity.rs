//! Word-similarity evaluation (the WS-353 protocol, paper Sec. IV-A):
//! rank word pairs by model cosine similarity and report Spearman ρ
//! against the reference judgements.

use super::spearman::spearman;
use crate::corpus::vocab::Vocab;
use crate::model::Embedding;

/// A test pair: two words and a reference similarity judgement.
#[derive(Clone, Debug, PartialEq)]
pub struct SimilarityPair {
    pub a: String,
    pub b: String,
    pub score: f64,
}

/// Result: Spearman ρ (×100, as the paper reports) and coverage.
#[derive(Clone, Copy, Debug)]
pub struct SimilarityReport {
    /// Spearman ρ × 100 over the covered pairs.
    pub rho100: f64,
    pub pairs_total: usize,
    pub pairs_covered: usize,
}

/// Cosine of two rows.
///
/// A zero-norm operand yields 0.0 — the neutral score the WS-353
/// protocol wants for untrained rows (pinned by `cosine_basics`).  That
/// convention is WRONG for a top-k scan: 0.0 ranks a padded/dead row
/// ABOVE every genuinely negative match.  Ranked scans must therefore
/// filter candidates through [`row_servable`] first; the serve engine
/// does exactly that and documents the policy in its wire format.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut num, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        num += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    if na <= 0.0 || nb <= 0.0 {
        0.0
    } else {
        num / (na.sqrt() * nb.sqrt())
    }
}

/// The serve scan's candidate policy: a row participates in ranked
/// top-k results only if it is non-degenerate — every component finite
/// and at least one non-zero.  Zero-norm rows (never-touched vocab
/// slots, padding) and rows poisoned by a non-finite value are
/// EXCLUDED from scans rather than scored: `cosine`'s 0.0 convention
/// would rank them above true negative matches, and NaN would poison
/// the ordering entirely.  Deterministic: depends only on the row
/// bytes.  `eval_similarity` intentionally does NOT apply this filter —
/// its neutral-zero behaviour is part of the WS-353 protocol.
pub fn row_servable(row: &[f32]) -> bool {
    let mut any_nonzero = false;
    for &x in row {
        if !x.is_finite() {
            return false;
        }
        any_nonzero |= x != 0.0;
    }
    any_nonzero
}

/// Evaluate `M_in` embeddings on a pair set; OOV pairs are skipped (the
/// standard protocol).
pub fn eval_similarity(
    pairs: &[SimilarityPair],
    vocab: &Vocab,
    emb: &Embedding,
) -> SimilarityReport {
    let mut model_scores = Vec::new();
    let mut ref_scores = Vec::new();
    for p in pairs {
        if let (Some(ia), Some(ib)) = (vocab.id(&p.a), vocab.id(&p.b)) {
            model_scores.push(cosine(emb.row(ia), emb.row(ib)));
            ref_scores.push(p.score);
        }
    }
    let rho = spearman(&model_scores, &ref_scores).unwrap_or(0.0);
    SimilarityReport {
        rho100: rho * 100.0,
        pairs_total: pairs.len(),
        pairs_covered: model_scores.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab4() -> Vocab {
        Vocab::build("a a a a b b b c c d".split_whitespace(), 1)
    }

    fn emb4() -> Embedding {
        // a=[1,0], b=[0.9,0.1], c=[0,1], d=[-1,0]
        let mut e = Embedding::zeros(4, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[0.9, 0.1]);
        e.row_mut(2).copy_from_slice(&[0.0, 1.0]);
        e.row_mut(3).copy_from_slice(&[-1.0, 0.0]);
        e
    }

    fn pair(a: &str, b: &str, s: f64) -> SimilarityPair {
        SimilarityPair {
            a: a.into(),
            b: b.into(),
            score: s,
        }
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        // Zero-norm convention: neutral 0.0 — NOT an error, NOT skipped.
        // `eval_similarity` depends on this; ranked scans must use
        // `row_servable` instead (see that function's doc).
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }

    #[test]
    fn row_servable_excludes_degenerate_rows_only() {
        assert!(row_servable(&[1.0, 0.0]));
        assert!(row_servable(&[-0.25, 1e-30]));
        assert!(!row_servable(&[0.0, 0.0]), "zero-norm row must be excluded");
        assert!(!row_servable(&[]), "empty row has zero norm");
        assert!(!row_servable(&[1.0, f32::NAN]));
        assert!(!row_servable(&[f32::INFINITY, 1.0]));
        assert!(!row_servable(&[1.0, f32::NEG_INFINITY]));
    }

    #[test]
    fn eval_similarity_zero_norm_behaviour_unchanged_by_serve_policy() {
        // A vocab slot that training never touched scores 0.0 against
        // everything and the pair still COUNTS — the serve-side
        // exclusion policy must not leak into the offline protocol.
        let vocab = Vocab::build("a a a b b z".split_whitespace(), 1);
        let mut e = Embedding::zeros(3, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[0.9, 0.1]);
        // row 2 ("z") stays all-zero.
        let pairs = vec![pair("a", "b", 9.0), pair("a", "z", 1.0)];
        let r = eval_similarity(&pairs, &vocab, &e);
        assert_eq!(r.pairs_covered, 2, "zero-norm pair must still be covered");
        assert!((r.rho100 - 100.0).abs() < 1e-9, "rho={}", r.rho100);
    }

    #[test]
    fn agreeing_judgements_score_high() {
        let pairs = vec![
            pair("a", "b", 9.0), // cos ~0.99
            pair("a", "c", 5.0), // cos 0
            pair("a", "d", 1.0), // cos -1
        ];
        let r = eval_similarity(&pairs, &vocab4(), &emb4());
        assert!((r.rho100 - 100.0).abs() < 1e-9, "rho={}", r.rho100);
        assert_eq!(r.pairs_covered, 3);
    }

    #[test]
    fn inverted_judgements_score_low() {
        let pairs = vec![
            pair("a", "b", 1.0),
            pair("a", "c", 5.0),
            pair("a", "d", 9.0),
        ];
        let r = eval_similarity(&pairs, &vocab4(), &emb4());
        assert!((r.rho100 + 100.0).abs() < 1e-9);
    }

    #[test]
    fn oov_pairs_skipped() {
        let pairs = vec![pair("a", "b", 9.0), pair("a", "zzz", 5.0), pair("a", "d", 1.0)];
        let r = eval_similarity(&pairs, &vocab4(), &emb4());
        assert_eq!(r.pairs_total, 3);
        assert_eq!(r.pairs_covered, 2);
    }
}
