//! Bench harness (criterion substitute; DESIGN.md §3): timing statistics,
//! aligned table printing matched to the paper's table/figure layouts, CSV
//! emission under `bench_results/`, and the shared synthetic workload
//! cache used by every bench binary.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::corpus::synthetic::{LatentModel, SyntheticConfig};
use crate::corpus::vocab::Vocab;
use crate::util::csv::CsvWriter;

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

/// Time `f` for `iters` iterations after `warmup` throwaway runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Stats {
        iters: n,
        min: samples[0],
        median: samples[n / 2],
        mean: samples.iter().sum::<f64>() / n as f64,
        max: samples[n - 1],
    }
}

/// Median-time ratio `slow/fast` between two measurements (the bench
/// tables' speedup column; >1 means `fast` wins).
pub fn speedup(fast: &Stats, slow: &Stats) -> f64 {
    slow.median / fast.median.max(1e-12)
}

/// An aligned results table that also lands in `bench_results/*.csv`.
pub struct BenchTable {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl BenchTable {
    pub fn new(name: &str, header: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.header.len(), "row arity");
        self.rows.push(fields);
    }

    /// Print aligned to stdout and write `bench_results/<name>.csv`.
    pub fn finish(self) -> anyhow::Result<()> {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, f) in r.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        println!("\n== {} ==", self.name);
        let fmt_row = |fields: &[String]| {
            fields
                .iter()
                .enumerate()
                .map(|(i, f)| format!("{:<w$}", f, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
        let path = Path::new("bench_results").join(format!("{}.csv", self.name));
        let headers: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        let mut csv = CsvWriter::create(&path, &headers)?;
        for r in &self.rows {
            csv.row(r)?;
        }
        csv.flush()?;
        println!("(csv: {})", path.display());
        Ok(())
    }
}

/// A cached synthetic workload: corpus file + vocab + latent ground truth.
pub struct Workload {
    pub corpus: PathBuf,
    pub vocab: Vocab,
    pub latent: LatentModel,
}

/// Generate (or reuse from `bench_data/`) the corpus for `cfg`.
pub fn workload(cfg: SyntheticConfig) -> anyhow::Result<Workload> {
    std::fs::create_dir_all("bench_data")?;
    let path = PathBuf::from(format!(
        "bench_data/corpus_v{}_t{}_c{}_s{}.txt",
        cfg.vocab, cfg.tokens, cfg.clusters, cfg.seed
    ));
    let latent = LatentModel::new(cfg);
    if !path.exists() {
        eprintln!("generating workload {} ...", path.display());
        latent.write_corpus(&path)?;
    }
    let vocab = Vocab::build_from_file(&path, 1)?;
    Ok(Workload {
        corpus: path,
        vocab,
        latent,
    })
}

/// The standard bench corpus (stands in for the 1B-word benchmark at this
/// box's scale): Zipf vocabulary ~20K retained words, 2M tokens.
pub fn standard_workload() -> anyhow::Result<Workload> {
    workload(SyntheticConfig {
        vocab: 20_000,
        tokens: 2_000_000,
        clusters: 50,
        ..SyntheticConfig::default()
    })
}

/// A smaller corpus for convergence-heavy (accuracy) benches.
pub fn accuracy_workload(seed: u64) -> anyhow::Result<Workload> {
    workload(SyntheticConfig {
        vocab: 8_000,
        tokens: 1_200_000,
        clusters: 40,
        beta: 5.0,
        seed,
        ..SyntheticConfig::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_ordered_stats() {
        let mut x = 0u64;
        let s = time(1, 5, || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
        std::hint::black_box(x);
    }

    #[test]
    fn speedup_is_median_ratio() {
        let fast = Stats { iters: 1, min: 1.0, median: 2.0, mean: 2.0, max: 3.0 };
        let slow = Stats { iters: 1, min: 3.0, median: 5.0, mean: 5.0, max: 7.0 };
        assert!((speedup(&fast, &slow) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = BenchTable::new("pw2v_test_table", &["a", "b"]);
        t.row(vec!["x".into(), "1".into()]);
        t.finish().unwrap();
        let csv = std::fs::read_to_string("bench_results/pw2v_test_table.csv")
            .unwrap();
        assert!(csv.contains("x,1"));
        std::fs::remove_file("bench_results/pw2v_test_table.csv").ok();
    }
}
