//! Bench harness (criterion substitute; DESIGN.md §3): timing statistics,
//! aligned table printing matched to the paper's table/figure layouts, CSV
//! emission under `bench_results/`, and the shared synthetic workload
//! cache used by every bench binary.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::corpus::synthetic::{LatentModel, SyntheticConfig};
use crate::corpus::vocab::Vocab;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

/// Time `f` for `iters` iterations after `warmup` throwaway runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Stats {
        iters: n,
        min: samples[0],
        median: samples[n / 2],
        mean: samples.iter().sum::<f64>() / n as f64,
        max: samples[n - 1],
    }
}

/// Median-time ratio `slow/fast` between two measurements (the bench
/// tables' speedup column; >1 means `fast` wins).
pub fn speedup(fast: &Stats, slow: &Stats) -> f64 {
    slow.median / fast.median.max(1e-12)
}

/// An aligned results table that also lands in `bench_results/*.csv`.
pub struct BenchTable {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl BenchTable {
    pub fn new(name: &str, header: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.header.len(), "row arity");
        self.rows.push(fields);
    }

    /// Print aligned to stdout and write `bench_results/<name>.csv`.
    pub fn finish(self) -> anyhow::Result<()> {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, f) in r.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        println!("\n== {} ==", self.name);
        let fmt_row = |fields: &[String]| {
            fields
                .iter()
                .enumerate()
                .map(|(i, f)| format!("{:<w$}", f, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
        let path = Path::new("bench_results").join(format!("{}.csv", self.name));
        let headers: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        let mut csv = CsvWriter::create(&path, &headers)?;
        for r in &self.rows {
            csv.row(r)?;
        }
        csv.flush()?;
        println!("(csv: {})", path.display());
        Ok(())
    }
}

/// Merge-updating writer for the machine-readable perf trajectory:
/// `BENCH_throughput.json` at the repo root.
///
/// Each bench harness invoked with `--json` replaces only its OWN
/// top-level sections, so `microbench` (kernel GFLOP/s, fused-vs-gemm3
/// window ablation) and `fig3_thread_scaling` (trainer words/sec per
/// backend × kernel × threads) accumulate into one file that later PRs
/// diff against.
pub struct ThroughputReport {
    path: PathBuf,
    sections: BTreeMap<String, Json>,
}

impl ThroughputReport {
    /// Open (or create) the report at `path`, keeping existing sections.
    ///
    /// An existing file that fails to parse is NOT silently discarded —
    /// the trajectory is the whole point of the file — it is preserved as
    /// `<path>.bak` with a loud warning before this run starts fresh.
    pub fn at(path: PathBuf) -> Self {
        let mut sections = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            match Json::parse(&text) {
                Ok(Json::Obj(m)) => sections = m,
                Ok(_) | Err(_) => {
                    let bak = path.with_extension("json.bak");
                    eprintln!(
                        "WARNING: {} exists but is not a JSON object; \
                         preserving it as {} and starting fresh",
                        path.display(),
                        bak.display()
                    );
                    let _ = std::fs::copy(&path, &bak);
                }
            }
        }
        Self { path, sections }
    }

    /// Open the report at the repo root: the nearest ancestor of the
    /// current directory holding `ROADMAP.md` (benches run from `rust/`,
    /// the trajectory file lives one level up), else the current
    /// directory.
    pub fn open_at_repo_root() -> Self {
        let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        let root = cwd
            .ancestors()
            .take(5)
            .find(|dir| dir.join("ROADMAP.md").exists())
            .unwrap_or(cwd.as_path())
            .to_path_buf();
        Self::at(root.join("BENCH_throughput.json"))
    }

    /// Replace one top-level section.
    pub fn set(&mut self, section: &str, value: Json) {
        self.sections.insert(section.to_string(), value);
    }

    /// Write the merged report back to disk.
    pub fn save(&mut self) -> anyhow::Result<()> {
        self.sections.insert("schema".to_string(), Json::Num(1.0));
        let text = Json::Obj(self.sections.clone()).to_string();
        std::fs::write(&self.path, text + "\n")?;
        println!("(json: {})", self.path.display());
        Ok(())
    }
}

/// A cached synthetic workload: corpus file + vocab + latent ground truth.
pub struct Workload {
    pub corpus: PathBuf,
    pub vocab: Vocab,
    pub latent: LatentModel,
}

/// Generate (or reuse from `bench_data/`) the corpus for `cfg`.
pub fn workload(cfg: SyntheticConfig) -> anyhow::Result<Workload> {
    std::fs::create_dir_all("bench_data")?;
    let path = PathBuf::from(format!(
        "bench_data/corpus_v{}_t{}_c{}_s{}.txt",
        cfg.vocab, cfg.tokens, cfg.clusters, cfg.seed
    ));
    let latent = LatentModel::new(cfg);
    if !path.exists() {
        eprintln!("generating workload {} ...", path.display());
        latent.write_corpus(&path)?;
    }
    let vocab = Vocab::build_from_file(&path, 1)?;
    Ok(Workload {
        corpus: path,
        vocab,
        latent,
    })
}

/// The standard bench corpus (stands in for the 1B-word benchmark at this
/// box's scale): Zipf vocabulary ~20K retained words, 2M tokens.
pub fn standard_workload() -> anyhow::Result<Workload> {
    workload(SyntheticConfig {
        vocab: 20_000,
        tokens: 2_000_000,
        clusters: 50,
        ..SyntheticConfig::default()
    })
}

/// A smaller corpus for convergence-heavy (accuracy) benches.
pub fn accuracy_workload(seed: u64) -> anyhow::Result<Workload> {
    workload(SyntheticConfig {
        vocab: 8_000,
        tokens: 1_200_000,
        clusters: 40,
        beta: 5.0,
        seed,
        ..SyntheticConfig::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_ordered_stats() {
        let mut x = 0u64;
        let s = time(1, 5, || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
        std::hint::black_box(x);
    }

    #[test]
    fn speedup_is_median_ratio() {
        let fast = Stats { iters: 1, min: 1.0, median: 2.0, mean: 2.0, max: 3.0 };
        let slow = Stats { iters: 1, min: 3.0, median: 5.0, mean: 5.0, max: 7.0 };
        assert!((speedup(&fast, &slow) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn throughput_report_merges_sections() {
        let path = std::env::temp_dir().join(format!(
            "pw2v_throughput_{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        let mut r = ThroughputReport::at(path.clone());
        r.set("alpha", Json::obj([("x", Json::num(1))]));
        r.save().unwrap();
        // A second writer must keep the first writer's section.
        let mut r = ThroughputReport::at(path.clone());
        r.set("beta", Json::num(2));
        r.save().unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("alpha").unwrap().get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("beta").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("schema").unwrap().as_f64(), Some(1.0));
        // A corrupt trajectory file is preserved as .bak, not clobbered.
        std::fs::write(&path, "{not json").unwrap();
        let mut r = ThroughputReport::at(path.clone());
        r.set("gamma", Json::num(3));
        r.save().unwrap();
        let bak = path.with_extension("json.bak");
        assert_eq!(std::fs::read_to_string(&bak).unwrap(), "{not json");
        std::fs::remove_file(&bak).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn table_roundtrip() {
        let mut t = BenchTable::new("pw2v_test_table", &["a", "b"]);
        t.row(vec!["x".into(), "1".into()]);
        t.finish().unwrap();
        let csv = std::fs::read_to_string("bench_results/pw2v_test_table.csv")
            .unwrap();
        assert!(csv.contains("x,1"));
        std::fs::remove_file("bench_results/pw2v_test_table.csv").ok();
    }
}
