//! Streaming corpus reader: whitespace tokens → id sentences.
//!
//! Mirrors the original word2vec's reading discipline: a "sentence" is a
//! newline-delimited line, clipped at [`MAX_SENTENCE_LEN`] tokens;
//! out-of-vocabulary tokens are dropped.  Readers can be restricted to a
//! byte range of the file, which is how both the multi-thread trainer and
//! the distributed sharder partition the corpus (each worker seeks to its
//! range and starts at the next line boundary, as the C code does).

use std::fs::File;
use std::io::{BufRead, BufReader, ErrorKind, Read, Seek, SeekFrom};
use std::path::Path;

use super::vocab::Vocab;

/// The original's MAX_SENTENCE_LENGTH.
pub const MAX_SENTENCE_LEN: usize = 1000;

/// Streaming sentence iterator over a byte range of a tokenized file.
pub struct SentenceReader<'v> {
    reader: BufReader<File>,
    vocab: &'v Vocab,
    /// Read stops once the underlying offset passes this.
    end: u64,
    pos: u64,
    line: String,
    done: bool,
}

impl<'v> SentenceReader<'v> {
    /// Read the whole file.
    pub fn open<P: AsRef<Path>>(path: P, vocab: &'v Vocab) -> anyhow::Result<Self> {
        let len = std::fs::metadata(&path)?.len();
        Self::open_range(path, vocab, 0, len)
    }

    /// Read `[start, end)`; if `start` lands mid-line, skip to the next
    /// line boundary (the partial first line belongs to the previous
    /// shard).  A line that BEGINS exactly at `start` is owned by this
    /// shard and is NOT skipped: the previous shard's reader stops as
    /// soon as its position reaches its `end`, so a line starting on the
    /// boundary would otherwise be read by nobody.  (The original C code
    /// sidesteps the question by seeking without any alignment and eating
    /// a partial first word; our line-aligned discipline needs the
    /// boundary case decided explicitly, and the encoded corpus index
    /// reproduces exactly this rule.)
    pub fn open_range<P: AsRef<Path>>(
        path: P,
        vocab: &'v Vocab,
        start: u64,
        end: u64,
    ) -> anyhow::Result<Self> {
        let mut f = File::open(&path)?;
        if start > 0 {
            // Inspect the byte BEFORE `start`: '\n' means `start` opens a
            // fresh line; anything else means we are mid-line.
            f.seek(SeekFrom::Start(start - 1))?;
        }
        let mut reader = BufReader::with_capacity(1 << 20, f);
        let mut pos = start;
        if start > 0 {
            let mut prev = [0u8; 1];
            let at_boundary = match reader.read_exact(&mut prev) {
                Ok(()) => prev[0] == b'\n',
                // `start` at/past EOF: nothing to skip or read.
                Err(e) if e.kind() == ErrorKind::UnexpectedEof => true,
                Err(e) => return Err(e.into()),
            };
            if !at_boundary {
                let mut skipped = String::new();
                let n = reader.read_line(&mut skipped)?;
                pos += n as u64;
            }
        }
        Ok(Self {
            reader,
            vocab,
            end,
            pos,
            line: String::new(),
            done: false,
        })
    }

    /// Next sentence as vocabulary ids (OOV dropped, clipped). `None` at
    /// end of range.  Empty sentences are skipped.
    pub fn next_sentence(&mut self) -> anyhow::Result<Option<Vec<u32>>> {
        let mut sent = Vec::new();
        Ok(if self.next_sentence_into(&mut sent)? {
            Some(sent)
        } else {
            None
        })
    }

    /// Zero-allocation variant: fill `out` (cleared first) with the next
    /// sentence's ids.  Returns `false` at end of range.  The trainer's
    /// hot loop reuses one buffer across the whole shard.
    pub fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool> {
        Ok(self.next_sentence_into_with_pos(out)?.is_some())
    }

    /// Like [`Self::next_sentence_into`], additionally reporting the byte
    /// offset of the LINE the sentence came from (`None` at end of
    /// range).  The encoded-corpus builder records this offset per
    /// sentence so byte-range sharding of the cache selects exactly the
    /// sentences the text reader would yield for the same range.
    pub fn next_sentence_into_with_pos(
        &mut self,
        out: &mut Vec<u32>,
    ) -> anyhow::Result<Option<u64>> {
        loop {
            if self.done || self.pos >= self.end {
                return Ok(None);
            }
            let line_start = self.pos;
            self.line.clear();
            let n = self.reader.read_line(&mut self.line)?;
            if n == 0 {
                self.done = true;
                return Ok(None);
            }
            self.pos += n as u64;
            out.clear();
            for tok in self.line.split_ascii_whitespace() {
                if let Some(id) = self.vocab.id(tok) {
                    out.push(id);
                    if out.len() >= MAX_SENTENCE_LEN {
                        break;
                    }
                }
            }
            if !out.is_empty() {
                return Ok(Some(line_start));
            }
        }
    }

    /// Drain the remainder of the range into a Vec (tests/small corpora).
    pub fn collect_sentences(mut self) -> anyhow::Result<Vec<Vec<u32>>> {
        let mut out = Vec::new();
        while let Some(s) = self.next_sentence()? {
            out.push(s);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, content: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn reads_sentences_as_ids() {
        let path = write_tmp("pw2v_reader1.txt", "a b c\nb c d\n");
        let vocab = Vocab::build("a b b c c c d".split_whitespace(), 1);
        let r = SentenceReader::open(&path, &vocab).unwrap();
        let sents = r.collect_sentences().unwrap();
        assert_eq!(sents.len(), 2);
        assert_eq!(sents[0].len(), 3);
        // c is most frequent -> id 0; b -> 1; a and d count 1.
        assert_eq!(vocab.word(0), "c");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn drops_oov() {
        let path = write_tmp("pw2v_reader2.txt", "a UNKNOWN b\n");
        let vocab = Vocab::build("a b".split_whitespace(), 1);
        let r = SentenceReader::open(&path, &vocab).unwrap();
        let sents = r.collect_sentences().unwrap();
        assert_eq!(sents[0].len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn skips_empty_lines() {
        let path = write_tmp("pw2v_reader3.txt", "\n\na b\n\n");
        let vocab = Vocab::build("a b".split_whitespace(), 1);
        let sents = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(sents.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ranges_partition_the_file() {
        // Every line must be seen exactly once across disjoint ranges.
        let mut content = String::new();
        for i in 0..100 {
            content.push_str(&format!("w{} w{}\n", i % 7, (i + 1) % 7));
        }
        let path = write_tmp("pw2v_reader4.txt", &content);
        let tokens: Vec<String> =
            (0..7).map(|i| format!("w{i}")).collect();
        let vocab = Vocab::build(tokens.iter().map(|s| s.as_str()), 1);
        let len = std::fs::metadata(&path).unwrap().len();

        let whole = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();

        let mut parts = Vec::new();
        let nshards = 3u64;
        for s in 0..nshards {
            let start = len * s / nshards;
            let end = len * (s + 1) / nshards;
            let got = SentenceReader::open_range(&path, &vocab, start, end)
                .unwrap()
                .collect_sentences()
                .unwrap();
            parts.extend(got);
        }
        assert_eq!(parts.len(), whole.len());
        assert_eq!(parts, whole);
        std::fs::remove_file(&path).ok();
    }

    /// Pin the range-edge rule: a shard whose `start` falls exactly on a
    /// line boundary OWNS that line.  The previous shard's reader stops
    /// once `pos >= end`, so before the fix the boundary line was skipped
    /// by the next shard too and silently dropped from training.
    #[test]
    fn range_starting_on_line_boundary_owns_that_line() {
        let path = write_tmp("pw2v_reader6.txt", "aa\nbb\ncc\n");
        let vocab = Vocab::build(["aa", "bb", "cc"], 1);
        // Lines start at bytes 0, 3, 6; total length 9.
        let first = SentenceReader::open_range(&path, &vocab, 0, 3)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(first.len(), 1, "shard [0,3) is exactly the first line");
        let second = SentenceReader::open_range(&path, &vocab, 3, 9)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(
            second.len(),
            2,
            "start=3 is a line boundary: 'bb' belongs to this shard"
        );
        assert_eq!(second[0], vec![vocab.id("bb").unwrap()]);
        // A start mid-line still cedes the partial line to the previous
        // shard: start=4 is inside "bb\n", so only "cc" remains.
        let mid = SentenceReader::open_range(&path, &vocab, 4, 9)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(mid.len(), 1);
        assert_eq!(mid[0], vec![vocab.id("cc").unwrap()]);
        std::fs::remove_file(&path).ok();
    }

    /// Exhaustive split sweep: EVERY byte split point must partition the
    /// sentence stream exactly (no loss, no duplication) — including the
    /// splits that land on line boundaries, which the pre-fix reader
    /// dropped.
    #[test]
    fn every_split_point_partitions_exactly() {
        let content = "a b\n\ncc\ndd ee a\nb\n";
        let path = write_tmp("pw2v_reader7.txt", content);
        let vocab = Vocab::build(["a", "b", "cc", "dd", "ee"], 1);
        let len = content.len() as u64;
        let whole = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();
        for split in 0..=len {
            let mut parts = SentenceReader::open_range(&path, &vocab, 0, split)
                .unwrap()
                .collect_sentences()
                .unwrap();
            parts.extend(
                SentenceReader::open_range(&path, &vocab, split, len)
                    .unwrap()
                    .collect_sentences()
                    .unwrap(),
            );
            assert_eq!(parts, whole, "split at byte {split}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reports_line_offsets() {
        let path = write_tmp("pw2v_reader8.txt", "a b\n\nZZZ\nb a\n");
        let vocab = Vocab::build(["a", "b"], 1);
        let mut r = SentenceReader::open(&path, &vocab).unwrap();
        let mut sent = Vec::new();
        // First sentence from the line at byte 0; the empty line and the
        // all-OOV line are skipped, so the next comes from byte 9.
        assert_eq!(r.next_sentence_into_with_pos(&mut sent).unwrap(), Some(0));
        assert_eq!(r.next_sentence_into_with_pos(&mut sent).unwrap(), Some(9));
        assert_eq!(r.next_sentence_into_with_pos(&mut sent).unwrap(), None);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn clips_very_long_sentences() {
        let long: String = std::iter::repeat("a ")
            .take(2 * MAX_SENTENCE_LEN)
            .collect();
        let path = write_tmp("pw2v_reader5.txt", &long);
        let vocab = Vocab::build(["a"], 1);
        let sents = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(sents[0].len(), MAX_SENTENCE_LEN);
        std::fs::remove_file(&path).ok();
    }
}
