//! Corpus sharding for data-parallel training (paper Sec. III-E): the
//! training file is partitioned into equal byte ranges, one per worker
//! thread (shared memory) or per node (distributed).  Ranges are aligned
//! to line boundaries by the reader, so every sentence belongs to exactly
//! one shard.

use std::path::Path;

use crate::util::split_point;

/// A byte range `[start, end)` of the corpus file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub start: u64,
    pub end: u64,
}

impl Shard {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// Split a file into `n` equal byte ranges.
pub fn shards_for_file<P: AsRef<Path>>(path: P, n: usize) -> anyhow::Result<Vec<Shard>> {
    let len = std::fs::metadata(&path)?.len();
    Ok(shards_for_len(len, n))
}

/// Split `len` bytes into `n` contiguous ranges differing by at most 1 byte
/// (the repo-wide [`split_point`] rule).
pub fn shards_for_len(len: u64, n: usize) -> Vec<Shard> {
    assert!(n > 0);
    (0..n as u64)
        .map(|i| Shard {
            index: i as usize,
            start: split_point(len, n as u64, i),
            end: split_point(len, n as u64, i + 1),
        })
        .collect()
}

/// Two-level sharding for the distributed trainer: corpus → node shard →
/// per-thread subshards within the node's range.
pub fn subshards(shard: Shard, threads: usize) -> Vec<Shard> {
    assert!(threads > 0);
    let len = shard.len();
    (0..threads as u64)
        .map(|i| Shard {
            index: shard.index * threads + i as usize,
            start: shard.start + split_point(len, threads as u64, i),
            end: shard.start + split_point(len, threads as u64, i + 1),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_whole_range_disjointly() {
        for n in [1usize, 2, 3, 7, 32] {
            let s = shards_for_len(1_000_003, n);
            assert_eq!(s.len(), n);
            assert_eq!(s[0].start, 0);
            assert_eq!(s[n - 1].end, 1_000_003);
            for w in s.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            let total: u64 = s.iter().map(|x| x.len()).sum();
            assert_eq!(total, 1_000_003);
        }
    }

    #[test]
    fn balanced_within_one_byte() {
        let s = shards_for_len(100, 7);
        let min = s.iter().map(|x| x.len()).min().unwrap();
        let max = s.iter().map(|x| x.len()).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn subshards_nest() {
        let node = Shard {
            index: 2,
            start: 100,
            end: 200,
        };
        let subs = subshards(node, 4);
        assert_eq!(subs[0].start, 100);
        assert_eq!(subs[3].end, 200);
        for w in subs.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn empty_file_gives_empty_shards() {
        let s = shards_for_len(0, 4);
        assert!(s.iter().all(|x| x.is_empty()));
    }
}
