//! The ingest seam: one `SentenceSource` contract over the streaming
//! text reader and the pre-encoded `u32` cache, plus the [`Corpus`]
//! handle the trainers open once and range-shard per worker/epoch.
//!
//! Both backends shard over byte ranges OF THE SOURCE TEXT FILE (the
//! cache header records the text length), so `--corpus-cache` never
//! changes which sentences a given worker sees — only how cheaply it
//! reads them.

use std::path::{Path, PathBuf};

use super::encoded::{EncodedCorpus, EncodedSentenceReader};
use super::reader::SentenceReader;
use super::vocab::Vocab;
use crate::config::CorpusCacheMode;

/// The sentence-iteration contract shared by every corpus backend: fill
/// `out` (cleared first) with the next sentence's vocabulary ids, `false`
/// at end of range.  Zero allocations at steady state.
pub trait SentenceSource {
    fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool>;
}

impl SentenceSource for SentenceReader<'_> {
    fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool> {
        SentenceReader::next_sentence_into(self, out)
    }
}

impl SentenceSource for EncodedSentenceReader<'_> {
    fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool> {
        EncodedSentenceReader::next_sentence_into(self, out)
    }
}

/// An opened training corpus: the text file itself, or its encoded
/// cache.  Shared by reference across worker threads; each worker opens
/// its own range cursors (per epoch) through [`Corpus::open_range`].
pub enum Corpus<'v> {
    Text {
        path: PathBuf,
        vocab: &'v Vocab,
        /// File length at open time (shard geometry).
        len: u64,
    },
    Encoded(EncodedCorpus),
}

impl<'v> Corpus<'v> {
    /// Open `path` under the given cache policy.  `Auto`/`Path` build or
    /// rebuild the encoded cache as needed (see [`EncodedCorpus::ensure`]).
    pub fn open(
        path: &Path,
        vocab: &'v Vocab,
        mode: &CorpusCacheMode,
    ) -> anyhow::Result<Self> {
        match mode {
            CorpusCacheMode::Off => Ok(Corpus::Text {
                path: path.to_path_buf(),
                vocab,
                len: std::fs::metadata(path)?.len(),
            }),
            CorpusCacheMode::Auto => {
                let cache = EncodedCorpus::cache_path_for(path);
                let (enc, _) = EncodedCorpus::ensure(path, vocab, &cache)?;
                Ok(Corpus::Encoded(enc))
            }
            CorpusCacheMode::Path(cache) => {
                let (enc, _) = EncodedCorpus::ensure(path, vocab, cache)?;
                Ok(Corpus::Encoded(enc))
            }
        }
    }

    /// Byte length the shard splitter divides: the TEXT file's length on
    /// both backends, so `--corpus-cache` leaves shard geometry (and
    /// therefore every worker's sentence stream) unchanged.
    pub fn shard_len(&self) -> u64 {
        match self {
            Corpus::Text { len, .. } => *len,
            Corpus::Encoded(e) => e.text_len(),
        }
    }

    pub fn is_encoded(&self) -> bool {
        matches!(self, Corpus::Encoded(_))
    }

    /// Cursor over the sentences of text-byte range `[start, end)`.
    pub fn open_range(&self, start: u64, end: u64) -> anyhow::Result<SourceReader<'_>> {
        Ok(match self {
            Corpus::Text { path, vocab, .. } => SourceReader::Text(
                SentenceReader::open_range(path, vocab, start, end)?,
            ),
            Corpus::Encoded(e) => SourceReader::Encoded(e.reader_range(start, end)),
        })
    }
}

/// A range cursor over either backend (the trainers' per-epoch reader).
pub enum SourceReader<'a> {
    Text(SentenceReader<'a>),
    Encoded(EncodedSentenceReader<'a>),
}

impl SourceReader<'_> {
    pub fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool> {
        match self {
            SourceReader::Text(r) => r.next_sentence_into(out),
            SourceReader::Encoded(r) => r.next_sentence_into(out),
        }
    }
}

impl SentenceSource for SourceReader<'_> {
    fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool> {
        SourceReader::next_sentence_into(self, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_tmp(name: &str, content: &str) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("pw2v_src_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn off_streams_text_and_auto_builds_cache() {
        let path = write_tmp("oa.txt", "a b\nb a\n");
        let vocab = Vocab::build(["a", "b"], 1);
        let text = Corpus::open(&path, &vocab, &CorpusCacheMode::Off).unwrap();
        assert!(!text.is_encoded());
        assert_eq!(text.shard_len(), 8);
        let auto = Corpus::open(&path, &vocab, &CorpusCacheMode::Auto).unwrap();
        assert!(auto.is_encoded());
        assert_eq!(auto.shard_len(), 8);
        let cache = EncodedCorpus::cache_path_for(&path);
        assert!(cache.exists());
        // Both cursors yield the same stream through the trait.
        let collect = |c: &Corpus| {
            let mut r = c.open_range(0, 8).unwrap();
            let mut out = Vec::new();
            let mut sent = Vec::new();
            while r.next_sentence_into(&mut sent).unwrap() {
                out.push(sent.clone());
            }
            out
        };
        assert_eq!(collect(&text), collect(&auto));
        assert_eq!(collect(&text).len(), 2);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }
}
