//! Synthetic corpus with **known ground truth** — the substitute for the
//! paper's text8 / One-Billion-Words / 7.2B-word corpora (no network in
//! this environment; DESIGN.md §3, §6).
//!
//! Generative model (distributional-hypothesis by construction):
//!
//! * every word `w` gets a latent unit vector `z_w ∈ R^L`, organised into
//!   `C` clusters; a set of `R` relation offsets plants analogy structure
//!   (`z_b ≈ normalize(z_a + r)` for planted pairs);
//! * unigram frequencies are Zipf(s) (matching real-corpus statistics the
//!   paper's throughput depends on);
//! * each sentence draws a topic cluster, then emits tokens from
//!   `p(w | c) ∝ unigram(w) · exp(β ⟨z_w, center_c⟩)`, mixed with global
//!   unigram noise.
//!
//! Co-occurrence statistics are therefore log-linear in the latent space,
//! which is exactly the structure SGNS factorises (Levy & Goldberg 2014) —
//! so a correct trainer recovers embeddings affinely related to `z`, the
//! planted similarities rank-correlate with model cosines (Table I/II/IV
//! protocol), and planted analogies are answerable by 3CosAdd.

use std::io::Write;
use std::path::Path;

use crate::sampling::alias::AliasTable;
use crate::util::rng::Xoshiro256ss;

/// Parameters of the generative model.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// Vocabulary size V.
    pub vocab: usize,
    /// Tokens to emit.
    pub tokens: u64,
    /// Latent dimension L.
    pub latent_dim: usize,
    /// Number of semantic clusters C.
    pub clusters: usize,
    /// Number of analogy relations R.
    pub relations: usize,
    /// Planted (a, b) pairs per relation.
    pub pairs_per_relation: usize,
    /// Zipf exponent for unigram frequencies.
    pub zipf: f64,
    /// Sharpness of the topical emission distribution.
    pub beta: f64,
    /// Probability of emitting from the global unigram instead of the topic.
    pub noise: f64,
    /// Mean sentence length (geometric, clamped to [5, 70]).
    pub sentence_len: usize,
    /// Cluster dispersion: latent noise added around the cluster center.
    pub sigma: f64,
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        Self {
            vocab: 10_000,
            tokens: 2_000_000,
            latent_dim: 16,
            clusters: 40,
            relations: 6,
            pairs_per_relation: 12,
            zipf: 1.0,
            beta: 4.0,
            noise: 0.25,
            sentence_len: 20,
            sigma: 0.35,
            seed: 1234,
        }
    }
}

impl SyntheticConfig {
    /// Small config for unit tests (fast to generate + train).
    pub fn test_tiny() -> Self {
        Self {
            vocab: 500,
            tokens: 60_000,
            clusters: 10,
            relations: 3,
            pairs_per_relation: 5,
            ..Self::default()
        }
    }
}

/// A planted analogy pair list for one relation: (a, b) with z_b ≈ z_a + r.
#[derive(Clone, Debug)]
pub struct Relation {
    pub pairs: Vec<(u32, u32)>,
}

/// The ground-truth latent model + corpus generator.
pub struct LatentModel {
    pub cfg: SyntheticConfig,
    /// Latent unit vectors, row-major [V, L].
    pub z: Vec<f32>,
    /// Cluster assignment per word.
    pub cluster_of: Vec<u16>,
    /// Zipf unigram weights (unnormalised), per word id (descending).
    pub unigram: Vec<f64>,
    /// Planted analogy relations.
    pub relations: Vec<Relation>,
    /// Per-cluster emission alias tables.
    emit: Vec<AliasTable>,
    /// Global unigram alias table.
    global: AliasTable,
    /// Cluster weights (mass of member words) for topic selection.
    topic: AliasTable,
}

impl LatentModel {
    pub fn new(cfg: SyntheticConfig) -> Self {
        assert!(cfg.vocab >= 10 && cfg.clusters >= 2);
        assert!(cfg.clusters <= u16::MAX as usize);
        let mut rng = Xoshiro256ss::new(cfg.seed);
        let l = cfg.latent_dim;
        let v = cfg.vocab;

        // Cluster centers: random unit vectors.
        let mut centers = vec![0.0f32; cfg.clusters * l];
        for c in 0..cfg.clusters {
            let row = &mut centers[c * l..(c + 1) * l];
            random_unit(row, &mut rng);
        }

        // Word latents: center + sigma * noise, normalised.  Cluster
        // assignment round-robins over ranks so every cluster holds words
        // from the whole frequency spectrum (the paper's hot rows then
        // spread across topics, as in real corpora).
        let mut z = vec![0.0f32; v * l];
        let mut cluster_of = vec![0u16; v];
        for w in 0..v {
            let c = w % cfg.clusters;
            cluster_of[w] = c as u16;
            let row = &mut z[w * l..(w + 1) * l];
            for (i, x) in row.iter_mut().enumerate() {
                *x = centers[c * l + i]
                    + (cfg.sigma * rng.next_gauss()) as f32;
            }
            normalize(row);
        }

        // Plant analogy relations: offset vectors applied to random words.
        // For each relation draw an offset `r`; for each pair pick `a` and
        // REDEFINE z_b := normalize(z_a + r) for a fresh word b (chosen
        // from mid-frequency ranks so both a and b occur often enough to
        // be learnable).
        let mut relations = Vec::with_capacity(cfg.relations);
        let mut used: Vec<bool> = vec![false; v];
        let lo = v / 20; // skip the ultra-frequent head
        let hi = (v * 3 / 5).max(lo + 2 * cfg.pairs_per_relation + 2);
        for _ in 0..cfg.relations {
            let mut offset = vec![0.0f32; l];
            random_unit(&mut offset, &mut rng);
            // moderate offset magnitude keeps b's cluster geometry intact
            for x in offset.iter_mut() {
                *x *= 0.8;
            }
            let mut pairs = Vec::with_capacity(cfg.pairs_per_relation);
            let mut guard = 0;
            while pairs.len() < cfg.pairs_per_relation && guard < 10_000 {
                guard += 1;
                let a = lo + rng.below(hi - lo);
                let b = lo + rng.below(hi - lo);
                if a == b || used[a] || used[b] {
                    continue;
                }
                used[a] = true;
                used[b] = true;
                let (za, zb) = rows_mut(&mut z, l, a, b);
                for i in 0..l {
                    zb[i] = za[i] + offset[i];
                }
                normalize(zb);
                pairs.push((a as u32, b as u32));
            }
            relations.push(Relation { pairs });
        }

        // Zipf unigram over frequency-ranked ids.
        let unigram: Vec<f64> = (0..v)
            .map(|i| 1.0 / ((i + 1) as f64).powf(cfg.zipf))
            .collect();

        // Emission distributions:
        //   p(w|c) ∝ unigram(w)^0.7 · exp(beta·<z_w, center_c>).
        // The 0.7 damping keeps the Zipf head from swamping the topical
        // signal (head words sit in every cluster), so co-occurrence
        // stays strongly log-linear in the latent space.
        let mut emit = Vec::with_capacity(cfg.clusters);
        for c in 0..cfg.clusters {
            let center = &centers[c * l..(c + 1) * l];
            let weights: Vec<f64> = (0..v)
                .map(|w| {
                    let zc = dotf(&z[w * l..(w + 1) * l], center);
                    unigram[w].powf(0.7) * (cfg.beta * zc as f64).exp()
                })
                .collect();
            emit.push(AliasTable::new(&weights));
        }
        let global = AliasTable::new(&unigram);

        // Topic weights: total unigram mass per cluster.
        let mut mass = vec![0.0f64; cfg.clusters];
        for w in 0..v {
            mass[cluster_of[w] as usize] += unigram[w];
        }
        let topic = AliasTable::new(&mass);

        Self {
            cfg,
            z,
            cluster_of,
            unigram,
            relations,
            emit,
            global,
            topic,
        }
    }

    /// Latent vector of word `w`.
    pub fn latent(&self, w: u32) -> &[f32] {
        let l = self.cfg.latent_dim;
        &self.z[w as usize * l..(w as usize + 1) * l]
    }

    /// Ground-truth similarity = latent cosine (latents are unit vectors).
    pub fn similarity(&self, a: u32, b: u32) -> f32 {
        dotf(self.latent(a), self.latent(b))
    }

    /// Token for word id (ids are frequency-ranked by construction).
    pub fn token(&self, w: u32) -> String {
        format!("w{w:06}")
    }

    /// Emit one sentence of word ids.
    pub fn sentence(&self, rng: &mut Xoshiro256ss) -> Vec<u32> {
        // Geometric length with the configured mean, clamped to [5, 70].
        let p = 1.0 / self.cfg.sentence_len as f64;
        let mut len = 0usize;
        while rng.next_f64() >= p && len < 70 {
            len += 1;
        }
        let len = len.clamp(5, 70);
        let c = self.topic.sample(rng) as usize;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            let w = if rng.next_f64() < self.cfg.noise {
                self.global.sample(rng)
            } else {
                self.emit[c].sample(rng)
            };
            out.push(w);
        }
        out
    }

    /// Write `tokens` worth of sentences to a corpus file (one sentence
    /// per line).  Returns the number of tokens written.
    pub fn write_corpus<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<u64> {
        let mut rng = Xoshiro256ss::new(self.cfg.seed ^ 0x5EED_C0DE);
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
        let mut written = 0u64;
        let mut line = String::with_capacity(1024);
        while written < self.cfg.tokens {
            let sent = self.sentence(&mut rng);
            line.clear();
            for (i, &id) in sent.iter().enumerate() {
                if i > 0 {
                    line.push(' ');
                }
                line.push_str(&self.token(id));
            }
            line.push('\n');
            w.write_all(line.as_bytes())?;
            written += sent.len() as u64;
        }
        w.flush()?;
        Ok(written)
    }
}

fn random_unit(row: &mut [f32], rng: &mut Xoshiro256ss) {
    for x in row.iter_mut() {
        *x = rng.next_gauss() as f32;
    }
    normalize(row);
}

fn normalize(row: &mut [f32]) {
    let n = dotf(row, row).sqrt().max(1e-12);
    for x in row.iter_mut() {
        *x /= n;
    }
}

fn dotf(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Two disjoint mutable rows of a row-major matrix.
fn rows_mut(z: &mut [f32], l: usize, a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = z.split_at_mut(b * l);
        (&mut lo[a * l..(a + 1) * l], &mut hi[..l])
    } else {
        let (lo, hi) = z.split_at_mut(a * l);
        let bl = &mut lo[b * l..(b + 1) * l];
        (&mut hi[..l], bl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> LatentModel {
        LatentModel::new(SyntheticConfig::test_tiny())
    }

    #[test]
    fn latents_are_unit() {
        let m = tiny_model();
        for w in 0..m.cfg.vocab as u32 {
            let n = dotf(m.latent(w), m.latent(w));
            assert!((n - 1.0).abs() < 1e-4, "word {w} norm {n}");
        }
    }

    #[test]
    fn same_cluster_more_similar() {
        let m = tiny_model();
        let (mut same, mut diff) = (Vec::new(), Vec::new());
        let mut rng = Xoshiro256ss::new(99);
        for _ in 0..3000 {
            let a = rng.below(m.cfg.vocab) as u32;
            let b = rng.below(m.cfg.vocab) as u32;
            if a == b {
                continue;
            }
            let s = m.similarity(a, b);
            if m.cluster_of[a as usize] == m.cluster_of[b as usize] {
                same.push(s);
            } else {
                diff.push(s);
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(
            mean(&same) > mean(&diff) + 0.2,
            "same {} vs diff {}",
            mean(&same),
            mean(&diff)
        );
    }

    #[test]
    fn relations_plant_parallel_offsets() {
        let m = tiny_model();
        for rel in &m.relations {
            assert!(!rel.pairs.is_empty());
            // For two pairs (a,b), (c,d) of a relation, z_b - z_a must be
            // closer to z_d - z_c than random word differences are.
            if rel.pairs.len() >= 2 {
                let (a, b) = rel.pairs[0];
                let (c, d) = rel.pairs[1];
                let l = m.cfg.latent_dim;
                let mut off1 = vec![0.0f32; l];
                let mut off2 = vec![0.0f32; l];
                for i in 0..l {
                    off1[i] = m.latent(b)[i] - m.latent(a)[i];
                    off2[i] = m.latent(d)[i] - m.latent(c)[i];
                }
                let cos = dotf(&off1, &off2)
                    / (dotf(&off1, &off1).sqrt() * dotf(&off2, &off2).sqrt())
                        .max(1e-9);
                assert!(cos > 0.5, "relation offsets not parallel: {cos}");
            }
        }
    }

    #[test]
    fn sentences_have_sane_lengths() {
        let m = tiny_model();
        let mut rng = Xoshiro256ss::new(5);
        for _ in 0..200 {
            let s = m.sentence(&mut rng);
            assert!((5..=70).contains(&s.len()));
            assert!(s.iter().all(|&w| (w as usize) < m.cfg.vocab));
        }
    }

    #[test]
    fn corpus_is_topical() {
        // Words co-occurring in a sentence must be latently more similar
        // than random pairs — the distributional hypothesis holds in the
        // generated data.
        let m = tiny_model();
        let mut rng = Xoshiro256ss::new(6);
        let mut cooc = Vec::new();
        let mut rand_pairs = Vec::new();
        for _ in 0..300 {
            let s = m.sentence(&mut rng);
            for i in 1..s.len() {
                if s[i] != s[i - 1] {
                    cooc.push(m.similarity(s[i], s[i - 1]));
                }
            }
            let a = rng.below(m.cfg.vocab) as u32;
            let b = rng.below(m.cfg.vocab) as u32;
            if a != b {
                rand_pairs.push(m.similarity(a, b));
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        // Co-occurring pairs run ~2× the random-pair similarity in the
        // tiny test config (larger configs are sharper).
        assert!(
            mean(&cooc) > mean(&rand_pairs) + 0.05,
            "cooc {} vs random {}",
            mean(&cooc),
            mean(&rand_pairs)
        );
    }

    #[test]
    fn write_corpus_roundtrips_through_vocab() {
        let mut cfg = SyntheticConfig::test_tiny();
        cfg.tokens = 5_000;
        let m = LatentModel::new(cfg);
        let path = std::env::temp_dir().join("pw2v_synth_test.txt");
        let n = m.write_corpus(&path).unwrap();
        assert!(n >= 5_000);
        let v = crate::corpus::vocab::Vocab::build_from_file(&path, 1).unwrap();
        assert!(v.len() > 100, "vocab too small: {}", v.len());
        // Tokens parse back to ids.
        assert!(v.id("w000000").is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zipf_head_dominates() {
        let mut cfg = SyntheticConfig::test_tiny();
        cfg.tokens = 30_000;
        cfg.noise = 1.0; // pure unigram to test the frequency profile
        let m = LatentModel::new(cfg);
        let mut rng = Xoshiro256ss::new(7);
        let mut counts = vec![0u64; m.cfg.vocab];
        let mut total = 0u64;
        while total < 30_000 {
            for w in m.sentence(&mut rng) {
                counts[w as usize] += 1;
                total += 1;
            }
        }
        let head: u64 = counts[..10].iter().sum();
        assert!(
            head as f64 / total as f64 > 0.25,
            "head mass {}",
            head as f64 / total as f64
        );
    }
}
