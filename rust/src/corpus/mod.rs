//! Corpus substrate: vocabulary, streaming readers, the pre-encoded `u32`
//! corpus cache (`encoded`/`source`: mmap-backed, zero per-epoch hashing),
//! subsampling, sharding, and the synthetic latent-model corpus generator
//! that substitutes for the paper's text8 / One-Billion-Words / 7.2B-word
//! corpora (DESIGN.md §3, §6).

pub mod encoded;
pub mod reader;
pub mod shard;
pub mod source;
pub mod subsample;
pub mod synthetic;
pub mod vocab;

pub use encoded::{EncodedCorpus, EncodedSentenceReader};
pub use reader::{SentenceReader, MAX_SENTENCE_LEN};
pub use source::{Corpus, SentenceSource, SourceReader};
pub use subsample::Subsampler;
pub use synthetic::{LatentModel, SyntheticConfig};
pub use vocab::Vocab;
