//! Corpus substrate: vocabulary, streaming readers, subsampling, sharding,
//! and the synthetic latent-model corpus generator that substitutes for the
//! paper's text8 / One-Billion-Words / 7.2B-word corpora (DESIGN.md §3, §6).

pub mod reader;
pub mod shard;
pub mod subsample;
pub mod synthetic;
pub mod vocab;

pub use reader::{SentenceReader, MAX_SENTENCE_LEN};
pub use subsample::Subsampler;
pub use synthetic::{LatentModel, SyntheticConfig};
pub use vocab::Vocab;
