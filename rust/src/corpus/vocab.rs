//! Vocabulary: token → id mapping with corpus counts, built exactly like
//! the original word2vec — count, filter by `min_count`, sort by frequency
//! descending so id 0 is the most frequent word.  Frequency-sorted ids are
//! load-bearing downstream: the distributed sub-model synchroniser and the
//! cache-conflict performance model both reason about "the top-k rows".
//!
//! STREAMING extension: a vocabulary may GROW after construction.  OOV
//! tokens seen by the stream driver accumulate in a candidate buffer
//! ([`Vocab::observe`]); once a candidate's count crosses the admission
//! threshold it is [admitted](Vocab::admit) — appended at the next free
//! id, never renumbering existing ids (which keeps every encoded cache,
//! checkpoint and row store built so far valid).  Each admission bumps a
//! `generation` counter that [`Vocab::fingerprint`] mixes in (only when
//! non-zero, so frozen vocabularies keep their pre-streaming digests).
//! The admitted tail is frequency-sorted only within itself — the global
//! "id 0 is most frequent" invariant holds for the frozen prefix, and
//! downstream top-k reasoning is unaffected because admitted words are
//! rare by construction (they just crossed `min_count`).

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Vocab {
    /// Words sorted by count descending (index = word id).
    words: Vec<String>,
    counts: Vec<u64>,
    index: HashMap<String, u32>,
    /// Total corpus tokens covered by the retained vocabulary.
    total: u64,
    /// Debug-build instrumentation: number of [`Vocab::id`] hash lookups
    /// against THIS instance.  The encoded-corpus acceptance criterion
    /// asserts this stays flat while training from a cache (the cached
    /// path never hashes a token).  Release builds never touch it.
    lookups: AtomicU64,
    /// OOV candidate buffer (streaming): word → count seen so far.
    /// Empty for batch-built vocabularies.
    candidates: HashMap<String, u64>,
    /// Number of admissions performed on this vocabulary.  0 = frozen
    /// batch vocabulary (and the fingerprint is then byte-identical to
    /// the pre-streaming scheme).
    generation: u64,
}

impl Clone for Vocab {
    fn clone(&self) -> Self {
        Self {
            words: self.words.clone(),
            counts: self.counts.clone(),
            index: self.index.clone(),
            total: self.total,
            lookups: AtomicU64::new(self.lookups.load(Ordering::Relaxed)),
            candidates: self.candidates.clone(),
            generation: self.generation,
        }
    }
}

impl Vocab {
    /// Build from an iterator of tokens.
    pub fn build<I, S>(tokens: I, min_count: u64) -> Self
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut counts: HashMap<String, u64> = HashMap::new();
        for t in tokens {
            *counts.entry(t.as_ref().to_string()).or_insert(0) += 1;
        }
        Self::from_counts(counts, min_count)
    }

    /// Build by streaming a whitespace-tokenized file (one pass).
    pub fn build_from_file<P: AsRef<Path>>(
        path: P,
        min_count: u64,
    ) -> anyhow::Result<Self> {
        let f = std::fs::File::open(&path)?;
        let mut reader = std::io::BufReader::with_capacity(1 << 20, f);
        let mut counts: HashMap<String, u64> = HashMap::new();
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            for t in line.split_ascii_whitespace() {
                *counts.entry(t.to_string()).or_insert(0) += 1;
            }
        }
        Ok(Self::from_counts(counts, min_count))
    }

    pub fn from_counts(counts: HashMap<String, u64>, min_count: u64) -> Self {
        let mut pairs: Vec<(String, u64)> = counts
            .into_iter()
            .filter(|(_, c)| *c >= min_count)
            .collect();
        // Sort by count desc, then lexicographically for determinism.
        pairs.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let mut v = Vocab::default();
        for (w, c) in pairs {
            v.index.insert(w.clone(), v.words.len() as u32);
            v.words.push(w);
            v.counts.push(c);
            v.total += c;
        }
        v
    }

    /// Truncate to the `n` most frequent words (Table II's vocab sweep).
    pub fn truncated(&self, n: usize) -> Vocab {
        let n = n.min(self.words.len());
        let mut v = Vocab::default();
        for i in 0..n {
            v.index.insert(self.words[i].clone(), i as u32);
            v.words.push(self.words[i].clone());
            v.counts.push(self.counts[i]);
            v.total += self.counts[i];
        }
        v
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Total retained-token count (the original's `train_words`).
    pub fn total_words(&self) -> u64 {
        self.total
    }

    pub fn id(&self, word: &str) -> Option<u32> {
        #[cfg(debug_assertions)]
        self.lookups.fetch_add(1, Ordering::Relaxed);
        self.index.get(word).copied()
    }

    /// Hash lookups performed through [`Vocab::id`] so far (debug builds
    /// only; always 0 in release).  Tests use before/after snapshots to
    /// prove the cached-corpus path performs no per-token hashing.
    pub fn id_lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    pub fn count(&self, id: u32) -> u64 {
        self.counts[id as usize]
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Relative frequency of a word id.
    pub fn freq(&self, id: u32) -> f64 {
        self.counts[id as usize] as f64 / self.total.max(1) as f64
    }

    // ---- streaming growth --------------------------------------------

    /// Record one occurrence of an out-of-vocabulary token in the
    /// candidate buffer, returning its accumulated count.  The stream
    /// driver calls this for every OOV token in newly arrived bytes.
    pub fn observe(&mut self, word: &str) -> u64 {
        match self.candidates.get_mut(word) {
            Some(c) => {
                *c += 1;
                *c
            }
            None => {
                self.candidates.insert(word.to_string(), 1);
                1
            }
        }
    }

    /// Candidates whose accumulated count has reached `threshold`,
    /// sorted (count desc, then lexicographic — the same tie-break as
    /// [`from_counts`](Self::from_counts)) so admission order is
    /// deterministic.
    pub fn admissible(&self, threshold: u64) -> Vec<(String, u64)> {
        let mut due: Vec<(String, u64)> = self
            .candidates
            .iter()
            .filter(|(_, c)| **c >= threshold.max(1))
            .map(|(w, c)| (w.clone(), *c))
            .collect();
        due.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        due
    }

    /// Admit one candidate: append it at the next free id with its
    /// buffered count, bump the generation, and drop it from the
    /// candidate buffer.  Existing ids are never renumbered.  Returns
    /// the new id, or `None` if the word is already in the vocabulary.
    pub fn admit(&mut self, word: &str) -> Option<u32> {
        if self.index.contains_key(word) {
            self.candidates.remove(word);
            return None;
        }
        let count = self.candidates.remove(word)?;
        let id = self.words.len() as u32;
        self.index.insert(word.to_string(), id);
        self.words.push(word.to_string());
        self.counts.push(count);
        self.total += count;
        self.generation += 1;
        Some(id)
    }

    /// Number of admissions performed (0 = frozen batch vocabulary).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Pending (not yet admitted) candidate count.
    pub fn candidate_len(&self) -> usize {
        self.candidates.len()
    }

    /// Iterate the candidate buffer (checkpoint sidecar serialisation).
    pub fn candidates(&self) -> impl Iterator<Item = (&str, u64)> {
        self.candidates.iter().map(|(w, c)| (w.as_str(), *c))
    }

    /// Restore one candidate-buffer entry (checkpoint resume).
    pub fn restore_candidate(&mut self, word: &str, count: u64) {
        self.candidates.insert(word.to_string(), count);
    }

    /// Rebuild a streamed (admission-extended) vocabulary from saved
    /// state: the frozen prefix plus admitted tail in id order, and the
    /// generation stamp.  Unlike [`load`](Self::load) this does NOT
    /// enforce the global frequency-sort invariant — an admitted tail
    /// legitimately breaks it — but it does require ids to be dense and
    /// words unique.
    pub fn from_saved_parts(
        words: Vec<String>,
        counts: Vec<u64>,
        generation: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(words.len() == counts.len(), "words/counts length mismatch");
        let mut v = Vocab::default();
        for (w, c) in words.into_iter().zip(counts) {
            anyhow::ensure!(
                !v.index.contains_key(&w),
                "duplicate word {w:?} in saved vocab"
            );
            v.index.insert(w.clone(), v.words.len() as u32);
            v.words.push(w);
            v.counts.push(c);
            v.total += c;
        }
        v.generation = generation;
        Ok(v)
    }

    /// Order-sensitive 64-bit FNV-1a digest over the full (word, count)
    /// sequence.  The encoded corpus cache stores it in its header: a
    /// cache built under a different vocabulary (different corpus,
    /// `min_count`, or truncation) has a different fingerprint and is
    /// rejected/rebuilt instead of feeding stale ids to the trainer.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h = (*h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        mix(&mut h, &(self.words.len() as u64).to_le_bytes());
        for (w, c) in self.words.iter().zip(&self.counts) {
            mix(&mut h, w.as_bytes());
            // 0xFF never occurs in UTF-8: an unambiguous separator.
            mix(&mut h, &[0xFF]);
            mix(&mut h, &c.to_le_bytes());
        }
        // Generation stamp: mixed only when admissions have happened, so
        // every pre-streaming digest (existing caches, checkpoints, row
        // stores) is preserved byte-for-byte at generation 0.
        if self.generation > 0 {
            mix(&mut h, &self.generation.to_le_bytes());
        }
        h
    }

    /// `word<TAB>count` lines, frequency order.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        for (word, count) in self.words.iter().zip(&self.counts) {
            writeln!(w, "{word}\t{count}")?;
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut v = Vocab::default();
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let (w, c) = line.split_once('\t').ok_or_else(|| {
                anyhow::anyhow!("vocab line {}: expected word<TAB>count", lineno + 1)
            })?;
            let c: u64 = c.parse()?;
            v.index.insert(w.to_string(), v.words.len() as u32);
            v.words.push(w.to_string());
            v.counts.push(c);
            v.total += c;
        }
        // Enforce the frequency-sorted invariant.
        anyhow::ensure!(
            v.counts.windows(2).all(|p| p[0] >= p[1]),
            "vocab file not sorted by count descending"
        );
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vocab {
        Vocab::build(
            "the cat sat on the mat the cat".split_whitespace(),
            1,
        )
    }

    #[test]
    fn ids_are_frequency_sorted() {
        let v = sample();
        assert_eq!(v.word(0), "the"); // count 3
        assert_eq!(v.word(1), "cat"); // count 2
        assert_eq!(v.count(0), 3);
        assert_eq!(v.count(1), 2);
        assert_eq!(v.len(), 5);
        assert_eq!(v.total_words(), 8);
    }

    #[test]
    fn min_count_filters() {
        let v = Vocab::build(
            "the cat sat on the mat the cat".split_whitespace(),
            2,
        );
        assert_eq!(v.len(), 2); // only "the" and "cat"
        assert!(v.id("sat").is_none());
    }

    #[test]
    fn truncation_keeps_top_n() {
        let v = sample();
        let t = v.truncated(2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.word(0), "the");
        assert!(t.id("mat").is_none());
        assert_eq!(t.total_words(), 5);
    }

    #[test]
    fn truncation_beyond_len_is_identity() {
        let v = sample();
        assert_eq!(v.truncated(100).len(), v.len());
    }

    #[test]
    fn save_load_roundtrip() {
        let v = sample();
        let path = std::env::temp_dir().join("pw2v_vocab_test.txt");
        v.save(&path).unwrap();
        let l = Vocab::load(&path).unwrap();
        assert_eq!(l.len(), v.len());
        for i in 0..v.len() as u32 {
            assert_eq!(l.word(i), v.word(i));
            assert_eq!(l.count(i), v.count(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_tie_break() {
        let a = Vocab::build("b a".split_whitespace(), 1);
        let b = Vocab::build("a b".split_whitespace(), 1);
        assert_eq!(a.word(0), b.word(0));
    }

    #[test]
    fn fingerprint_tracks_vocab_identity() {
        let v = sample();
        assert_eq!(v.fingerprint(), sample().fingerprint());
        assert_eq!(v.fingerprint(), v.clone().fingerprint());
        // Any change to the retained set or counts changes the digest.
        assert_ne!(v.fingerprint(), v.truncated(2).fingerprint());
        let shifted = Vocab::build(
            "the cat sat on the mat the cat the".split_whitespace(),
            1,
        );
        assert_ne!(v.fingerprint(), shifted.fingerprint());
        // Word-boundary ambiguity is broken by the 0xFF separator.
        let a = Vocab::build(["ab", "c"], 1);
        let b = Vocab::build(["a", "bc"], 1);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn id_lookup_counter_counts_in_debug_builds() {
        let v = sample();
        let before = v.id_lookups();
        let _ = v.id("the");
        let _ = v.id("UNKNOWN");
        if cfg!(debug_assertions) {
            assert_eq!(v.id_lookups() - before, 2);
        } else {
            assert_eq!(v.id_lookups(), 0);
        }
    }

    #[test]
    fn freq_sums_to_one() {
        let v = sample();
        let s: f64 = (0..v.len() as u32).map(|i| v.freq(i)).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn observe_admit_appends_without_renumbering() {
        let mut v = sample();
        let frozen: Vec<String> =
            (0..v.len() as u32).map(|i| v.word(i).to_string()).collect();
        let before_total = v.total_words();
        assert_eq!(v.generation(), 0);
        for _ in 0..3 {
            v.observe("zebra");
        }
        v.observe("yak");
        assert_eq!(v.candidate_len(), 2);
        // Only zebra crossed threshold 3.
        let due = v.admissible(3);
        assert_eq!(due, vec![("zebra".to_string(), 3)]);
        let id = v.admit("zebra").unwrap();
        assert_eq!(id as usize, frozen.len());
        assert_eq!(v.generation(), 1);
        assert_eq!(v.count(id), 3);
        assert_eq!(v.total_words(), before_total + 3);
        assert_eq!(v.candidate_len(), 1); // yak still pending
        for (i, w) in frozen.iter().enumerate() {
            assert_eq!(v.word(i as u32), w, "frozen prefix id moved");
        }
        // Re-admitting (or admitting a known word) is a no-op.
        assert!(v.admit("zebra").is_none());
        assert!(v.admit("the").is_none());
        assert_eq!(v.generation(), 1);
    }

    #[test]
    fn admissible_orders_deterministically() {
        let mut v = sample();
        for _ in 0..2 {
            v.observe("bb");
        }
        for _ in 0..2 {
            v.observe("aa");
        }
        for _ in 0..5 {
            v.observe("cc");
        }
        let due = v.admissible(2);
        let names: Vec<&str> = due.iter().map(|(w, _)| w.as_str()).collect();
        assert_eq!(names, ["cc", "aa", "bb"], "count desc, then lexicographic");
    }

    #[test]
    fn generation_stamps_fingerprint_only_after_admission() {
        let mut v = sample();
        let frozen_fp = v.fingerprint();
        v.observe("zebra"); // candidates alone do not move the digest
        assert_eq!(v.fingerprint(), frozen_fp);
        v.observe("zebra");
        v.admit("zebra").unwrap();
        let g1 = v.fingerprint();
        assert_ne!(g1, frozen_fp);
        // Same words/counts at a DIFFERENT generation → different digest
        // (a resumed store must match the exact admission history).
        let same_words: Vec<String> =
            (0..v.len() as u32).map(|i| v.word(i).to_string()).collect();
        let same_counts = v.counts().to_vec();
        let rebuilt =
            Vocab::from_saved_parts(same_words.clone(), same_counts.clone(), 1).unwrap();
        assert_eq!(rebuilt.fingerprint(), g1);
        let wrong_gen = Vocab::from_saved_parts(same_words, same_counts, 2).unwrap();
        assert_ne!(wrong_gen.fingerprint(), g1);
    }

    #[test]
    fn from_saved_parts_accepts_admitted_tail_and_rejects_dupes() {
        // An admitted tail breaks the global sort (count 9 after count 1)
        // — from_saved_parts accepts it, load() would not.
        let v = Vocab::from_saved_parts(
            vec!["a".into(), "b".into(), "late".into()],
            vec![5, 1, 9],
            1,
        )
        .unwrap();
        assert_eq!(v.id("late"), Some(2));
        assert_eq!(v.total_words(), 15);
        assert!(Vocab::from_saved_parts(
            vec!["a".into(), "a".into()],
            vec![2, 1],
            0
        )
        .is_err());
    }
}
