//! Vocabulary: token → id mapping with corpus counts, built exactly like
//! the original word2vec — count, filter by `min_count`, sort by frequency
//! descending so id 0 is the most frequent word.  Frequency-sorted ids are
//! load-bearing downstream: the distributed sub-model synchroniser and the
//! cache-conflict performance model both reason about "the top-k rows".

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Vocab {
    /// Words sorted by count descending (index = word id).
    words: Vec<String>,
    counts: Vec<u64>,
    index: HashMap<String, u32>,
    /// Total corpus tokens covered by the retained vocabulary.
    total: u64,
    /// Debug-build instrumentation: number of [`Vocab::id`] hash lookups
    /// against THIS instance.  The encoded-corpus acceptance criterion
    /// asserts this stays flat while training from a cache (the cached
    /// path never hashes a token).  Release builds never touch it.
    lookups: AtomicU64,
}

impl Clone for Vocab {
    fn clone(&self) -> Self {
        Self {
            words: self.words.clone(),
            counts: self.counts.clone(),
            index: self.index.clone(),
            total: self.total,
            lookups: AtomicU64::new(self.lookups.load(Ordering::Relaxed)),
        }
    }
}

impl Vocab {
    /// Build from an iterator of tokens.
    pub fn build<I, S>(tokens: I, min_count: u64) -> Self
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut counts: HashMap<String, u64> = HashMap::new();
        for t in tokens {
            *counts.entry(t.as_ref().to_string()).or_insert(0) += 1;
        }
        Self::from_counts(counts, min_count)
    }

    /// Build by streaming a whitespace-tokenized file (one pass).
    pub fn build_from_file<P: AsRef<Path>>(
        path: P,
        min_count: u64,
    ) -> anyhow::Result<Self> {
        let f = std::fs::File::open(&path)?;
        let mut reader = std::io::BufReader::with_capacity(1 << 20, f);
        let mut counts: HashMap<String, u64> = HashMap::new();
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            for t in line.split_ascii_whitespace() {
                *counts.entry(t.to_string()).or_insert(0) += 1;
            }
        }
        Ok(Self::from_counts(counts, min_count))
    }

    pub fn from_counts(counts: HashMap<String, u64>, min_count: u64) -> Self {
        let mut pairs: Vec<(String, u64)> = counts
            .into_iter()
            .filter(|(_, c)| *c >= min_count)
            .collect();
        // Sort by count desc, then lexicographically for determinism.
        pairs.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let mut v = Vocab::default();
        for (w, c) in pairs {
            v.index.insert(w.clone(), v.words.len() as u32);
            v.words.push(w);
            v.counts.push(c);
            v.total += c;
        }
        v
    }

    /// Truncate to the `n` most frequent words (Table II's vocab sweep).
    pub fn truncated(&self, n: usize) -> Vocab {
        let n = n.min(self.words.len());
        let mut v = Vocab::default();
        for i in 0..n {
            v.index.insert(self.words[i].clone(), i as u32);
            v.words.push(self.words[i].clone());
            v.counts.push(self.counts[i]);
            v.total += self.counts[i];
        }
        v
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Total retained-token count (the original's `train_words`).
    pub fn total_words(&self) -> u64 {
        self.total
    }

    pub fn id(&self, word: &str) -> Option<u32> {
        #[cfg(debug_assertions)]
        self.lookups.fetch_add(1, Ordering::Relaxed);
        self.index.get(word).copied()
    }

    /// Hash lookups performed through [`Vocab::id`] so far (debug builds
    /// only; always 0 in release).  Tests use before/after snapshots to
    /// prove the cached-corpus path performs no per-token hashing.
    pub fn id_lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    pub fn count(&self, id: u32) -> u64 {
        self.counts[id as usize]
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Relative frequency of a word id.
    pub fn freq(&self, id: u32) -> f64 {
        self.counts[id as usize] as f64 / self.total.max(1) as f64
    }

    /// Order-sensitive 64-bit FNV-1a digest over the full (word, count)
    /// sequence.  The encoded corpus cache stores it in its header: a
    /// cache built under a different vocabulary (different corpus,
    /// `min_count`, or truncation) has a different fingerprint and is
    /// rejected/rebuilt instead of feeding stale ids to the trainer.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h = (*h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        mix(&mut h, &(self.words.len() as u64).to_le_bytes());
        for (w, c) in self.words.iter().zip(&self.counts) {
            mix(&mut h, w.as_bytes());
            // 0xFF never occurs in UTF-8: an unambiguous separator.
            mix(&mut h, &[0xFF]);
            mix(&mut h, &c.to_le_bytes());
        }
        h
    }

    /// `word<TAB>count` lines, frequency order.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        for (word, count) in self.words.iter().zip(&self.counts) {
            writeln!(w, "{word}\t{count}")?;
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut v = Vocab::default();
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let (w, c) = line.split_once('\t').ok_or_else(|| {
                anyhow::anyhow!("vocab line {}: expected word<TAB>count", lineno + 1)
            })?;
            let c: u64 = c.parse()?;
            v.index.insert(w.to_string(), v.words.len() as u32);
            v.words.push(w.to_string());
            v.counts.push(c);
            v.total += c;
        }
        // Enforce the frequency-sorted invariant.
        anyhow::ensure!(
            v.counts.windows(2).all(|p| p[0] >= p[1]),
            "vocab file not sorted by count descending"
        );
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vocab {
        Vocab::build(
            "the cat sat on the mat the cat".split_whitespace(),
            1,
        )
    }

    #[test]
    fn ids_are_frequency_sorted() {
        let v = sample();
        assert_eq!(v.word(0), "the"); // count 3
        assert_eq!(v.word(1), "cat"); // count 2
        assert_eq!(v.count(0), 3);
        assert_eq!(v.count(1), 2);
        assert_eq!(v.len(), 5);
        assert_eq!(v.total_words(), 8);
    }

    #[test]
    fn min_count_filters() {
        let v = Vocab::build(
            "the cat sat on the mat the cat".split_whitespace(),
            2,
        );
        assert_eq!(v.len(), 2); // only "the" and "cat"
        assert!(v.id("sat").is_none());
    }

    #[test]
    fn truncation_keeps_top_n() {
        let v = sample();
        let t = v.truncated(2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.word(0), "the");
        assert!(t.id("mat").is_none());
        assert_eq!(t.total_words(), 5);
    }

    #[test]
    fn truncation_beyond_len_is_identity() {
        let v = sample();
        assert_eq!(v.truncated(100).len(), v.len());
    }

    #[test]
    fn save_load_roundtrip() {
        let v = sample();
        let path = std::env::temp_dir().join("pw2v_vocab_test.txt");
        v.save(&path).unwrap();
        let l = Vocab::load(&path).unwrap();
        assert_eq!(l.len(), v.len());
        for i in 0..v.len() as u32 {
            assert_eq!(l.word(i), v.word(i));
            assert_eq!(l.count(i), v.count(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_tie_break() {
        let a = Vocab::build("b a".split_whitespace(), 1);
        let b = Vocab::build("a b".split_whitespace(), 1);
        assert_eq!(a.word(0), b.word(0));
    }

    #[test]
    fn fingerprint_tracks_vocab_identity() {
        let v = sample();
        assert_eq!(v.fingerprint(), sample().fingerprint());
        assert_eq!(v.fingerprint(), v.clone().fingerprint());
        // Any change to the retained set or counts changes the digest.
        assert_ne!(v.fingerprint(), v.truncated(2).fingerprint());
        let shifted = Vocab::build(
            "the cat sat on the mat the cat the".split_whitespace(),
            1,
        );
        assert_ne!(v.fingerprint(), shifted.fingerprint());
        // Word-boundary ambiguity is broken by the 0xFF separator.
        let a = Vocab::build(["ab", "c"], 1);
        let b = Vocab::build(["a", "bc"], 1);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn id_lookup_counter_counts_in_debug_builds() {
        let v = sample();
        let before = v.id_lookups();
        let _ = v.id("the");
        let _ = v.id("UNKNOWN");
        if cfg!(debug_assertions) {
            assert_eq!(v.id_lookups() - before, 2);
        } else {
            assert_eq!(v.id_lookups(), 0);
        }
    }

    #[test]
    fn freq_sums_to_one() {
        let v = sample();
        let s: f64 = (0..v.len() as u32).map(|i| v.freq(i)).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
