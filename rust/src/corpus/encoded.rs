//! Pre-encoded `u32` corpus cache: the ingest layer's second backend.
//!
//! The streaming text path re-reads the corpus and re-hashes every token
//! through the vocabulary on every epoch and every shard pass.  Ji et
//! al. train from a pre-tokenized integer stream so the per-word cost is
//! pure SGNS work; this module moves our encoding out of the epoch loop
//! the same way.  A one-time builder pass streams the text corpus through
//! the existing [`SentenceReader`] and writes `<corpus>.pw2v.u32`:
//! out-of-vocabulary tokens already dropped, sentences already clipped to
//! [`MAX_SENTENCE_LEN`], every surviving sentence stored as packed
//! little-endian `u32` ids.  Epoch 2+ I/O shrinks to a sequential `u32`
//! scan with ZERO vocabulary lookups (asserted by
//! `tests/corpus_parity.rs` via the debug `Vocab::id_lookups` counter).
//!
//! ## File format (version 1)
//!
//! ```text
//! offset  size             field
//! 0       8                magic "PW2VU32\0"
//! 8       4                version (u32 LE) = 1
//! 12      4                max token id in the payload (u32 LE; 0 when
//!                          n_tokens = 0) — lets `open` bound-check the
//!                          whole id stream in O(1) instead of scanning
//!                          gigabytes of mmapped tokens at startup
//! 16      8                vocab fingerprint (u64 LE, Vocab::fingerprint)
//! 24      8                source text length in bytes (u64 LE)
//! 32      8                n_sentences (u64 LE)
//! 40      8                n_tokens (u64 LE)
//! 48      4·n_tokens       token ids (u32 LE, concatenated sentences)
//! …       8·n_sentences    per-sentence source-line byte offset (u64 LE)
//! …       8·(n_sentences+1) token-prefix index (u64 LE, starts[0]=0,
//!                          starts[n]=n_tokens)
//! ```
//!
//! The per-sentence LINE OFFSET into the source text file is the key to
//! drop-in sharding: `trainer.rs` and `dist/train.rs` partition the
//! corpus into byte ranges of the TEXT file, and
//! [`EncodedCorpus::reader_range`] selects exactly the sentences whose
//! line offset falls in `[start, end)` — the same rule the (fixed)
//! [`SentenceReader::open_range`] applies — so every shard split yields
//! bit-identical sentence streams on both paths.
//!
//! Readers mmap the cache on 64-bit unix (raw `mmap(2)`/`munmap(2)`
//! through the libc the std runtime already links — no new crate), and
//! fall back to one buffered read into memory elsewhere, under
//! `--no-default-features` (the `mmap` feature), or with
//! `PW2V_CORPUS_MMAP=off` (the CI leg exercising the portable reader).
//! Caches that fail validation (wrong magic/version, truncated body,
//! stale vocab fingerprint, changed source length, zero sentences,
//! out-of-range ids) are never trained from: `auto` mode preserves them
//! as `<cache>.bak` — the same discipline as `BENCH_throughput.json` —
//! and rebuilds.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::reader::{SentenceReader, MAX_SENTENCE_LEN};
use super::vocab::Vocab;
use crate::util::mmap::Bytes;

/// Identifies the file as a pw2v u32 corpus cache.
pub const MAGIC: [u8; 8] = *b"PW2VU32\0";
/// Current format version.
pub const VERSION: u32 = 1;
/// Suffix `auto` mode appends to the corpus path.
pub const CACHE_SUFFIX: &str = ".pw2v.u32";

const HEADER_LEN: usize = 48;

/// What one builder pass did (the microbench derives encode MB/s).
#[derive(Clone, Copy, Debug)]
pub struct BuildStats {
    pub sentences: u64,
    pub tokens: u64,
    /// Byte length of the source text file.
    pub text_bytes: u64,
    pub secs: f64,
}

/// A validated, memory-mapped (or memory-loaded) encoded corpus.
///
/// Shared by reference across all worker threads: the backing bytes are
/// immutable for the mapping's lifetime, and each worker iterates its own
/// [`EncodedSentenceReader`] cursor.
pub struct EncodedCorpus {
    bytes: Bytes,
    text_len: u64,
    n_sentences: u64,
    n_tokens: u64,
    off_off: usize,
    starts_off: usize,
}

impl EncodedCorpus {
    /// Where `auto` mode puts the cache: `<corpus>.pw2v.u32` next to the
    /// input.
    pub fn cache_path_for(corpus: &Path) -> PathBuf {
        let mut os = corpus.as_os_str().to_os_string();
        os.push(CACHE_SUFFIX);
        PathBuf::from(os)
    }

    /// One-time encoding pass: stream `text` through the existing
    /// [`SentenceReader`] (exactly once) and write the cache to `out`.
    /// The write goes to `<out>.tmp` first and is renamed into place, so
    /// a crashed build never leaves a half-written cache that a later
    /// `auto` run could pick up.
    pub fn build(text: &Path, vocab: &Vocab, out: &Path) -> anyhow::Result<BuildStats> {
        let text_len = std::fs::metadata(text)?.len();
        Self::build_upto(text, vocab, out, text_len)
    }

    /// [`build`](Self::build) over the text prefix `[0, upto)` only.  The
    /// stream driver uses this for its cold-start cache: training stops
    /// at the last COMPLETE line, so the cache must too (a trailing
    /// partial line would otherwise be encoded as a sentence the text
    /// path never yields, and the next [`append`](Self::append) would
    /// refuse the dirty boundary).
    pub fn build_upto(
        text: &Path,
        vocab: &Vocab,
        out: &Path,
        upto: u64,
    ) -> anyhow::Result<BuildStats> {
        let t0 = Instant::now();
        let text_len = upto;
        let tmp = append_name(out, ".tmp");
        let mut w = BufWriter::with_capacity(1 << 20, File::create(&tmp)?);
        w.write_all(&MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        // max token id / n_sentences / n_tokens are not known until the
        // pass completes; they are patched over these placeholders below.
        w.write_all(&0u32.to_le_bytes())?;
        w.write_all(&vocab.fingerprint().to_le_bytes())?;
        w.write_all(&text_len.to_le_bytes())?;
        w.write_all(&0u64.to_le_bytes())?;
        w.write_all(&0u64.to_le_bytes())?;

        let mut offsets: Vec<u64> = Vec::new();
        let mut starts: Vec<u64> = vec![0];
        let mut n_tokens = 0u64;
        let mut max_id = 0u32;
        let mut reader = SentenceReader::open_range(text, vocab, 0, upto)?;
        let mut sent: Vec<u32> = Vec::with_capacity(MAX_SENTENCE_LEN);
        while let Some(line_off) = reader.next_sentence_into_with_pos(&mut sent)? {
            offsets.push(line_off);
            n_tokens += sent.len() as u64;
            starts.push(n_tokens);
            for &id in &sent {
                max_id = max_id.max(id);
                w.write_all(&id.to_le_bytes())?;
            }
        }
        for &o in &offsets {
            w.write_all(&o.to_le_bytes())?;
        }
        for &s in &starts {
            w.write_all(&s.to_le_bytes())?;
        }
        w.flush()?;
        let mut f = w.into_inner().map_err(|e| e.into_error())?;
        f.seek(SeekFrom::Start(12))?;
        f.write_all(&max_id.to_le_bytes())?;
        f.seek(SeekFrom::Start(32))?;
        f.write_all(&(offsets.len() as u64).to_le_bytes())?;
        f.write_all(&n_tokens.to_le_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, out)?;
        Ok(BuildStats {
            sentences: offsets.len() as u64,
            tokens: n_tokens,
            text_bytes: text_len,
            secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Append-aware builder (streaming ingest): extend an existing cache
    /// with the source bytes `[recorded_text_len, upto)` WITHOUT
    /// re-tokenizing the prefix.  The prefix token/offset/index sections
    /// are copied raw; only the suffix is streamed through the
    /// [`SentenceReader`].  The extended cache lands via the same
    /// tmp+rename discipline as [`build`](Self::build).
    ///
    /// `expect_fp` is the vocab fingerprint the EXISTING cache must
    /// carry.  It may differ from `vocab.fingerprint()` when admissions
    /// happened since the last append — the caller (the stream driver)
    /// guarantees `vocab` is an append-extension of the vocabulary the
    /// cache was built under, which keeps every prefix token id valid.
    /// The rewritten header carries the CURRENT fingerprint.
    ///
    /// Fails (caller falls back to a full rebuild) when the recorded
    /// prefix does not end at a line boundary — appended bytes would
    /// otherwise extend a sentence the cache already encoded.
    pub fn append(
        text: &Path,
        vocab: &Vocab,
        cache: &Path,
        expect_fp: u64,
        upto: u64,
    ) -> anyhow::Result<BuildStats> {
        let t0 = Instant::now();
        let old = Self::parse_with(load_bytes(cache)?, vocab, expect_fp)
            .map_err(|e| e.context(format!("corpus cache {}", cache.display())))?;
        let old_len = old.text_len;
        anyhow::ensure!(
            upto >= old_len,
            "append window ends at {upto}, before the recorded prefix \
             ({old_len} bytes)"
        );
        anyhow::ensure!(
            prefix_ends_at_newline(text, old_len)?,
            "recorded prefix does not end at a line boundary; the last \
             cached sentence could grow — full rebuild required"
        );
        // Encode the suffix first (counts are needed up front — the
        // rewrite streams every section in order, no placeholder pass).
        let mut suf_tokens: Vec<u32> = Vec::new();
        let mut suf_offsets: Vec<u64> = Vec::new();
        let mut suf_starts: Vec<u64> = Vec::new();
        let mut max_id = u32::from_le_bytes(old.bytes[12..16].try_into().unwrap());
        let mut reader = SentenceReader::open_range(text, vocab, old_len, upto)?;
        let mut sent: Vec<u32> = Vec::with_capacity(MAX_SENTENCE_LEN);
        while let Some(line_off) = reader.next_sentence_into_with_pos(&mut sent)? {
            suf_offsets.push(line_off);
            for &id in &sent {
                max_id = max_id.max(id);
                suf_tokens.push(id);
            }
            suf_starts.push(old.n_tokens + suf_tokens.len() as u64);
        }
        let n_sentences = old.n_sentences + suf_offsets.len() as u64;
        let n_tokens = old.n_tokens + suf_tokens.len() as u64;
        let tmp = append_name(cache, ".tmp");
        let mut w = BufWriter::with_capacity(1 << 20, File::create(&tmp)?);
        w.write_all(&MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&max_id.to_le_bytes())?;
        w.write_all(&vocab.fingerprint().to_le_bytes())?;
        w.write_all(&upto.to_le_bytes())?;
        w.write_all(&n_sentences.to_le_bytes())?;
        w.write_all(&n_tokens.to_le_bytes())?;
        // Prefix sections raw, suffix entries appended to each.
        w.write_all(&old.bytes[HEADER_LEN..old.off_off])?;
        for &id in &suf_tokens {
            w.write_all(&id.to_le_bytes())?;
        }
        w.write_all(&old.bytes[old.off_off..old.starts_off])?;
        for &o in &suf_offsets {
            w.write_all(&o.to_le_bytes())?;
        }
        // The old starts section already ends with starts[n] =
        // old_n_tokens, which is exactly the first suffix boundary.
        w.write_all(
            &old.bytes[old.starts_off..old.starts_off + 8 * (old.n_sentences as usize + 1)],
        )?;
        for &s in &suf_starts {
            w.write_all(&s.to_le_bytes())?;
        }
        w.flush()?;
        let f = w.into_inner().map_err(|e| e.into_error())?;
        f.sync_all()?;
        drop(f);
        drop(old); // release the mapping before replacing the file
        std::fs::rename(&tmp, cache)?;
        Ok(BuildStats {
            sentences: suf_offsets.len() as u64,
            tokens: suf_tokens.len() as u64,
            text_bytes: upto - old_len,
            secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Open and fully validate a cache against `vocab`.  Every rejection
    /// path here is exercised by `tests/corpus_parity.rs`.
    pub fn open(path: &Path, vocab: &Vocab) -> anyhow::Result<Self> {
        let inner = || -> anyhow::Result<Self> {
            let bytes = load_bytes(path)?;
            Self::parse(bytes, vocab)
        };
        inner().map_err(|e| e.context(format!("corpus cache {}", path.display())))
    }

    fn parse(bytes: Bytes, vocab: &Vocab) -> anyhow::Result<Self> {
        Self::parse_with(bytes, vocab, vocab.fingerprint())
    }

    /// Like [`parse`](Self::parse) but accepting an explicit expected
    /// fingerprint: the APPEND path validates a cache written under an
    /// earlier vocabulary generation (ids unchanged — admission only
    /// appends entries) before extending it under the current one.
    fn parse_with(bytes: Bytes, vocab: &Vocab, expected_fp: u64) -> anyhow::Result<Self> {
        let b: &[u8] = &bytes;
        anyhow::ensure!(
            b.len() >= HEADER_LEN,
            "truncated: {} bytes, the header alone is {HEADER_LEN}",
            b.len()
        );
        anyhow::ensure!(
            b[..8] == MAGIC,
            "bad magic: not a pw2v u32 corpus cache"
        );
        let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
        anyhow::ensure!(
            version == VERSION,
            "unsupported version {version} (this build reads {VERSION})"
        );
        let max_id = u32::from_le_bytes(b[12..16].try_into().unwrap());
        let le64 = |at: usize| u64::from_le_bytes(b[at..at + 8].try_into().unwrap());
        let fp = le64(16);
        anyhow::ensure!(
            fp == expected_fp,
            "stale vocab fingerprint {fp:#018x} (current vocabulary is \
             {expected_fp:#018x}); the cache was built under a different \
             vocabulary"
        );
        let text_len = le64(24);
        let n_sentences = le64(32);
        let n_tokens = le64(40);
        // u128 arithmetic: a corrupt header must fail the size check, not
        // overflow it.
        let expected = HEADER_LEN as u128
            + 4 * n_tokens as u128
            + 8 * n_sentences as u128
            + 8 * (n_sentences as u128 + 1);
        anyhow::ensure!(
            b.len() as u128 == expected,
            "truncated or corrupt: {} bytes on disk, header implies {expected}",
            b.len()
        );
        anyhow::ensure!(
            n_sentences > 0,
            "zero sentences (source corpus empty or fully out-of-vocabulary); \
             refusing to train from it"
        );
        // Out-of-range ids would index past the model matrices.  The
        // builder records the payload's max id in the header, so this
        // bound-check is O(1) — opening a multi-GB mmapped cache must not
        // force a full sequential page-in before training starts.
        anyhow::ensure!(
            (max_id as usize) < vocab.len(),
            "token ids out of range: payload max id {max_id}, vocabulary \
             has {} entries",
            vocab.len()
        );
        let off_off = HEADER_LEN + 4 * n_tokens as usize;
        let starts_off = off_off + 8 * n_sentences as usize;
        let c = Self {
            bytes,
            text_len,
            n_sentences,
            n_tokens,
            off_off,
            starts_off,
        };
        // The index scan below is O(n_sentences) — ~16 bytes per sentence,
        // a few percent of the file — and is load-bearing: range sharding
        // binary-searches `offsets`, so unsorted offsets would silently
        // misroute whole shards.  The token payload itself is NOT scanned
        // (see the max-id header check above).
        anyhow::ensure!(c.token_start(0) == 0, "corrupt index: starts[0] != 0");
        anyhow::ensure!(
            c.token_start(n_sentences) == n_tokens,
            "corrupt index: starts[n] != n_tokens"
        );
        let mut prev_off: Option<u64> = None;
        for i in 0..n_sentences {
            let o = c.offset(i);
            anyhow::ensure!(
                o < text_len,
                "corrupt index: sentence {i} line offset {o} past source \
                 length {text_len}"
            );
            if let Some(p) = prev_off {
                anyhow::ensure!(
                    o > p,
                    "corrupt index: line offsets not strictly increasing at \
                     sentence {i}"
                );
            }
            prev_off = Some(o);
            let lo = c.token_start(i);
            let hi = c.token_start(i + 1);
            anyhow::ensure!(
                hi > lo && hi - lo <= MAX_SENTENCE_LEN as u64,
                "corrupt index: sentence {i} spans tokens {lo}..{hi} \
                 (must be 1..={MAX_SENTENCE_LEN})"
            );
        }
        Ok(c)
    }

    /// Open a valid cache at `cache`, building (or rebuilding) it from
    /// `text` when missing or stale.  Staleness: failed validation, a
    /// changed source length, or a source file modified AFTER the cache
    /// was written (catches same-length rewrites — e.g. a line-shuffled
    /// corpus — that the fingerprint and length cannot see).  One
    /// exception, for streaming ingest: a source that GREW past a
    /// still-valid cache whose prefix ends at a line boundary is
    /// extended in place via [`append`](Self::append) — only the new
    /// suffix is tokenized.  (The grown-file mtime is necessarily newer;
    /// the rule trusts that growth means append, which is the streaming
    /// contract — a same-length-prefix rewrite plus growth is
    /// indistinguishable and remains the caller's responsibility.)  A
    /// stale/corrupt cache is preserved as `<cache>.bak` before the
    /// rebuild, like `BENCH_throughput.json` does for the perf
    /// trajectory.  Returns the cache and whether this call (re)built it.
    pub fn ensure(
        text: &Path,
        vocab: &Vocab,
        cache: &Path,
    ) -> anyhow::Result<(Self, bool)> {
        let text_meta = std::fs::metadata(text)?;
        let text_len = text_meta.len();
        if cache.exists() {
            // make(1)-style dependency rule; strict `>` so the cache a
            // build finishes in the same mtime tick as its source read
            // still counts as fresh.
            let cache_mtime =
                std::fs::metadata(cache).and_then(|m| m.modified());
            let text_newer = match (text_meta.modified(), cache_mtime) {
                (Ok(t), Ok(c)) => t > c,
                // No mtime support on this platform/fs: fall back to the
                // length + fingerprint checks alone.
                _ => false,
            };
            let why = match Self::open(cache, vocab) {
                Ok(c) if c.text_len() == text_len && !text_newer => {
                    return Ok((c, false))
                }
                Ok(c) if c.text_len() < text_len => {
                    // Source grew by a suffix: extend instead of rebuild.
                    let fp = vocab.fingerprint();
                    match Self::append(text, vocab, cache, fp, text_len) {
                        Ok(st) => {
                            eprintln!(
                                "extended corpus cache {}: +{} sentences, \
                                 +{} tokens from {} new text bytes in {:.2}s",
                                cache.display(),
                                st.sentences,
                                st.tokens,
                                st.text_bytes,
                                st.secs
                            );
                            return Ok((Self::open(cache, vocab)?, true));
                        }
                        Err(e) => format!(
                            "source grew ({} -> {text_len}) but cannot be \
                             append-encoded: {e:#}",
                            c.text_len()
                        ),
                    }
                }
                Ok(c) if c.text_len() != text_len => format!(
                    "source text length changed ({} -> {text_len})",
                    c.text_len()
                ),
                Ok(_) => "source text modified after the cache was built"
                    .to_string(),
                Err(e) => format!("{e:#}"),
            };
            let bak = append_name(cache, ".bak");
            eprintln!(
                "WARNING: corpus cache {} is stale ({why}); preserving it \
                 as {} and rebuilding",
                cache.display(),
                bak.display()
            );
            std::fs::rename(cache, &bak)?;
        }
        let st = Self::build(text, vocab, cache)?;
        eprintln!(
            "encoded {} -> {}: {} sentences, {} tokens from {} text bytes \
             in {:.2}s",
            text.display(),
            cache.display(),
            st.sentences,
            st.tokens,
            st.text_bytes,
            st.secs
        );
        Ok((Self::open(cache, vocab)?, true))
    }

    /// Byte length of the source text file (recorded at build time).
    /// Sharding uses THIS length so text and encoded paths split the
    /// corpus identically.
    pub fn text_len(&self) -> u64 {
        self.text_len
    }

    pub fn n_sentences(&self) -> u64 {
        self.n_sentences
    }

    pub fn n_tokens(&self) -> u64 {
        self.n_tokens
    }

    /// Cursor over the whole corpus.
    pub fn reader(&self) -> EncodedSentenceReader<'_> {
        EncodedSentenceReader {
            corpus: self,
            next: 0,
            end: self.n_sentences,
        }
    }

    /// Cursor over the sentences the text reader would yield for the
    /// byte range `[start, end)` of the SOURCE file: exactly those whose
    /// source line begins in the range.
    pub fn reader_range(&self, start: u64, end: u64) -> EncodedSentenceReader<'_> {
        let lo = self.lower_bound(start);
        let hi = self.lower_bound(end).max(lo);
        EncodedSentenceReader {
            corpus: self,
            next: lo,
            end: hi,
        }
    }

    /// First sentence index whose line offset is `>= target`.
    fn lower_bound(&self, target: u64) -> u64 {
        let (mut lo, mut hi) = (0u64, self.n_sentences);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.offset(mid) < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    fn le64_at(&self, at: usize) -> u64 {
        u64::from_le_bytes(self.bytes[at..at + 8].try_into().unwrap())
    }

    /// Source-line byte offset of sentence `i`.
    fn offset(&self, i: u64) -> u64 {
        self.le64_at(self.off_off + 8 * i as usize)
    }

    /// Token-prefix index entry `i` (valid for `0..=n_sentences`).
    fn token_start(&self, i: u64) -> u64 {
        self.le64_at(self.starts_off + 8 * i as usize)
    }

    /// Copy sentence `i`'s ids into `out` (cleared first); allocation-free
    /// once `out` has reached its high-water capacity.
    fn sentence_into(&self, i: u64, out: &mut Vec<u32>) {
        out.clear();
        let t0 = self.token_start(i) as usize;
        let t1 = self.token_start(i + 1) as usize;
        let base = HEADER_LEN + 4 * t0;
        let raw = &self.bytes[base..base + 4 * (t1 - t0)];
        out.extend(
            raw.chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap())),
        );
    }
}

/// Streaming cursor over a sentence range of an [`EncodedCorpus`].  Holds
/// NO vocabulary reference: by construction the cached path cannot hash a
/// token (the acceptance criterion "zero vocab lookups on epoch >= 2" is
/// provable from this type alone).
pub struct EncodedSentenceReader<'c> {
    corpus: &'c EncodedCorpus,
    next: u64,
    /// One past the last sentence index of the range.
    end: u64,
}

impl EncodedSentenceReader<'_> {
    /// Same contract as [`SentenceReader::next_sentence_into`]: fill
    /// `out` with the next sentence's ids, `false` at end of range.
    /// (Infallible here; the `Result` keeps both readers interchangeable
    /// behind `SentenceSource`.)
    pub fn next_sentence_into(&mut self, out: &mut Vec<u32>) -> anyhow::Result<bool> {
        if self.next >= self.end {
            return Ok(false);
        }
        self.corpus.sentence_into(self.next, out);
        self.next += 1;
        Ok(true)
    }

    /// Sentences left in the range.
    pub fn remaining(&self) -> u64 {
        self.end - self.next
    }

    /// Drain the range into a Vec (tests/small corpora).
    pub fn collect_sentences(mut self) -> anyhow::Result<Vec<Vec<u32>>> {
        let mut out = Vec::new();
        let mut sent = Vec::new();
        while self.next_sentence_into(&mut sent)? {
            out.push(sent.clone());
        }
        Ok(out)
    }
}

/// Append `suffix` to a path's final component (`x.u32` -> `x.u32.bak`).
fn append_name(path: &Path, suffix: &str) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

/// Does the source prefix `[0, len)` end exactly at a line boundary?
/// (`len == 0` counts: an empty prefix is a trivially clean boundary.)
fn prefix_ends_at_newline(text: &Path, len: u64) -> anyhow::Result<bool> {
    if len == 0 {
        return Ok(true);
    }
    use std::io::Read;
    let mut f = File::open(text)?;
    f.seek(SeekFrom::Start(len - 1))?;
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    Ok(b[0] == b'\n')
}

/// Open the cache bytes through the shared [`crate::util::mmap`]
/// substrate.  The `PW2V_CORPUS_MMAP=off|0` opt-out (the CI leg
/// exercising the portable buffered reader) lives HERE, at the corpus
/// call site — other `util::mmap` users (the serve row store) have their
/// own policy.
fn load_bytes(path: &Path) -> anyhow::Result<Bytes> {
    let off = matches!(
        std::env::var("PW2V_CORPUS_MMAP").as_deref(),
        Ok("off") | Ok("0")
    );
    crate::util::mmap::load_bytes(path, !off)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_tmp(name: &str, content: &str) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("pw2v_enc_{}_{name}", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    fn vocab_abc() -> Vocab {
        Vocab::build(["a", "b", "c"], 1)
    }

    #[test]
    fn roundtrips_sentences_and_offsets() {
        let path = write_tmp("rt.txt", "a b c\n\nZZ\nc b\n");
        let cache = append_name(&path, CACHE_SUFFIX);
        let vocab = vocab_abc();
        let st = EncodedCorpus::build(&path, &vocab, &cache).unwrap();
        assert_eq!(st.sentences, 2);
        assert_eq!(st.tokens, 5);
        assert_eq!(st.text_bytes, 14);
        let enc = EncodedCorpus::open(&cache, &vocab).unwrap();
        assert_eq!(enc.n_sentences(), 2);
        assert_eq!(enc.n_tokens(), 5);
        assert_eq!(enc.text_len(), 14);
        let got = enc.reader().collect_sentences().unwrap();
        let want = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(got, want);
        // Range selection: the second sentence's line starts at byte 10.
        assert_eq!(enc.reader_range(0, 10).remaining(), 1);
        assert_eq!(enc.reader_range(10, 14).remaining(), 1);
        assert_eq!(enc.reader_range(0, 11).remaining(), 2);
        assert_eq!(enc.reader_range(11, 14).remaining(), 0);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    #[test]
    fn ensure_reuses_then_appends_on_suffix_growth() {
        let path = write_tmp("ens.txt", "a b\nb c\n");
        let cache = append_name(&path, CACHE_SUFFIX);
        let vocab = vocab_abc();
        let (_, built) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
        assert!(built);
        let (_, built) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
        assert!(!built, "valid cache must be reused");
        // Suffix growth takes the append path: extended, no .bak.
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"c c\n").unwrap();
        drop(f);
        let (enc, built) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
        assert!(built, "growth must extend the cache");
        assert_eq!(enc.n_sentences(), 3);
        assert_eq!(enc.text_len(), 12);
        assert!(
            !append_name(&cache, ".bak").exists(),
            "append must not leave a .bak (nothing was discarded)"
        );
        // The extended cache matches a from-scratch text read exactly.
        let got = enc.reader().collect_sentences().unwrap();
        let want = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(got, want);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    #[test]
    fn ensure_rebuilds_when_prefix_boundary_is_dirty() {
        // Initial text ends WITHOUT a newline: the cached last sentence
        // could grow, so growth must fall back to a full rebuild.
        let path = write_tmp("dirty.txt", "a b\nb c");
        let cache = append_name(&path, CACHE_SUFFIX);
        let vocab = vocab_abc();
        let (enc, _) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
        assert_eq!(enc.n_sentences(), 2);
        drop(enc);
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b" c\na a\n").unwrap();
        drop(f);
        let (enc, built) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
        assert!(built);
        assert_eq!(enc.n_sentences(), 3, "grown line re-read whole");
        assert!(append_name(&cache, ".bak").exists(), "rebuild preserves old");
        let got = enc.reader().collect_sentences().unwrap();
        let want = SentenceReader::open(&path, &vocab)
            .unwrap()
            .collect_sentences()
            .unwrap();
        assert_eq!(got, want);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
        std::fs::remove_file(append_name(&cache, ".bak")).ok();
    }

    #[test]
    fn append_across_vocab_generations_rewrites_fingerprint() {
        let path = write_tmp("gen.txt", "a b c\n");
        let cache = append_name(&path, CACHE_SUFFIX);
        let mut vocab = vocab_abc();
        EncodedCorpus::build(&path, &vocab, &cache).unwrap();
        let old_fp = vocab.fingerprint();
        // Admit a new word, then append a suffix that uses it.
        vocab.observe("zz");
        vocab.observe("zz");
        vocab.admit("zz").unwrap();
        assert_ne!(vocab.fingerprint(), old_fp);
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"zz a zz\n").unwrap();
        drop(f);
        let upto = std::fs::metadata(&path).unwrap().len();
        let st = EncodedCorpus::append(&path, &vocab, &cache, old_fp, upto).unwrap();
        assert_eq!(st.sentences, 1);
        assert_eq!(st.tokens, 3);
        // The extended cache validates under the NEW fingerprint...
        let enc = EncodedCorpus::open(&cache, &vocab).unwrap();
        assert_eq!(enc.n_sentences(), 2);
        assert_eq!(enc.n_tokens(), 6);
        let sents = enc.reader().collect_sentences().unwrap();
        let zz = vocab.id("zz").unwrap();
        let a = vocab.id("a").unwrap();
        assert_eq!(sents[1], vec![zz, a, zz]);
        // ...and a second append with the wrong expected fp is refused.
        drop(enc);
        let err =
            EncodedCorpus::append(&path, &vocab, &cache, old_fp, upto).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    #[test]
    fn append_window_can_stop_before_file_end() {
        // The stream driver only feeds complete-line prefixes: an append
        // window ending before a trailing partial line must encode only
        // the complete lines and record text_len = window end.
        let path = write_tmp("win.txt", "a b\n");
        let cache = append_name(&path, CACHE_SUFFIX);
        let vocab = vocab_abc();
        EncodedCorpus::build(&path, &vocab, &cache).unwrap();
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"c c\nb b").unwrap(); // "b b" is incomplete
        drop(f);
        let st = EncodedCorpus::append(&path, &vocab, &cache, vocab.fingerprint(), 8)
            .unwrap();
        assert_eq!(st.sentences, 1);
        let enc = EncodedCorpus::open(&cache, &vocab).unwrap();
        assert_eq!(enc.text_len(), 8);
        assert_eq!(enc.n_sentences(), 2);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    #[test]
    fn empty_corpus_is_rejected() {
        let path = write_tmp("empty.txt", "");
        let cache = append_name(&path, CACHE_SUFFIX);
        let vocab = vocab_abc();
        EncodedCorpus::build(&path, &vocab, &cache).unwrap();
        let err = EncodedCorpus::open(&cache, &vocab).unwrap_err();
        assert!(format!("{err:#}").contains("zero sentences"), "{err:#}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }
}
