//! Frequent-word subsampling (Mikolov et al. 2013, Eq. 5 as implemented in
//! the C code): word w with corpus count `cn` is KEPT with probability
//!
//!   p_keep = (sqrt(cn / (sample * T)) + 1) * (sample * T) / cn
//!
//! where `T` is the total token count.  This aggressively discards the most
//! frequent words, which both speeds training and improves accuracy; the
//! paper uses `sample = 1e-4` throughout.

use super::vocab::Vocab;
use crate::util::rng::Xoshiro256ss;

#[derive(Clone, Debug)]
pub struct Subsampler {
    /// Per-word keep probability (clamped to 1).
    keep: Vec<f32>,
    enabled: bool,
}

impl Subsampler {
    pub fn new(vocab: &Vocab, sample: f32) -> Self {
        if sample <= 0.0 || vocab.is_empty() {
            return Self {
                keep: vec![1.0; vocab.len()],
                enabled: false,
            };
        }
        let t = sample as f64 * vocab.total_words() as f64;
        let keep = vocab
            .counts()
            .iter()
            .map(|&cn| {
                let cn = cn as f64;
                (((cn / t).sqrt() + 1.0) * t / cn).min(1.0) as f32
            })
            .collect();
        Self {
            keep,
            enabled: true,
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn keep_prob(&self, id: u32) -> f32 {
        self.keep[id as usize]
    }

    /// Bernoulli decision for one occurrence of `id`.
    #[inline]
    pub fn keep(&self, id: u32, rng: &mut Xoshiro256ss) -> bool {
        !self.enabled || rng.next_f32() < self.keep[id as usize]
    }

    /// Filter a sentence in place.
    pub fn filter(&self, sentence: &mut Vec<u32>, rng: &mut Xoshiro256ss) {
        if self.enabled {
            sentence.retain(|&id| rng.next_f32() < self.keep[id as usize]);
        }
    }

    /// Extend the keep table for newly ADMITTED vocabulary ids (streaming):
    /// every id in `old_len..new_len` gets keep probability 1.0.
    ///
    /// This is deliberately NOT what a cold rebuild would compute.  An
    /// admitted word just crossed the admission threshold, so under any
    /// realistic `sample` its exact keep probability rounds to 1.0 anyway
    /// — and the frozen prefix keeps its original probabilities (a cold
    /// rebuild would perturb ALL of them through the grown total `T`,
    /// changing every already-trained word's subsampling mid-run).  The
    /// divergence is documented in EXPERIMENTS.md §Streaming.
    pub fn extend_for_admitted(&mut self, new_len: usize) {
        while self.keep.len() < new_len {
            self.keep.push(1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zipf_vocab(v: usize) -> Vocab {
        // counts ~ 1e6 / rank
        let counts: std::collections::HashMap<String, u64> = (0..v)
            .map(|i| (format!("w{i}"), (1_000_000 / (i + 1)) as u64))
            .collect();
        Vocab::from_counts(counts, 1)
    }

    #[test]
    fn disabled_when_sample_zero() {
        let v = zipf_vocab(10);
        let s = Subsampler::new(&v, 0.0);
        assert!(!s.enabled());
        let mut rng = Xoshiro256ss::new(1);
        assert!((0..10u32).all(|i| s.keep(i, &mut rng)));
    }

    #[test]
    fn frequent_words_discarded_more() {
        let v = zipf_vocab(1000);
        let s = Subsampler::new(&v, 1e-4);
        // Monotone: keep prob must not decrease with rank (rarer => keep more).
        for i in 1..1000u32 {
            assert!(
                s.keep_prob(i) >= s.keep_prob(i - 1) - 1e-6,
                "rank {i}"
            );
        }
        // The most frequent word must be heavily subsampled.
        assert!(s.keep_prob(0) < 0.3, "keep(0) = {}", s.keep_prob(0));
        // Rare words must be untouched.
        assert_eq!(s.keep_prob(999), 1.0);
    }

    #[test]
    fn empirical_rate_matches_probability() {
        let v = zipf_vocab(100);
        let s = Subsampler::new(&v, 1e-3);
        let mut rng = Xoshiro256ss::new(42);
        let n = 200_000;
        let kept = (0..n).filter(|_| s.keep(0, &mut rng)).count();
        let want = s.keep_prob(0) as f64;
        let got = kept as f64 / n as f64;
        assert!((got - want).abs() < 0.01, "got {got} want {want}");
    }

    #[test]
    fn extend_for_admitted_keeps_prefix_and_appends_ones() {
        let v = zipf_vocab(100);
        let mut s = Subsampler::new(&v, 1e-4);
        let prefix: Vec<f32> = (0..100u32).map(|i| s.keep_prob(i)).collect();
        s.extend_for_admitted(103);
        for (i, p) in prefix.iter().enumerate() {
            assert_eq!(s.keep_prob(i as u32), *p, "prefix perturbed at {i}");
        }
        for i in 100..103u32 {
            assert_eq!(s.keep_prob(i), 1.0);
        }
        // Idempotent / never shrinks.
        s.extend_for_admitted(50);
        assert_eq!(s.keep_prob(102), 1.0);
    }

    #[test]
    fn filter_removes_in_place() {
        let v = zipf_vocab(100);
        let s = Subsampler::new(&v, 1e-5); // very aggressive
        let mut rng = Xoshiro256ss::new(7);
        let mut sent: Vec<u32> = (0..100).map(|i| i % 5).collect();
        let before = sent.len();
        s.filter(&mut sent, &mut rng);
        assert!(sent.len() < before);
    }
}
