//! Figure generators: project calibrated single-thread rates through the
//! coherence model (Fig. 3) and the cluster model (Fig. 4 / Table V).

use super::arch::{broadwell, knl, FabricSpec, MachineSpec};
use super::cache::{CoherenceModel, SchemeCost};
use super::network::ClusterModel;
use crate::dist::sync::SyncPolicy;

/// One point of a scaling curve.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    /// Threads (Fig. 3) or nodes (Fig. 4).
    pub x: usize,
    pub words_per_sec: f64,
}

/// Scheme parameters for figure generation.
#[derive(Clone, Copy, Debug)]
pub struct FigParams {
    /// Effective average context words per center (≈ window at paper
    /// defaults, after dynamic-window averaging 2·(c+1)/2 ≈ c+1 ≈ 6; the
    /// constant cancels in ratios).
    pub ctx: f64,
    pub negative: f64,
    pub dim: usize,
    /// Collision mass of the row-update distribution (vocab-dependent).
    pub collision_mass: f64,
}

impl Default for FigParams {
    fn default() -> Self {
        Self {
            ctx: 5.0,
            negative: 5.0,
            dim: 300,
            // EFFECTIVE collision mass, calibrated against the paper's
            // Fig. 3 anchors (see cache.rs docs); the raw Σp² of the
            // 1.1M-word unigram^0.75 distribution is ~1.6e-4, inflated by
            // the window of vulnerability and false sharing.
            collision_mass: 0.05,
        }
    }
}

/// Fig. 3: thread-scaling series on a machine for (scalar, gemm) schemes.
/// `w1_scalar`/`w1_gemm` anchor single-thread rates (measured or paper).
pub fn fig3_series(
    machine: &MachineSpec,
    p: &FigParams,
    w1_scalar: f64,
    w1_gemm: f64,
    threads: &[usize],
) -> (Vec<ScalingPoint>, Vec<ScalingPoint>) {
    let model = CoherenceModel::new(machine.clone(), p.collision_mass, p.dim);
    let scalar = SchemeCost::scalar(p.ctx, p.negative, w1_scalar);
    let gemm = SchemeCost::gemm(p.ctx, p.negative, w1_gemm);
    let mk = |cost: &SchemeCost| {
        threads
            .iter()
            .map(|&t| ScalingPoint {
                x: t,
                words_per_sec: model.throughput(cost, t),
            })
            .collect()
    };
    (mk(&scalar), mk(&gemm))
}

/// The thread counts the paper plots in Fig. 3.
pub fn fig3_thread_axis(machine: &MachineSpec) -> Vec<usize> {
    let mut t = vec![1, 2, 4, 8, 16];
    let c = machine.cores;
    if !t.contains(&c) {
        t.push(c);
    }
    let ht = machine.threads();
    if !t.contains(&ht) {
        t.push(ht);
    }
    t.sort_unstable();
    t
}

/// Fig. 4: node-scaling series for a cluster of `machine` nodes over
/// `fabric`, with the paper's shrinking sync interval.
pub fn fig4_series(
    machine: &MachineSpec,
    fabric: FabricSpec,
    p: &FigParams,
    w1_gemm: f64,
    nodes: &[usize],
) -> Vec<ScalingPoint> {
    let coh = CoherenceModel::new(machine.clone(), p.collision_mass, p.dim);
    let gemm = SchemeCost::gemm(p.ctx, p.negative, w1_gemm);
    let node_rate = coh.throughput(&gemm, machine.threads());
    let cluster = ClusterModel {
        fabric,
        node_words_per_sec: node_rate,
        vocab: 1_115_011,
        dim: p.dim,
    };
    nodes
        .iter()
        .map(|&n| {
            let interval = crate::dist::node::DistConfig::for_nodes(n).sync_interval;
            ScalingPoint {
                x: n,
                words_per_sec: cluster.throughput(
                    n,
                    &SyncPolicy::submodel_default(),
                    interval,
                ),
            }
        })
        .collect()
}

/// Convenience: the two clusters of the paper's Fig. 4.
pub fn paper_clusters() -> Vec<(MachineSpec, FabricSpec)> {
    vec![
        (broadwell(), super::arch::fdr_infiniband()),
        (knl(), super::arch::omnipath()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::arch::fdr_infiniband;

    #[test]
    fn fig3_shape_matches_paper() {
        // Anchors = paper 1T rates; shape claims of Fig. 3 must hold:
        // ours ~3.6x the original at 72 threads, original flattens early.
        let p = FigParams::default();
        let bdw = broadwell();
        let axis = fig3_thread_axis(&bdw);
        let (scalar, gemm) = fig3_series(&bdw, &p, 70_000.0, 182_000.0, &axis);
        let last_s = scalar.last().unwrap().words_per_sec;
        let last_g = gemm.last().unwrap().words_per_sec;
        let ratio = last_g / last_s;
        assert!(
            (2.5..6.0).contains(&ratio),
            "72-thread speedup {ratio} out of paper range"
        );
        // Absolute ballpark: paper reports 1.6M vs 5.8M words/s.
        assert!((0.8e6..3.0e6).contains(&last_s), "scalar {last_s}");
        assert!((3.5e6..9.0e6).contains(&last_g), "gemm {last_g}");
    }

    #[test]
    fn fig3_gemm_near_linear_within_socket() {
        // "near perfect within a single socket" (18 cores of the 2×18 BDW).
        let p = FigParams::default();
        let bdw = broadwell();
        let (_, gemm) = fig3_series(&bdw, &p, 70_000.0, 182_000.0, &[1, 18, 36]);
        let eff18 = gemm[1].words_per_sec / (18.0 * gemm[0].words_per_sec);
        let eff36 = gemm[2].words_per_sec / (36.0 * gemm[0].words_per_sec);
        assert!(eff18 > 0.85, "gemm 18T efficiency {eff18}");
        assert!(eff36 > 0.6, "gemm 36T efficiency {eff36}");
        assert!(eff36 < eff18, "cross-socket must cost something");
    }

    #[test]
    fn fig4_near_linear_then_bends() {
        let p = FigParams::default();
        let series = fig4_series(
            &broadwell(),
            fdr_infiniband(),
            &p,
            182_000.0,
            &[1, 2, 4, 8, 16, 32],
        );
        let w1 = series[0].words_per_sec;
        let eff = |i: usize| series[i].words_per_sec / (series[i].x as f64 * w1);
        assert!(eff(2) > 0.85, "4-node eff {}", eff(2));
        assert!(eff(5) < eff(2), "32-node should bend below 4-node");
        // Paper Table V ballpark: 4 BDW nodes ≈ 20M, 32 ≈ 110M words/s.
        let w4 = series[2].words_per_sec;
        let w32 = series[5].words_per_sec;
        assert!((1.2e7..3.5e7).contains(&w4), "4-node {w4}");
        assert!((6e7..2.0e8).contains(&w32), "32-node {w32}");
    }

    #[test]
    fn knl_beats_bdw_single_node() {
        // Paper Table III: KNL 8.9M vs BDW 5.8M.  With the same per-word
        // cost anchors scaled by core count/freq, KNL must come out ahead.
        let p = FigParams::default();
        let coh_b = CoherenceModel::new(broadwell(), p.collision_mass, p.dim);
        let coh_k = CoherenceModel::new(knl(), p.collision_mass, p.dim);
        // KNL cores are ~0.5x BDW single-thread (freq + uarch).
        let g_b = SchemeCost::gemm(p.ctx, p.negative, 182_000.0);
        let g_k = SchemeCost::gemm(p.ctx, p.negative, 85_000.0);
        let w_b = coh_b.throughput(&g_b, broadwell().threads());
        let w_k = coh_k.throughput(&g_k, knl().threads());
        assert!(w_k > w_b, "knl {w_k} vs bdw {w_b}");
    }
}
