//! The Hogwild coherence-stall model (paper Sec. III-A/C).
//!
//! Per trained word, a scheme performs `updates_per_word` read-modify-write
//! sweeps over model rows.  When `T` threads run, a row write whose cache
//! lines sit modified in another core's cache pays a line-transfer penalty.
//! The probability a given update collides with a concurrent writer is
//! driven by the *collision mass* of the update distribution over rows —
//! `m2 = Σ p_w²` — which for Zipf-ish vocabularies is dominated by the hot
//! head (exactly why the paper's Sec. IV-B vocabulary sweep stresses small
//! vocabularies).
//!
//! Seconds per word at T threads (per thread):
//!
//! ```text
//! s(T) = s1 + updates_per_word · lines_per_row · P_conflict(T) · L(T)
//! P_conflict(T) = 1 - (1 - m2)^(T-1)        (any of T-1 peers on my row)
//! L(T) = same-socket latency, or the cross-socket latency once the
//!        thread count spills over one socket
//! throughput(T) = T / s(T)
//! ```
//!
//! The paper's two effects drop out of the arithmetic:
//! * the scalar scheme updates per PAIR (2c·(1+K) row-writes per word),
//!   so its stall term is ~(1+K)× larger than the GEMM scheme's, which
//!   writes each touched row once per window;
//! * crossing the socket raises L(T), producing the sub-linear bend at
//!   T > cores/socket that Fig. 3 shows for both schemes.

use super::arch::MachineSpec;

/// Update-traffic profile of one training scheme.
#[derive(Clone, Copy, Debug)]
pub struct SchemeCost {
    /// Model-row writes per trained word.
    pub updates_per_word: f64,
    /// Single-thread words/sec (calibrated: measured or paper anchor).
    pub words_per_sec_1t: f64,
    /// Fraction of a conflicted line transfer that stalls the pipeline.
    /// Calibrated per scheme against the paper's Fig. 3 anchor points
    /// (original: 1.6M w/s at 72T; ours: 5.8M; near-linear to one socket):
    /// fine-grained per-pair updates expose nearly every conflict
    /// (scalar), while GEMM-block updates amortise ownership transfer
    /// over the whole window (lower exposure).
    pub exposure: f64,
}

impl SchemeCost {
    /// The original word2vec (Algorithm 1): every (input, sample) pair
    /// writes the sample row and accumulates the input row, i.e. per
    /// center word ≈ ctx·(1+K) output-row writes + ctx input-row writes.
    pub fn scalar(ctx: f64, negative: f64, w1: f64) -> Self {
        Self {
            updates_per_word: ctx * (negative + 1.0) + ctx,
            words_per_sec_1t: w1,
            exposure: 0.14,
        }
    }

    /// BIDMach's level-2 scheme: per vector op one output-row write +
    /// ctx input-row writes, (1+K) vector ops per window.
    pub fn bidmach(ctx: f64, negative: f64, w1: f64) -> Self {
        Self {
            updates_per_word: (negative + 1.0) * (1.0 + ctx) / 2.0,
            words_per_sec_1t: w1,
            exposure: 0.11,
        }
    }

    /// The paper's GEMM scheme: each touched row written ONCE per window:
    /// ctx input rows + (1+K) output rows per center word.
    pub fn gemm(ctx: f64, negative: f64, w1: f64) -> Self {
        Self {
            updates_per_word: ctx + (negative + 1.0),
            words_per_sec_1t: w1,
            exposure: 0.08,
        }
    }
}

/// The machine-level coherence model.
#[derive(Clone, Debug)]
pub struct CoherenceModel {
    pub machine: MachineSpec,
    /// EFFECTIVE collision mass of the row-update distribution: Σ p² of
    /// the update distribution, inflated by the window of vulnerability
    /// (a line stays exposed for many accesses) and false sharing.
    /// Calibrated constant; `collision_mass_from_counts` gives the raw
    /// lower bound and its vocabulary-size trend.
    pub collision_mass: f64,
    /// Cache lines per model row (D·4 / 64).
    pub lines_per_row: f64,
}

impl CoherenceModel {
    pub fn new(machine: MachineSpec, collision_mass: f64, dim: usize) -> Self {
        Self {
            machine,
            collision_mass,
            lines_per_row: (dim as f64 * 4.0 / 64.0).max(1.0),
        }
    }

    /// Collision mass of a unigram^power distribution from vocab counts.
    pub fn collision_mass_from_counts(counts: &[u64], power: f64) -> f64 {
        let pow: Vec<f64> = counts.iter().map(|&c| (c as f64).powf(power)).collect();
        let sum: f64 = pow.iter().sum();
        pow.iter().map(|p| (p / sum) * (p / sum)).sum()
    }

    /// Predicted aggregate words/sec at `threads`.
    pub fn throughput(&self, cost: &SchemeCost, threads: usize) -> f64 {
        if threads == 0 {
            return 0.0;
        }
        let t = threads as f64;
        let s1 = 1.0 / cost.words_per_sec_1t;
        // Conflict probability against T-1 peers.
        let p_conf = 1.0 - (1.0 - self.collision_mass).powf(t - 1.0);
        // Latency: same-socket while threads fit one socket (one thread
        // per core first, the usual pinning), cross-socket beyond.
        let lat_ns = if threads <= self.machine.cores_per_socket() {
            self.machine.coh_ns_same
        } else {
            self.machine.coh_ns_cross
        };
        let stall = cost.updates_per_word
            * self.lines_per_row
            * p_conf
            * lat_ns
            * 1e-9
            * cost.exposure;
        // SMT threads beyond physical cores add ~35% of a core each
        // (standard SMT yield on these workloads).
        let eff_t = if threads <= self.machine.cores {
            t
        } else {
            self.machine.cores as f64
                + (t - self.machine.cores as f64) * 0.35
        };
        eff_t / (s1 + stall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::arch::broadwell;

    fn zipf_mass(v: usize, power: f64) -> f64 {
        let counts: Vec<u64> = (1..=v).map(|r| (1e9 / r as f64) as u64).collect();
        CoherenceModel::collision_mass_from_counts(&counts, power)
    }

    #[test]
    fn collision_mass_drops_with_vocab_size() {
        let m_small = zipf_mass(50_000, 0.75);
        let m_large = zipf_mass(1_000_000, 0.75);
        assert!(m_small > m_large, "{m_small} vs {m_large}");
    }

    #[test]
    fn scalar_flattens_gemm_scales() {
        // The paper's Fig. 3 anchors: original 1.6M w/s at 72T, ours
        // 5.8M, ratio 3.6×, near-linear gemm within one socket.
        let model = CoherenceModel::new(broadwell(), 0.05, 300);
        let scalar = SchemeCost::scalar(5.0, 5.0, 70_000.0);
        let gemm = SchemeCost::gemm(5.0, 5.0, 182_000.0);

        let eff = |c: &SchemeCost, t: usize| {
            model.throughput(c, t) / (model.throughput(c, 1) * t as f64)
        };
        let w_s72 = model.throughput(&scalar, 72);
        let w_g72 = model.throughput(&gemm, 72);
        assert!((1.2e6..2.0e6).contains(&w_s72), "scalar72 {w_s72}");
        assert!((4.8e6..6.8e6).contains(&w_g72), "gemm72 {w_g72}");
        let ratio = w_g72 / w_s72;
        assert!((3.0..4.2).contains(&ratio), "72T ratio {ratio}");
        // Scalar: strong efficiency loss at 72 threads.
        assert!(eff(&scalar, 72) < 0.45, "scalar eff {}", eff(&scalar, 72));
        // GEMM: near-linear within one socket (18 cores).
        assert!(eff(&gemm, 18) > 0.85, "gemm eff18 {}", eff(&gemm, 18));
        assert!(
            eff(&gemm, 36) > eff(&scalar, 36) + 0.15,
            "gemm must out-scale scalar at 36T: {} vs {}",
            eff(&gemm, 36),
            eff(&scalar, 36)
        );
    }

    #[test]
    fn update_counts_ordering() {
        // Per-word update traffic: scalar > bidmach > gemm.
        let s = SchemeCost::scalar(5.0, 5.0, 1.0).updates_per_word;
        let b = SchemeCost::bidmach(5.0, 5.0, 1.0).updates_per_word;
        let g = SchemeCost::gemm(5.0, 5.0, 1.0).updates_per_word;
        assert!(s > b && b > g, "s={s} b={b} g={g}");
    }

    #[test]
    fn throughput_monotone_in_threads_within_socket() {
        let model = CoherenceModel::new(broadwell(), 1e-4, 300);
        let gemm = SchemeCost::gemm(5.0, 5.0, 100_000.0);
        let mut prev = 0.0;
        for t in 1..=18 {
            let w = model.throughput(&gemm, t);
            assert!(w > prev, "t={t}");
            prev = w;
        }
    }
}
