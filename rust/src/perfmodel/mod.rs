//! Calibrated performance model — the substitute for the paper's 36-core
//! Broadwell / 68-core KNL machines and 32-node clusters (this box has
//! one vCPU; DESIGN.md §3).
//!
//! The paper's scaling claims are, at bottom, arithmetic about (a) how many
//! model updates each scheme performs per trained word and (b) what each
//! update costs when other threads/nodes contend for the same cache lines
//! or NIC.  This module implements exactly that arithmetic:
//!
//! * [`arch`]    — machine descriptors for the paper's testbeds;
//! * [`cache`]   — the Hogwild coherence-stall model (update rates ×
//!   collision probability × line-transfer latency);
//! * [`network`] — the distributed sync-cost model (sub-model bytes/round
//!   over a finite-bandwidth fabric);
//! * [`simulate`]— the Fig 3 / Fig 4 curve generators, calibrated against
//!   REAL single-thread throughput measured on this box ([`calibrate`]).
//!
//! What is real vs. modelled is stated per bench in EXPERIMENTS.md.

pub mod arch;
pub mod cache;
pub mod calibrate;
pub mod network;
pub mod simulate;

pub use arch::MachineSpec;
pub use cache::{CoherenceModel, SchemeCost};
pub use calibrate::Calibration;
pub use simulate::{fig3_series, fig4_series, ScalingPoint};
