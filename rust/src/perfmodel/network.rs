//! Distributed sync-cost model (paper Sec. III-E, Fig. 4, Table V).
//!
//! Per sync round a node moves `2·(N-1)/N × payload` bytes (ring
//! allreduce) where the payload is the due sub-model rows × 2 matrices ×
//! D × 4 bytes.  A node syncing every `interval` words at per-node
//! compute rate `w_node` spends a fraction of its time on the wire;
//! cluster throughput is
//!
//! ```text
//! W(N) = N · w_node · (1 - sync_frac(N))           (synchronous rounds)
//! sync_frac = t_round / (t_round + interval / w_node)
//! t_round   = wire_bytes / bw + latency
//! ```
//!
//! with the paper's twist that `interval` SHRINKS as N grows (they raise
//! sync frequency to hold accuracy), which is what bends Fig. 4 sub-linear
//! at 32 BDW / 16 KNL nodes.

use super::arch::FabricSpec;
use crate::dist::sync::SyncPolicy;

/// Average payload bytes per sync round for a policy over `rounds` rounds
/// (tiers have different cadences, so we average).
pub fn avg_round_payload(policy: &SyncPolicy, vocab: usize, dim: usize, rounds: u32) -> f64 {
    let rounds = rounds.max(1);
    let mut total_rows = 0u64;
    for r in 1..=rounds {
        total_rows += policy
            .rows_due(vocab, r)
            .iter()
            .map(|x| x.len() as u64)
            .sum::<u64>();
    }
    // ×2 matrices × D × 4 bytes
    (total_rows as f64 / rounds as f64) * 2.0 * dim as f64 * 4.0
}

/// Cluster throughput at N nodes.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    pub fabric: FabricSpec,
    /// Per-node compute rate, words/sec (from the coherence model at the
    /// node's full thread count).
    pub node_words_per_sec: f64,
    pub vocab: usize,
    pub dim: usize,
}

impl ClusterModel {
    /// Seconds per sync round at N nodes for the given payload.
    pub fn round_secs(&self, n: usize, payload_bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let wire = 2.0 * (n as f64 - 1.0) / n as f64 * payload_bytes;
        wire / (self.fabric.bw_gbs * 1e9) + self.fabric.latency_us * 1e-6
    }

    /// Aggregate words/sec at N nodes under `policy` with per-node
    /// `interval` words between rounds.
    pub fn throughput(&self, n: usize, policy: &SyncPolicy, interval: u64) -> f64 {
        let payload = avg_round_payload(policy, self.vocab, self.dim, 64);
        let t_round = self.round_secs(n, payload);
        let t_compute = interval as f64 / self.node_words_per_sec;
        let frac = t_round / (t_round + t_compute);
        n as f64 * self.node_words_per_sec * (1.0 - frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::arch::fdr_infiniband;

    fn model() -> ClusterModel {
        ClusterModel {
            fabric: fdr_infiniband(),
            node_words_per_sec: 5.8e6, // paper's BDW single-node rate
            vocab: 1_115_011,
            dim: 300,
        }
    }

    #[test]
    fn submodel_payload_much_smaller_than_full() {
        let full = avg_round_payload(&SyncPolicy::Full, 1_115_011, 300, 64);
        let sub =
            avg_round_payload(&SyncPolicy::submodel_default(), 1_115_011, 300, 64);
        // Full model is ~2.5 GB; sub-model must be way below.
        assert!((2.0e9..3.0e9).contains(&full), "full={full}");
        assert!(sub < full * 0.2, "sub={sub} full={full}");
    }

    #[test]
    fn full_sync_kills_scaling_submodel_preserves_it() {
        let m = model();
        let interval = crate::dist::node::DistConfig::for_nodes(4).sync_interval;
        let w_full = m.throughput(4, &SyncPolicy::Full, interval);
        let w_sub = m.throughput(4, &SyncPolicy::submodel_default(), interval);
        let ideal = 4.0 * m.node_words_per_sec;
        assert!(w_sub > 0.8 * ideal, "sub-model eff {}", w_sub / ideal);
        assert!(w_full < 0.5 * ideal, "full eff {}", w_full / ideal);
        // Paper Table V anchor: 4 BDW nodes ≈ 20M words/s.
        assert!((1.6e7..2.4e7).contains(&w_sub), "4-node {w_sub}");
    }

    #[test]
    fn scaling_bends_when_interval_shrinks() {
        // Paper Sec. IV-C: higher sync frequency at 32 nodes costs
        // efficiency, but throughput still exceeds 100M words/s (Table V).
        let m = model();
        let pol = SyncPolicy::submodel_default();
        let iv = |n: usize| crate::dist::node::DistConfig::for_nodes(n).sync_interval;
        let eff = |n: usize| {
            m.throughput(n, &pol, iv(n)) / (n as f64 * m.node_words_per_sec)
        };
        assert!(eff(32) < eff(8), "bend missing: {} vs {}", eff(32), eff(8));
        let w32 = m.throughput(32, &pol, iv(32));
        assert!((0.8e8..1.8e8).contains(&w32), "32-node {w32}");
    }

    #[test]
    fn single_node_no_sync_cost() {
        let m = model();
        let w = m.throughput(1, &SyncPolicy::Full, 100_000);
        assert!((w - m.node_words_per_sec).abs() < 1.0);
    }
}
