//! Distributed sync-cost model (paper Sec. III-E, Fig. 4, Table V).
//!
//! Per sync round a node moves `2·(N-1)/N × payload` bytes (ring
//! allreduce) where the payload is the due sub-model rows × 2 matrices ×
//! D × 4 bytes.  A node syncing every `interval` words at per-node
//! compute rate `w_node` spends a fraction of its time on the wire;
//! cluster throughput is
//!
//! ```text
//! W(N) = N · w_node · (1 - sync_frac(N))           (synchronous rounds)
//! sync_frac = t_round / (t_round + interval / w_node)
//! t_round   = wire_bytes / bw + latency
//! ```
//!
//! with the paper's twist that `interval` SHRINKS as N grows (they raise
//! sync frequency to hold accuracy), which is what bends Fig. 4 sub-linear
//! at 32 BDW / 16 KNL nodes.
//!
//! Two collectives are modelled.  [`Collective::RingAllreduce`] is the
//! paper's idealized MPI cost (`2·(N-1)/N × payload` per node).
//! [`Collective::GatherScatter`] is what `dist::net` actually RUNS: a
//! gather-circulate of every origin's full due block (`(N-1) × payload`
//! per node) plus a scatter of the per-owner means (`(N-1)/N × payload`),
//! which buys BITWISE parity with thread mode at `(N+1)/2`× the ring's
//! traffic.  The analytic payload model here is calibrated against the
//! transport's exact frame-level predictor
//! (`dist::net::gather_scatter_wire_bytes`, which measured
//! `NetStats::slice_bytes_sent` must equal) — pinned within header
//! overhead by `analytic_model_matches_frame_level_predictor`, and
//! against live counters by `benches/microbench.rs --bench dist-ring`.

use super::arch::FabricSpec;
use crate::dist::sync::SyncPolicy;

/// Which allreduce implementation a cost estimate is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Idealized bandwidth-optimal ring allreduce (the paper's MPI
    /// assumption; what thread mode's `wire_bytes` accounts).
    RingAllreduce,
    /// The TCP transport's parity-exact gather + owner-average + scatter.
    GatherScatter,
}

/// Per-node wire bytes for ONE round moving `payload_bytes` of due rows.
pub fn node_round_bytes(collective: Collective, n: usize, payload_bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    match collective {
        Collective::RingAllreduce => 2.0 * (nf - 1.0) / nf * payload_bytes,
        Collective::GatherScatter => (nf - 1.0) * payload_bytes * (1.0 + 1.0 / nf),
    }
}

/// Average payload bytes per sync round for a policy over `rounds` rounds
/// (tiers have different cadences, so we average).
pub fn avg_round_payload(policy: &SyncPolicy, vocab: usize, dim: usize, rounds: u32) -> f64 {
    let rounds = rounds.max(1);
    let mut total_rows = 0u64;
    for r in 1..=rounds {
        total_rows += policy
            .rows_due(vocab, r)
            .iter()
            .map(|x| x.len() as u64)
            .sum::<u64>();
    }
    // ×2 matrices × D × 4 bytes
    (total_rows as f64 / rounds as f64) * 2.0 * dim as f64 * 4.0
}

/// Cluster throughput at N nodes.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    pub fabric: FabricSpec,
    /// Per-node compute rate, words/sec (from the coherence model at the
    /// node's full thread count).
    pub node_words_per_sec: f64,
    pub vocab: usize,
    pub dim: usize,
}

impl ClusterModel {
    /// Seconds per sync round at N nodes for the given payload (paper's
    /// ring-allreduce assumption; Fig. 4 / Table V use this).
    pub fn round_secs(&self, n: usize, payload_bytes: f64) -> f64 {
        self.round_secs_for(Collective::RingAllreduce, n, payload_bytes)
    }

    /// Seconds per sync round under a specific collective.
    pub fn round_secs_for(&self, c: Collective, n: usize, payload_bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let wire = node_round_bytes(c, n, payload_bytes);
        wire / (self.fabric.bw_gbs * 1e9) + self.fabric.latency_us * 1e-6
    }

    /// Aggregate words/sec at N nodes under `policy` with per-node
    /// `interval` words between rounds.
    pub fn throughput(&self, n: usize, policy: &SyncPolicy, interval: u64) -> f64 {
        self.throughput_for(Collective::RingAllreduce, n, policy, interval)
    }

    /// [`throughput`](Self::throughput) under a specific collective —
    /// `GatherScatter` answers "what does bitwise parity cost on this
    /// fabric?".
    pub fn throughput_for(
        &self,
        c: Collective,
        n: usize,
        policy: &SyncPolicy,
        interval: u64,
    ) -> f64 {
        let payload = avg_round_payload(policy, self.vocab, self.dim, 64);
        let t_round = self.round_secs_for(c, n, payload);
        let t_compute = interval as f64 / self.node_words_per_sec;
        let frac = t_round / (t_round + t_compute);
        n as f64 * self.node_words_per_sec * (1.0 - frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::arch::fdr_infiniband;

    fn model() -> ClusterModel {
        ClusterModel {
            fabric: fdr_infiniband(),
            node_words_per_sec: 5.8e6, // paper's BDW single-node rate
            vocab: 1_115_011,
            dim: 300,
        }
    }

    #[test]
    fn submodel_payload_much_smaller_than_full() {
        let full = avg_round_payload(&SyncPolicy::Full, 1_115_011, 300, 64);
        let sub =
            avg_round_payload(&SyncPolicy::submodel_default(), 1_115_011, 300, 64);
        // Full model is ~2.5 GB; sub-model must be way below.
        assert!((2.0e9..3.0e9).contains(&full), "full={full}");
        assert!(sub < full * 0.2, "sub={sub} full={full}");
    }

    #[test]
    fn full_sync_kills_scaling_submodel_preserves_it() {
        let m = model();
        let interval = crate::dist::node::DistConfig::for_nodes(4).sync_interval;
        let w_full = m.throughput(4, &SyncPolicy::Full, interval);
        let w_sub = m.throughput(4, &SyncPolicy::submodel_default(), interval);
        let ideal = 4.0 * m.node_words_per_sec;
        assert!(w_sub > 0.8 * ideal, "sub-model eff {}", w_sub / ideal);
        assert!(w_full < 0.5 * ideal, "full eff {}", w_full / ideal);
        // Paper Table V anchor: 4 BDW nodes ≈ 20M words/s.
        assert!((1.6e7..2.4e7).contains(&w_sub), "4-node {w_sub}");
    }

    #[test]
    fn scaling_bends_when_interval_shrinks() {
        // Paper Sec. IV-C: higher sync frequency at 32 nodes costs
        // efficiency, but throughput still exceeds 100M words/s (Table V).
        let m = model();
        let pol = SyncPolicy::submodel_default();
        let iv = |n: usize| crate::dist::node::DistConfig::for_nodes(n).sync_interval;
        let eff = |n: usize| {
            m.throughput(n, &pol, iv(n)) / (n as f64 * m.node_words_per_sec)
        };
        assert!(eff(32) < eff(8), "bend missing: {} vs {}", eff(32), eff(8));
        let w32 = m.throughput(32, &pol, iv(32));
        assert!((0.8e8..1.8e8).contains(&w32), "32-node {w32}");
    }

    #[test]
    fn single_node_no_sync_cost() {
        let m = model();
        let w = m.throughput(1, &SyncPolicy::Full, 100_000);
        assert!((w - m.node_words_per_sec).abs() < 1.0);
    }

    /// Parity costs `(N+1)/2`× the idealized ring's traffic — exactly.
    #[test]
    fn gather_scatter_premium_is_half_n_plus_one() {
        for n in 2..=8 {
            let p = 1.0e6;
            let gs = node_round_bytes(Collective::GatherScatter, n, p);
            let ring = node_round_bytes(Collective::RingAllreduce, n, p);
            let premium = (n as f64 + 1.0) / 2.0;
            assert!(
                (gs / ring - premium).abs() < 1e-9,
                "n={n}: {} vs {premium}",
                gs / ring
            );
        }
        assert_eq!(node_round_bytes(Collective::GatherScatter, 1, 1.0e6), 0.0);
    }

    /// The analytic per-node cost matches the transport's exact
    /// frame-level predictor (ranks averaged) to within frame-header
    /// overhead — the analytic model and the wire counters describe the
    /// SAME collective.
    #[test]
    fn analytic_model_matches_frame_level_predictor() {
        use crate::dist::net::gather_scatter_wire_bytes;
        let (vocab, dim) = (10_000usize, 128usize);
        for n in [2usize, 3, 5] {
            let policy = SyncPolicy::submodel_for_vocab(vocab);
            let due = policy.rows_due(vocab, 1);
            let rows: u64 = due.iter().map(|r| r.len() as u64).sum();
            let payload = rows as f64 * 2.0 * dim as f64 * 4.0;
            let analytic = node_round_bytes(Collective::GatherScatter, n, payload);
            let exact_avg = (0..n)
                .map(|rank| gather_scatter_wire_bytes(&due, n, rank, dim) as f64)
                .sum::<f64>()
                / n as f64;
            // Headers add 28 bytes per ≤16 KiB chunk ≈ 0.17%; allow 1%.
            let ratio = exact_avg / analytic;
            assert!(
                (1.0..1.01).contains(&ratio),
                "n={n}: exact {exact_avg} vs analytic {analytic} (ratio {ratio})"
            );
        }
    }

    /// On a fat fabric the parity premium barely dents sub-model
    /// scaling; under full sync it's ruinous — the reason `--policy sub`
    /// stays the distributed default.
    #[test]
    fn parity_premium_is_tolerable_under_submodel_sync() {
        let m = model();
        let interval = crate::dist::node::DistConfig::for_nodes(4).sync_interval;
        let pol = SyncPolicy::submodel_default();
        let ring = m.throughput_for(Collective::RingAllreduce, 4, &pol, interval);
        let gs = m.throughput_for(Collective::GatherScatter, 4, &pol, interval);
        assert!(gs < ring, "gather-scatter can't beat the ring");
        assert!(gs > 0.85 * ring, "sub-model premium too steep: {}", gs / ring);
        let gs_full = m.throughput_for(Collective::GatherScatter, 4, &SyncPolicy::Full, interval);
        assert!(gs_full < 0.5 * ring, "full-sync parity should be ruinous");
    }
}
