//! Calibration: measure REAL single-thread throughput of each back-end on
//! this box, then anchor the coherence/network models with it.
//!
//! The measured quantity is the per-word compute cost `s1` of each scheme
//! — the only term of the scaling model that depends on code quality
//! rather than on machine constants.  The measured RATIO between schemes
//! (ours / original ≈ 2.6× at one thread, Fig. 3) is the paper claim this
//! box can genuinely verify; the multi-thread/multi-node curves project
//! that ratio through the models.

use std::path::Path;

use crate::config::{Backend as BackendKind, TrainConfig};
use crate::corpus::vocab::Vocab;
use crate::model::SharedModel;
use crate::train;

/// Measured single-thread rates (words/sec).
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    pub scalar_w1: f64,
    pub bidmach_w1: f64,
    pub gemm_w1: f64,
    /// Optional: the AOT/PJRT path (None when artifacts are absent).
    pub pjrt_w1: Option<f64>,
}

impl Calibration {
    /// Train each back-end single-threaded on `corpus` and record words/sec.
    pub fn measure(
        cfg_base: &TrainConfig,
        corpus: &Path,
        vocab: &Vocab,
        include_pjrt: bool,
    ) -> anyhow::Result<Self> {
        let mut rates = Vec::new();
        for backend in [
            BackendKind::Scalar,
            BackendKind::Bidmach,
            BackendKind::Gemm,
        ] {
            let mut cfg = cfg_base.clone();
            cfg.backend = backend;
            cfg.threads = 1;
            let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
            let out = train::train(&cfg, corpus, vocab, &model)?;
            rates.push(out.snapshot.words_per_sec());
        }
        let pjrt_w1 = if include_pjrt {
            let mut cfg = cfg_base.clone();
            cfg.backend = BackendKind::Pjrt;
            cfg.threads = 1;
            let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
            match train::train(&cfg, corpus, vocab, &model) {
                Ok(out) => Some(out.snapshot.words_per_sec()),
                Err(e) => {
                    eprintln!("pjrt calibration skipped: {e}");
                    None
                }
            }
        } else {
            None
        };
        Ok(Self {
            scalar_w1: rates[0],
            bidmach_w1: rates[1],
            gemm_w1: rates[2],
            pjrt_w1,
        })
    }

    /// The headline single-thread speedup (paper: 2.6×).
    pub fn gemm_over_scalar(&self) -> f64 {
        self.gemm_w1 / self.scalar_w1.max(1e-9)
    }

    /// Paper-anchored calibration (used when measuring is too slow, e.g.
    /// in doc examples): the paper's 1-thread BDW rates, words/sec.
    pub fn paper_anchors() -> Self {
        // Fig. 3: original ≈ 70K words/s 1T (1.6M at 72T with flattening);
        // ours 2.6× that; BIDMach between (Table III single-node ratios).
        Self {
            scalar_w1: 70_000.0,
            bidmach_w1: 110_000.0,
            gemm_w1: 182_000.0,
            pjrt_w1: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{LatentModel, SyntheticConfig};

    #[test]
    fn measures_all_backends() {
        let mut scfg = SyntheticConfig::test_tiny();
        scfg.tokens = 20_000;
        let lm = LatentModel::new(scfg);
        let path = std::env::temp_dir().join(format!(
            "pw2v_calib_{}.txt",
            std::process::id()
        ));
        lm.write_corpus(&path).unwrap();
        let vocab = Vocab::build_from_file(&path, 1).unwrap();
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let c = Calibration::measure(&cfg, &path, &vocab, false).unwrap();
        assert!(c.scalar_w1 > 0.0 && c.bidmach_w1 > 0.0 && c.gemm_w1 > 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn paper_anchors_ratio() {
        let c = Calibration::paper_anchors();
        assert!((c.gemm_over_scalar() - 2.6).abs() < 0.1);
    }
}
