//! Machine descriptors for the paper's testbeds (Sec. IV-A) plus the GPU
//! comparison points quoted from BIDMach [10].

/// A shared-memory machine.
#[derive(Clone, Debug)]
pub struct MachineSpec {
    pub name: &'static str,
    /// Physical cores (all sockets).
    pub cores: usize,
    /// Hardware threads per core (SMT/HT).
    pub smt: usize,
    pub sockets: usize,
    pub freq_ghz: f64,
    /// f32 FLOPs per cycle per core (FMA × vector width × ports).
    pub flops_per_cycle: f64,
    /// Aggregate memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Same-socket cache-line transfer latency, ns.
    pub coh_ns_same: f64,
    /// Cross-socket line transfer latency, ns.
    pub coh_ns_cross: f64,
}

impl MachineSpec {
    /// Peak single-precision TFLOP/s.
    pub fn peak_tflops(&self) -> f64 {
        self.cores as f64 * self.freq_ghz * self.flops_per_cycle / 1e3
    }

    pub fn threads(&self) -> usize {
        self.cores * self.smt
    }

    pub fn cores_per_socket(&self) -> usize {
        self.cores / self.sockets
    }
}

/// Dual-socket Haswell E5-2680 v3 (paper Table III).
pub fn haswell() -> MachineSpec {
    MachineSpec {
        name: "Intel HSW (Xeon E5-2680 v3)",
        cores: 24,
        smt: 2,
        sockets: 2,
        freq_ghz: 2.5,
        flops_per_cycle: 32.0, // AVX2 FMA: 2×8×2
        mem_bw_gbs: 136.0,
        coh_ns_same: 60.0,
        coh_ns_cross: 180.0,
    }
}

/// Dual-socket Broadwell E5-2697 v4 (the paper's main machine: 36 cores).
pub fn broadwell() -> MachineSpec {
    MachineSpec {
        name: "Intel BDW (Xeon E5-2697 v4)",
        cores: 36,
        smt: 2,
        sockets: 2,
        freq_ghz: 2.3,
        flops_per_cycle: 32.0,
        mem_bw_gbs: 154.0,
        coh_ns_same: 60.0,
        coh_ns_cross: 180.0,
    }
}

/// Knights Landing Xeon Phi, 68 cores (single socket, MCDRAM).
pub fn knl() -> MachineSpec {
    MachineSpec {
        name: "Intel KNL (Xeon Phi)",
        cores: 68,
        smt: 4,
        sockets: 1,
        freq_ghz: 1.4,
        flops_per_cycle: 64.0, // AVX-512 FMA ×2
        mem_bw_gbs: 400.0,     // MCDRAM
        coh_ns_same: 120.0,    // mesh is slower per hop
        coh_ns_cross: 120.0,
    }
}

/// GPU throughput points quoted from BIDMach [10] (words/sec on the 1B
/// benchmark) — the paper quotes these rather than re-running them.
pub fn bidmach_gpu_points() -> Vec<(&'static str, f64)> {
    vec![
        ("Nvidia K40 (BIDMach)", 4.2e6),
        ("Nvidia GeForce Titan-X (BIDMach)", 8.5e6),
    ]
}

/// Cluster fabric descriptor (Sec. III-E).
#[derive(Clone, Debug)]
pub struct FabricSpec {
    pub name: &'static str,
    /// Per-node bandwidth, GB/s.
    pub bw_gbs: f64,
    /// Per-collective latency, µs.
    pub latency_us: f64,
}

/// FDR InfiniBand (Broadwell cluster).
pub fn fdr_infiniband() -> FabricSpec {
    FabricSpec {
        name: "FDR InfiniBand",
        bw_gbs: 6.8,
        latency_us: 3.0,
    }
}

/// Intel Omni-Path (KNL cluster).
pub fn omnipath() -> FabricSpec {
    FabricSpec {
        name: "Intel OPA",
        bw_gbs: 12.3,
        latency_us: 2.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_flops_sane() {
        // BDW: 36 × 2.3 × 32 ≈ 2.65 TFLOP/s (paper: Titan-X has ~3× BDW).
        let bdw = broadwell().peak_tflops();
        assert!((2.0..3.5).contains(&bdw), "bdw={bdw}");
        let knl = knl().peak_tflops();
        assert!(knl > bdw, "knl should exceed bdw");
    }

    #[test]
    fn threads_and_sockets() {
        assert_eq!(broadwell().threads(), 72);
        assert_eq!(broadwell().cores_per_socket(), 18);
        assert_eq!(knl().threads(), 272);
    }
}
