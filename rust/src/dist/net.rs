//! TCP ring transport for multi-process distributed training.
//!
//! std-only (raw sockets, no new crates — the PR-3/PR-4 discipline).
//! N processes, one per rank, form a unidirectional ring: rank k writes
//! to rank (k+1) % n and reads from rank (k-1+n) % n.  Everything on
//! the wire is a length-prefixed FRAME:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "PWRG"
//!      4     2  version (little-endian u16, currently 1)
//!      6     1  frame type (Hello|Status|Slice|AvgSlice|Heartbeat|Abort)
//!      7     1  origin rank
//!      8     4  sync round the frame belongs to (u32)
//!     12     4  payload length in bytes (u32)
//!     16     8  FNV-1a checksum of the payload (u64)
//!     24     …  payload
//! ```
//!
//! Robustness model:
//!
//! * **Ring formation** — every rank binds its listener FIRST, then
//!   connects to its successor with bounded exponential backoff (the
//!   connect succeeds as soon as the peer has bound, via the kernel
//!   backlog), then accepts its predecessor.  A `Hello` exchange checks
//!   ring wiring, rank count and the config fingerprint before any
//!   training traffic.
//! * **Failure detection** — a heartbeat thread sends a `Heartbeat`
//!   frame to the successor every `heartbeat_ms`; reads carry a
//!   deadline of `io_timeout_ms` that any complete incoming frame
//!   resets.  A dead peer (closed socket) fails the read instantly; a
//!   wedged peer (alive but silent — see `PW2V_FAULT stall-after`)
//!   trips the deadline.
//! * **Failure propagation** — a failing rank best-effort sends an
//!   `Abort` frame carrying a reason; receivers forward it around the
//!   ring and return an error, so every survivor exits with a
//!   diagnostic instead of hanging in allreduce.
//! * **Deadlock freedom** — every rank runs send-then-recv in the same
//!   ring step, so a block larger than the kernel socket buffers would
//!   wedge all ranks in `write`.  Block transfers are therefore split
//!   into ≤[`CHUNK_PAYLOAD`]-byte frames with send/recv interleaved per
//!   chunk; both sides compute the expected byte counts locally (same
//!   due ranges, same partition rule), so chunks need no extra framing.
//!
//! The allreduce ([`Ring::allreduce_rows`]) is gather-circulate +
//! scatter rather than a true ring-allreduce: reduction arithmetic runs
//! only on the OWNER of a row (`row % n == rank`), accumulating the n
//! per-origin contributions in origin order with the same
//! `axpy`-into-scratch loop as the in-process collective
//! (`sync::average_row`).  That costs more bandwidth than ring
//! allreduce ((n-1)·P + (n-1)/n·P vs 2·(n-1)/n·P per rank) but makes
//! the result BITWISE IDENTICAL to thread mode — the acceptance
//! criterion this transport is built around.  `perfmodel/network.rs`
//! carries the analytic cost model; [`gather_scatter_wire_bytes`] is
//! the exact per-rank byte predictor that measured [`NetStats`] are
//! checked against.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dist::fault::FaultSpec;
use crate::linalg::vecops::axpy;
use crate::model::SharedModel;
use crate::util::fnv::fnv1a;

const MAGIC: [u8; 4] = *b"PWRG";
const VERSION: u16 = 1;
/// Frame header size on the wire.
pub const HEADER_BYTES: usize = 24;
/// Largest payload a single frame carries.  Must stay safely below the
/// smallest kernel socket buffer so one in-flight chunk per direction
/// can never wedge the ring (see module docs).
pub const CHUNK_PAYLOAD: usize = 16 * 1024;
/// Receive-side sanity bound on the header's length field.
const MAX_PAYLOAD: usize = 1 << 20;

/// Process exit code for `PW2V_FAULT kill-after=N`.
pub const EXIT_FAULT_KILL: i32 = 42;
/// Process exit code for `PW2V_FAULT torn-frame=N`.
pub const EXIT_FAULT_TORN: i32 = 43;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameType {
    /// Ring-formation handshake: `[nranks u32][fingerprint u64]`.
    Hello = 1,
    /// Small u64-array circulation (stop decision, resume negotiation).
    Status = 2,
    /// Gather-phase model block (raw replica rows).
    Slice = 3,
    /// Scatter-phase model block (averaged owner rows).
    AvgSlice = 4,
    /// Liveness beacon; resets the receiver's read deadline, carries no
    /// payload, and is invisible to fault frame counting.
    Heartbeat = 5,
    /// Failure propagation: payload is a UTF-8 reason.
    Abort = 6,
}

impl FrameType {
    fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => FrameType::Hello,
            2 => FrameType::Status,
            3 => FrameType::Slice,
            4 => FrameType::AvgSlice,
            5 => FrameType::Heartbeat,
            6 => FrameType::Abort,
            other => anyhow::bail!("unknown frame type {other} (protocol corruption)"),
        })
    }
}

/// One decoded frame.
pub struct Frame {
    pub ftype: FrameType,
    pub origin: u8,
    pub round: u32,
    pub payload: Vec<u8>,
}

/// `--dist tcp:<rank>@addr0,addr1,...` — this process is `rank`;
/// `addrs[k]` is where rank k listens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RingSpec {
    pub rank: usize,
    pub addrs: Vec<String>,
}

impl RingSpec {
    /// Parse a ring spec; a leading `tcp:` is accepted and ignored so
    /// callers may pass the full `--dist` value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.strip_prefix("tcp:").unwrap_or(s);
        let (rank, addrs) = s.split_once('@').ok_or_else(|| {
            anyhow::anyhow!("ring spec '{s}': expected <rank>@addr0,addr1,...")
        })?;
        let rank: usize = rank
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("ring spec rank '{rank}': {e}"))?;
        let addrs: Vec<String> = addrs
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        anyhow::ensure!(!addrs.is_empty(), "ring spec '{s}': no addresses");
        anyhow::ensure!(
            rank < addrs.len(),
            "ring spec rank {rank} out of range for {} addresses",
            addrs.len()
        );
        anyhow::ensure!(addrs.len() <= 255, "ring spec: at most 255 ranks");
        Ok(Self { rank, addrs })
    }

    pub fn nranks(&self) -> usize {
        self.addrs.len()
    }
}

/// Transport tuning knobs (all CLI-overridable; defaults documented in
/// EXPERIMENTS.md §Distributed-TCP).
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Ring-formation budget: how long to retry connecting to the
    /// successor (exponential backoff 10ms → 500ms) and to wait for the
    /// predecessor to connect.
    pub connect_timeout_ms: u64,
    /// Read/write deadline per frame once the ring is up; a peer silent
    /// for this long is declared dead/wedged.
    pub io_timeout_ms: u64,
    /// Heartbeat period (must be well under `io_timeout_ms`).
    pub heartbeat_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            connect_timeout_ms: 15_000,
            io_timeout_ms: 10_000,
            heartbeat_ms: 300,
        }
    }
}

/// Measured transport counters for one rank (calibrates
/// `perfmodel/network.rs`; surfaced in `DistOutcome`).
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    pub frames_sent: u64,
    pub frames_recv: u64,
    /// Header + payload bytes, every frame type.
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    /// Header + payload bytes of Slice/AvgSlice frames only — the
    /// quantity [`gather_scatter_wire_bytes`] predicts exactly.
    pub slice_bytes_sent: u64,
    pub heartbeats_sent: u64,
}

/// Writing half of the successor connection, shared between the trainer
/// and the heartbeat thread behind one mutex (a frame is always written
/// under a single lock hold, so frames never interleave).
struct FrameWriter {
    stream: TcpStream,
    fault: Option<FaultSpec>,
    /// Data frames written so far (heartbeats excluded) — the counter
    /// `PW2V_FAULT` triggers key off, kept heartbeat-free so fault
    /// schedules are deterministic.
    data_frames: u64,
    frames_sent: u64,
    bytes_sent: u64,
    slice_bytes_sent: u64,
    heartbeats_sent: u64,
}

impl FrameWriter {
    fn send(&mut self, ftype: FrameType, origin: u8, round: u32, payload: &[u8]) -> anyhow::Result<()> {
        let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.push(ftype as u8);
        buf.push(origin);
        buf.extend_from_slice(&round.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&fnv1a(payload).to_le_bytes());
        buf.extend_from_slice(payload);

        if ftype != FrameType::Heartbeat {
            match self.fault {
                Some(FaultSpec::KillAfterFrames(n)) if self.data_frames >= n => {
                    eprintln!("PW2V_FAULT kill-after={n}: exiting now");
                    std::process::exit(EXIT_FAULT_KILL);
                }
                Some(FaultSpec::TornFrame(n)) if self.data_frames == n => {
                    // Crash mid-write: header plus half the payload.
                    let torn = HEADER_BYTES + payload.len() / 2;
                    let _ = self.stream.write_all(&buf[..torn]);
                    let _ = self.stream.flush();
                    eprintln!("PW2V_FAULT torn-frame={n}: wrote {torn} bytes, exiting");
                    std::process::exit(EXIT_FAULT_TORN);
                }
                Some(FaultSpec::StallAfterFrames(n)) if self.data_frames >= n => {
                    // Wedge while HOLDING the writer lock: the heartbeat
                    // thread blocks on the same mutex, so heartbeats stop
                    // and peers must detect us via the read deadline.
                    eprintln!("PW2V_FAULT stall-after={n}: stalling (lock held)");
                    loop {
                        std::thread::sleep(Duration::from_secs(3600));
                    }
                }
                _ => {}
            }
            self.data_frames += 1;
        }

        self.stream.write_all(&buf)?;
        self.frames_sent += 1;
        self.bytes_sent += buf.len() as u64;
        match ftype {
            FrameType::Slice | FrameType::AvgSlice => {
                self.slice_bytes_sent += buf.len() as u64;
            }
            FrameType::Heartbeat => self.heartbeats_sent += 1,
            _ => {}
        }
        Ok(())
    }
}

/// Reading half of the predecessor connection.
struct FrameReader {
    stream: TcpStream,
    io_timeout: Duration,
    frames_recv: u64,
    bytes_recv: u64,
}

impl FrameReader {
    /// Fill `buf` completely, tolerating short reads and poll timeouts,
    /// failing once `deadline` passes with nothing left to read.
    fn read_full(&mut self, buf: &mut [u8], deadline: Instant) -> anyhow::Result<()> {
        let mut filled = 0;
        while filled < buf.len() {
            match self.stream.read(&mut buf[filled..]) {
                Ok(0) => anyhow::bail!("peer closed the connection"),
                Ok(k) => filled += k,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "peer silent for {}ms (dead or wedged)",
                        self.io_timeout.as_millis()
                    );
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Read and validate one frame (any type).
    fn recv(&mut self) -> anyhow::Result<Frame> {
        let deadline = Instant::now() + self.io_timeout;
        let mut head = [0u8; HEADER_BYTES];
        self.read_full(&mut head, deadline)?;
        anyhow::ensure!(head[..4] == MAGIC, "bad frame magic (protocol corruption)");
        let version = u16::from_le_bytes(head[4..6].try_into().unwrap());
        anyhow::ensure!(
            version == VERSION,
            "frame version {version} (expected {VERSION})"
        );
        let ftype = FrameType::from_u8(head[6])?;
        let origin = head[7];
        let round = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let len = u32::from_le_bytes(head[12..16].try_into().unwrap()) as usize;
        anyhow::ensure!(len <= MAX_PAYLOAD, "frame length {len} exceeds protocol max");
        let want = u64::from_le_bytes(head[16..24].try_into().unwrap());
        let mut payload = vec![0u8; len];
        self.read_full(&mut payload, deadline)
            .map_err(|e| anyhow::anyhow!("truncated frame payload: {e}"))?;
        anyhow::ensure!(
            fnv1a(&payload) == want,
            "frame checksum mismatch (corrupt or torn frame)"
        );
        self.frames_recv += 1;
        self.bytes_recv += (HEADER_BYTES + len) as u64;
        Ok(Frame {
            ftype,
            origin,
            round,
            payload,
        })
    }
}

/// Established ring endpoint for one rank.
pub struct Ring {
    rank: usize,
    n: usize,
    writer: Arc<Mutex<FrameWriter>>,
    reader: FrameReader,
    hb_stop: Arc<AtomicBool>,
    hb_join: Option<std::thread::JoinHandle<()>>,
}

fn connect_retry(addr: &str, timeout: Duration) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(10);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                anyhow::ensure!(
                    Instant::now() + backoff < deadline,
                    "could not connect to successor {addr} within {}ms: {e}",
                    timeout.as_millis()
                );
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

fn accept_deadline(listener: &TcpListener, timeout: Duration) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                return Ok(s);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "predecessor did not connect within {}ms",
                    timeout.as_millis()
                );
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

impl Ring {
    /// Bind this rank's listener and form the ring.  `fingerprint`
    /// guards against mixed-config launches: all ranks must present the
    /// same value during the Hello exchange.
    pub fn establish(spec: &RingSpec, net: &NetConfig, fingerprint: u64) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(&spec.addrs[spec.rank])
            .map_err(|e| anyhow::anyhow!("rank {}: bind {}: {e}", spec.rank, spec.addrs[spec.rank]))?;
        Self::establish_on(listener, spec, net, fingerprint)
    }

    /// Form the ring over an already-bound listener (tests and benches
    /// bind `127.0.0.1:0` first to learn their ports).
    pub fn establish_on(
        listener: TcpListener,
        spec: &RingSpec,
        net: &NetConfig,
        fingerprint: u64,
    ) -> anyhow::Result<Self> {
        let rank = spec.rank;
        let n = spec.nranks();
        let connect_timeout = Duration::from_millis(net.connect_timeout_ms.max(1));
        let io_timeout = Duration::from_millis(net.io_timeout_ms.max(1));

        // Listener is bound (above or by the caller) BEFORE we connect
        // out, so every rank's connect finds every listener regardless
        // of launch order.
        let succ = &spec.addrs[(rank + 1) % n];
        let out = connect_retry(succ, connect_timeout)?;
        out.set_nodelay(true)?;
        out.set_write_timeout(Some(io_timeout))?;

        let inc = accept_deadline(&listener, connect_timeout)?;
        inc.set_nodelay(true)?;
        // Short poll quantum; recv loops re-check their own deadline.
        inc.set_read_timeout(Some(Duration::from_millis(100)))?;

        let mut writer = FrameWriter {
            stream: out,
            fault: FaultSpec::from_env()?,
            data_frames: 0,
            frames_sent: 0,
            bytes_sent: 0,
            slice_bytes_sent: 0,
            heartbeats_sent: 0,
        };
        let mut reader = FrameReader {
            stream: inc,
            io_timeout,
            frames_recv: 0,
            bytes_recv: 0,
        };

        // Hello exchange: wiring + config sanity before any training
        // traffic.
        let mut hello = Vec::with_capacity(12);
        hello.extend_from_slice(&(n as u32).to_le_bytes());
        hello.extend_from_slice(&fingerprint.to_le_bytes());
        writer.send(FrameType::Hello, rank as u8, 0, &hello)?;
        let f = reader.recv()?;
        anyhow::ensure!(
            f.ftype == FrameType::Hello,
            "rank {rank}: expected Hello, got {:?}",
            f.ftype
        );
        let expect_pred = (rank + n - 1) % n;
        anyhow::ensure!(
            f.origin as usize == expect_pred,
            "rank {rank}: predecessor claims rank {}, expected {expect_pred} (ring miswired)",
            f.origin
        );
        anyhow::ensure!(f.payload.len() == 12, "rank {rank}: malformed Hello");
        let peer_n = u32::from_le_bytes(f.payload[..4].try_into().unwrap()) as usize;
        anyhow::ensure!(
            peer_n == n,
            "rank {rank}: predecessor believes nranks={peer_n}, we have {n}"
        );
        let peer_fp = u64::from_le_bytes(f.payload[4..12].try_into().unwrap());
        anyhow::ensure!(
            peer_fp == fingerprint,
            "rank {rank}: config fingerprint mismatch with predecessor \
             (mixed binaries or flags across the ring?)"
        );

        let writer = Arc::new(Mutex::new(writer));
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb_join = {
            let writer = Arc::clone(&writer);
            let stop = Arc::clone(&hb_stop);
            let period = Duration::from_millis(net.heartbeat_ms.max(1));
            Some(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(period);
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                    if w.send(FrameType::Heartbeat, rank as u8, 0, &[]).is_err() {
                        // Successor is gone; the trainer will find out
                        // through its own send/recv errors.
                        break;
                    }
                }
            }))
        };

        Ok(Self {
            rank,
            n,
            writer,
            reader,
            hb_stop,
            hb_join,
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.n
    }

    fn send_frame(&self, ftype: FrameType, origin: u8, round: u32, payload: &[u8]) -> anyhow::Result<()> {
        self.writer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .send(ftype, origin, round, payload)
    }

    /// Receive the next DATA frame: heartbeats are skipped (each resets
    /// the deadline simply by arriving), aborts are forwarded around
    /// the ring and surfaced as errors.
    fn recv_data(&mut self) -> anyhow::Result<Frame> {
        loop {
            let f = self.reader.recv()?;
            match f.ftype {
                FrameType::Heartbeat => continue,
                FrameType::Abort => {
                    let reason = String::from_utf8_lossy(&f.payload).into_owned();
                    if f.origin as usize != self.rank {
                        // Forward so the whole ring learns; best-effort,
                        // the successor may already be gone.
                        let _ = self.send_frame(FrameType::Abort, f.origin, f.round, &f.payload);
                    }
                    anyhow::bail!("rank {} aborted: {reason}", f.origin);
                }
                _ => return Ok(f),
            }
        }
    }

    /// Best-effort failure propagation: send an `Abort` with a reason.
    /// Never fails — the caller is already on its error path.
    pub fn abort(&self, reason: &str) {
        let payload = reason.as_bytes();
        let capped = &payload[..payload.len().min(4096)];
        let _ = self.send_frame(FrameType::Abort, self.rank as u8, 0, capped);
    }

    /// Exchange one logical block per ring step: stream `out` (as
    /// origin `origin_out`) to the successor in ≤[`CHUNK_PAYLOAD`]
    /// chunks while collecting exactly `in_len` bytes of origin
    /// `origin_in` from the predecessor, interleaved chunk-by-chunk so
    /// the ring can never wedge on full socket buffers.
    fn exchange_raw(
        &mut self,
        ftype: FrameType,
        round: u32,
        origin_out: usize,
        out: &[u8],
        origin_in: usize,
        in_len: usize,
    ) -> anyhow::Result<Vec<u8>> {
        let mut got = Vec::with_capacity(in_len);
        let mut sent = 0;
        while sent < out.len() || got.len() < in_len {
            if sent < out.len() {
                let end = (sent + CHUNK_PAYLOAD).min(out.len());
                self.send_frame(ftype, origin_out as u8, round, &out[sent..end])?;
                sent = end;
            }
            if got.len() < in_len {
                let f = self.recv_data()?;
                anyhow::ensure!(
                    f.ftype == ftype && f.origin as usize == origin_in && f.round == round,
                    "rank {}: protocol desync (got {:?} origin {} round {}, \
                     expected {:?} origin {} round {})",
                    self.rank,
                    f.ftype,
                    f.origin,
                    f.round,
                    ftype,
                    origin_in,
                    round
                );
                anyhow::ensure!(
                    got.len() + f.payload.len() <= in_len,
                    "rank {}: oversized block from rank {origin_in}",
                    self.rank
                );
                got.extend_from_slice(&f.payload);
            }
        }
        Ok(got)
    }

    /// Circulate `vals` so every rank sees every rank's values (all
    /// ranks must pass the SAME element count).  Returns the per-origin
    /// values, own included.  This is the ring's replacement for the
    /// in-process barrier + shared state: the stop decision and resume
    /// negotiation both ride on it.
    pub fn circulate_u64s(&mut self, vals: &[u64], round: u32) -> anyhow::Result<Vec<Vec<u64>>> {
        let (n, k) = (self.n, vals.len());
        let mut blocks: Vec<Vec<u64>> = vec![Vec::new(); n];
        blocks[self.rank] = vals.to_vec();
        for s in 0..n - 1 {
            let so = (self.rank + n - s) % n;
            let out: Vec<u8> = blocks[so].iter().flat_map(|v| v.to_le_bytes()).collect();
            let io_ = (self.rank + n - 1 - s) % n;
            let got = self.exchange_raw(FrameType::Status, round, so, &out, io_, k * 8)?;
            blocks[io_] = got
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
        }
        Ok(blocks)
    }

    /// Synchronous allreduce-average of `due` rows of both matrices
    /// across the ring, bitwise-identical to the in-process
    /// `sync::average_row` collective (see module docs).
    ///
    /// Phase 1 (gather): circulate every rank's raw due-rows block, so
    /// each rank holds all n contributions.  Phase 2: each rank
    /// averages the rows it OWNS (`row % n == rank`), accumulating
    /// per-origin contributions in origin order 0..n — the exact
    /// model-order `axpy` loop of `average_row` — and writes the means
    /// into its replica.  Phase 3 (scatter): circulate the per-owner
    /// averaged blocks; every rank copies foreign owners' means into
    /// its replica.
    pub fn allreduce_rows(
        &mut self,
        model: &SharedModel,
        due: &[Range<u32>],
        round: u32,
    ) -> anyhow::Result<()> {
        let (n, rank) = (self.n, self.rank);
        let dim = model.dim();
        let row_bytes = 8 * dim; // M_in + M_out, f32 each
        let due_rows: Vec<u32> = due.iter().flat_map(|r| r.clone()).collect();
        for &r in &due_rows {
            anyhow::ensure!(
                (r as usize) < model.vocab(),
                "due row {r} out of range for vocab {}",
                model.vocab()
            );
        }
        if due_rows.is_empty() || n == 1 {
            return Ok(());
        }

        // My raw contribution, rows in due order, [M_in | M_out] per row.
        let mut mine = Vec::with_capacity(due_rows.len() * row_bytes);
        for &r in &due_rows {
            // SAFETY: this process's trainer is quiescent during the
            // sync phase and the heartbeat thread never touches the
            // model, so access is exclusive.
            for &x in unsafe { model.row_in(r) }.iter() {
                mine.extend_from_slice(&x.to_le_bytes());
            }
            for &x in unsafe { model.row_out(r) }.iter() {
                mine.extend_from_slice(&x.to_le_bytes());
            }
        }

        // Gather: after n-1 steps every rank holds all n blocks.
        let block_len = mine.len();
        let mut blocks: Vec<Vec<u8>> = vec![Vec::new(); n];
        blocks[rank] = mine;
        for s in 0..n - 1 {
            let so = (rank + n - s) % n;
            let io_ = (rank + n - 1 - s) % n;
            let out = std::mem::take(&mut blocks[so]);
            let got = self.exchange_raw(FrameType::Slice, round, so, &out, io_, block_len)?;
            blocks[so] = out;
            blocks[io_] = got;
        }

        // Average the rows this rank owns, origin order 0..n (the
        // model order of sync::average_row), writing means into the
        // local replica and into the outgoing averaged block.
        let inv = 1.0 / n as f32;
        let mut scratch = vec![0.0f32; dim];
        let mut tmp = vec![0.0f32; dim];
        let owned: Vec<(usize, u32)> = due_rows
            .iter()
            .enumerate()
            .filter(|(_, &r)| r as usize % n == rank)
            .map(|(j, &r)| (j, r))
            .collect();
        let mut avg_mine = Vec::with_capacity(owned.len() * row_bytes);
        for &(j, r) in &owned {
            for half in 0..2 {
                let off = j * row_bytes + half * 4 * dim;
                scratch.fill(0.0);
                for block in &blocks {
                    decode_f32(&block[off..off + 4 * dim], &mut tmp);
                    axpy(inv, &tmp, &mut scratch);
                }
                // SAFETY: as above; owners partition rows disjointly.
                let dst = if half == 0 {
                    unsafe { model.row_in(r) }
                } else {
                    unsafe { model.row_out(r) }
                };
                dst.copy_from_slice(&scratch);
                for &x in scratch.iter() {
                    avg_mine.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        drop(blocks);

        // Scatter: circulate per-owner averaged blocks; apply foreign
        // owners' means.
        let owned_count = |o: usize| due_rows.iter().filter(|&&r| r as usize % n == o).count();
        let mut avg: Vec<Vec<u8>> = vec![Vec::new(); n];
        avg[rank] = avg_mine;
        for s in 0..n - 1 {
            let so = (rank + n - s) % n;
            let io_ = (rank + n - 1 - s) % n;
            let out = std::mem::take(&mut avg[so]);
            let got = self.exchange_raw(
                FrameType::AvgSlice,
                round,
                so,
                &out,
                io_,
                owned_count(io_) * row_bytes,
            )?;
            avg[so] = out;
            // Apply immediately; keep the block around for forwarding.
            let mut k = 0;
            for &r in due_rows.iter().filter(|&&r| r as usize % n == io_) {
                decode_f32(&got[k * row_bytes..k * row_bytes + 4 * dim], &mut tmp);
                // SAFETY: as above.
                unsafe { model.row_in(r) }.copy_from_slice(&tmp);
                decode_f32(&got[k * row_bytes + 4 * dim..(k + 1) * row_bytes], &mut tmp);
                // SAFETY: as above.
                unsafe { model.row_out(r) }.copy_from_slice(&tmp);
                k += 1;
            }
            avg[io_] = got;
        }
        Ok(())
    }

    /// Snapshot the transport counters.
    pub fn stats(&self) -> NetStats {
        let w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        NetStats {
            frames_sent: w.frames_sent,
            frames_recv: self.reader.frames_recv,
            bytes_sent: w.bytes_sent,
            bytes_recv: self.reader.bytes_recv,
            slice_bytes_sent: w.slice_bytes_sent,
            heartbeats_sent: w.heartbeats_sent,
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.hb_join.take() {
            let _ = h.join();
        }
    }
}

fn decode_f32(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 4 * out.len());
    for (j, slot) in out.iter_mut().enumerate() {
        *slot = f32::from_le_bytes(bytes[4 * j..4 * j + 4].try_into().unwrap());
    }
}

/// Exact Slice/AvgSlice bytes (headers included) rank `rank` SENDS in
/// one [`Ring::allreduce_rows`] over `due`: the prediction that
/// measured [`NetStats::slice_bytes_sent`] must equal — pinned by
/// `wire_bytes_prediction_is_exact` and recheck-able against any run's
/// counters.
pub fn gather_scatter_wire_bytes(due: &[Range<u32>], n: usize, rank: usize, dim: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let due_total: u64 = due.iter().map(|r| r.len() as u64).sum();
    if due_total == 0 {
        return 0;
    }
    let row_bytes = 8 * dim as u64;
    let chunk = CHUNK_PAYLOAD as u64;
    let framed = |bytes: u64| -> u64 {
        if bytes == 0 {
            0
        } else {
            bytes + (bytes + chunk - 1) / chunk * HEADER_BYTES as u64
        }
    };
    // Gather: n-1 sends of the full due block.
    let mut total = (n as u64 - 1) * framed(due_total * row_bytes);
    // Scatter: origins (rank - s) % n for s in 0..n-1, each origin's
    // owned-rows block.
    for s in 0..n - 1 {
        let o = (rank + n - s) % n;
        let owned = due
            .iter()
            .flat_map(|r| r.clone())
            .filter(|&r| r as usize % n == o)
            .count() as u64;
        total += framed(owned * row_bytes);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn local_specs(n: usize) -> (Vec<TcpListener>, Vec<RingSpec>) {
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
            .collect();
        let specs = (0..n)
            .map(|rank| RingSpec {
                rank,
                addrs: addrs.clone(),
            })
            .collect();
        (listeners, specs)
    }

    fn fast_net() -> NetConfig {
        NetConfig {
            connect_timeout_ms: 5_000,
            io_timeout_ms: 5_000,
            heartbeat_ms: 50,
        }
    }

    #[test]
    fn ring_spec_parses_and_rejects() {
        let s = RingSpec::parse("tcp:1@127.0.0.1:7000,127.0.0.1:7001").unwrap();
        assert_eq!(s.rank, 1);
        assert_eq!(s.nranks(), 2);
        // Prefix optional.
        assert_eq!(RingSpec::parse("1@a:1,b:2").unwrap(), s_plain());
        assert!(RingSpec::parse("no-at-sign").is_err());
        assert!(RingSpec::parse("x@a:1").is_err());
        assert!(RingSpec::parse("2@a:1,b:2").is_err()); // rank out of range
        assert!(RingSpec::parse("0@").is_err());
    }

    fn s_plain() -> RingSpec {
        RingSpec {
            rank: 1,
            addrs: vec!["a:1".into(), "b:2".into()],
        }
    }

    #[test]
    fn frame_roundtrip_and_corruption_detection() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let out = TcpStream::connect(addr).unwrap();
        let (inc, _) = l.accept().unwrap();
        inc.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut w = FrameWriter {
            stream: out,
            fault: None,
            data_frames: 0,
            frames_sent: 0,
            bytes_sent: 0,
            slice_bytes_sent: 0,
            heartbeats_sent: 0,
        };
        let mut r = FrameReader {
            stream: inc,
            io_timeout: Duration::from_millis(500),
            frames_recv: 0,
            bytes_recv: 0,
        };

        w.send(FrameType::Status, 2, 7, &[1, 2, 3]).unwrap();
        w.send(FrameType::Heartbeat, 2, 0, &[]).unwrap();
        let f = r.recv().unwrap();
        assert_eq!(f.ftype, FrameType::Status);
        assert_eq!(f.origin, 2);
        assert_eq!(f.round, 7);
        assert_eq!(f.payload, vec![1, 2, 3]);
        let hb = r.recv().unwrap();
        assert_eq!(hb.ftype, FrameType::Heartbeat);
        assert!(hb.payload.is_empty());

        // Corrupt frame: valid header, payload checksum wrong.
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.push(FrameType::Status as u8);
        raw.push(0);
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&0xBAD0_BAD0_BAD0_BAD0u64.to_le_bytes());
        raw.extend_from_slice(&[9, 9]);
        w.stream.write_all(&raw).unwrap();
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");

        // Garbage magic.
        w.stream.write_all(&[0u8; HEADER_BYTES]).unwrap();
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn torn_frame_is_rejected_as_truncation() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let mut out = TcpStream::connect(addr).unwrap();
        let (inc, _) = l.accept().unwrap();
        inc.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut r = FrameReader {
            stream: inc,
            io_timeout: Duration::from_millis(500),
            frames_recv: 0,
            bytes_recv: 0,
        };
        // Header promising 100 payload bytes, connection closed after 10.
        let payload = [7u8; 100];
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.push(FrameType::Slice as u8);
        raw.push(0);
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&100u32.to_le_bytes());
        raw.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        raw.extend_from_slice(&payload[..10]);
        out.write_all(&raw).unwrap();
        drop(out);
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("truncated") || err.contains("closed"), "{err}");
    }

    #[test]
    fn hello_rejects_fingerprint_mismatch() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (i, (l, spec)) in listeners.into_iter().zip(specs).enumerate() {
            handles.push(std::thread::spawn(move || {
                Ring::establish_on(l, &spec, &fast_net(), 100 + i as u64).map(|_| ())
            }));
        }
        for h in handles {
            let res = h.join().unwrap();
            assert!(res.is_err(), "mixed fingerprints must not form a ring");
            let msg = format!("{:#}", res.unwrap_err());
            assert!(msg.contains("fingerprint"), "{msg}");
        }
    }

    #[test]
    fn circulate_sees_every_rank() {
        let (listeners, specs) = local_specs(3);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 1).unwrap();
                let rank = ring.rank() as u64;
                ring.circulate_u64s(&[rank * 10, rank * 10 + 1], 1).unwrap()
            }));
        }
        for h in handles {
            let blocks = h.join().unwrap();
            for (o, vals) in blocks.iter().enumerate() {
                let o = o as u64;
                assert_eq!(vals, &vec![o * 10, o * 10 + 1]);
            }
        }
    }

    #[test]
    fn three_rank_allreduce_matches_in_process_average_bitwise() {
        let (vocab, dim, n) = (37usize, 12usize, 3usize);
        // Expected means, computed with the exact average_row arithmetic
        // (same axpy, same origin order) on copies of the initial rows.
        let inits: Vec<SharedModel> = (0..n)
            .map(|i| SharedModel::init(vocab, dim, 1000 + i as u64))
            .collect();
        let inv = 1.0 / n as f32;
        let mut want_in = vec![vec![0.0f32; dim]; vocab];
        let mut want_out = vec![vec![0.0f32; dim]; vocab];
        for r in 0..vocab as u32 {
            for m in &inits {
                axpy(inv, m.m_in().row(r), &mut want_in[r as usize]);
                axpy(inv, m.m_out().row(r), &mut want_out[r as usize]);
            }
        }

        let (listeners, specs) = local_specs(n);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let model = SharedModel::init(37, 12, 1000 + rank as u64);
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 7).unwrap();
                let due = vec![0..37u32];
                ring.allreduce_rows(&model, &due, 1).unwrap();
                let stats = ring.stats();
                (rank, model, stats)
            }));
        }
        let due = vec![0..vocab as u32];
        for h in handles {
            let (rank, model, stats) = h.join().unwrap();
            for r in 0..vocab as u32 {
                for j in 0..dim {
                    assert_eq!(
                        model.m_in().row(r)[j].to_bits(),
                        want_in[r as usize][j].to_bits(),
                        "rank {rank} M_in[{r}][{j}]"
                    );
                    assert_eq!(
                        model.m_out().row(r)[j].to_bits(),
                        want_out[r as usize][j].to_bits(),
                        "rank {rank} M_out[{r}][{j}]"
                    );
                }
            }
            // Measured slice traffic equals the analytic predictor
            // exactly — this is the calibration contract.
            assert_eq!(
                stats.slice_bytes_sent,
                gather_scatter_wire_bytes(&due, n, rank, dim),
                "rank {rank} wire bytes"
            );
            assert!(stats.frames_sent > 0 && stats.frames_recv > 0);
        }
    }

    #[test]
    fn abort_reaches_peer_with_reason() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 3).unwrap();
                if rank == 1 {
                    ring.abort("injected failure for test");
                    Ok(())
                } else {
                    ring.recv_data().map(|_| ())
                }
            }));
        }
        let r1 = handles.pop().unwrap().join().unwrap();
        let r0 = handles.pop().unwrap().join().unwrap();
        assert!(r1.is_ok());
        let err = format!("{:#}", r0.unwrap_err());
        assert!(err.contains("rank 1 aborted"), "{err}");
        assert!(err.contains("injected failure"), "{err}");
    }

    #[test]
    fn dead_peer_trips_read_deadline() {
        let (listeners, specs) = local_specs(2);
        let mut net = fast_net();
        net.io_timeout_ms = 400;
        net.heartbeat_ms = 50;
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            let net = net;
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let mut ring = Ring::establish_on(l, &spec, &net, 9).unwrap();
                if rank == 1 {
                    // Die silently without aborting: drop the ring (the
                    // closed socket is what rank 0 must detect).
                    drop(ring);
                    Ok(())
                } else {
                    let t0 = Instant::now();
                    let res = ring.recv_data().map(|_| ());
                    assert!(
                        t0.elapsed() < Duration::from_millis(2 * net.io_timeout_ms as u64 + 1000),
                        "detection took {:?}",
                        t0.elapsed()
                    );
                    res
                }
            }));
        }
        let r1 = handles.pop().unwrap().join().unwrap();
        let r0 = handles.pop().unwrap().join().unwrap();
        assert!(r1.is_ok());
        let err = format!("{:#}", r0.unwrap_err());
        assert!(
            err.contains("closed") || err.contains("silent"),
            "unexpected diagnostic: {err}"
        );
    }

    #[test]
    fn wire_bytes_predictor_edges() {
        assert_eq!(gather_scatter_wire_bytes(&[], 3, 0, 8), 0);
        assert_eq!(gather_scatter_wire_bytes(&[0..10], 1, 0, 8), 0);
        // 2 ranks, 3 rows, dim 1: block = 3*8 = 24 bytes, one chunk.
        // Gather: 1 send of 24+24; scatter: origin = rank itself owns
        // ceil/floor split of rows by parity.
        let due = vec![0..3u32];
        let b = gather_scatter_wire_bytes(&due, 2, 0, 1);
        // rank 0 owns rows 0 and 2 (2 rows): scatter block 2*8=16 + 24.
        assert_eq!(b, (24 + 24) + (16 + 24));
        let b1 = gather_scatter_wire_bytes(&due, 2, 1, 1);
        // rank 1 owns row 1: scatter block 8 + 24.
        assert_eq!(b1, (24 + 24) + (8 + 24));
    }

    #[test]
    fn chunking_splits_large_blocks() {
        // A block of 40 KiB must cost 3 headers.
        let rows = (40 * 1024) / 8; // dim 1 → 8 bytes/row
        let due = vec![0..rows as u32];
        let b = gather_scatter_wire_bytes(&due, 2, 0, 1);
        let chunk = CHUNK_PAYLOAD as u64;
        let nchunks = |bytes: u64| (bytes + chunk - 1) / chunk;
        let block = rows as u64 * 8;
        let own = due
            .iter()
            .flat_map(|r| r.clone())
            .filter(|&r| r % 2 == 0)
            .count() as u64
            * 8;
        let expect = (block + nchunks(block) * 24) + (own + nchunks(own) * 24);
        assert_eq!(b, expect);
        assert_eq!(nchunks(block), 3);
    }
}
