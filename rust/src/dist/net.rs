//! TCP ring transport for multi-process distributed training.
//!
//! std-only (raw sockets, no new crates — the PR-3/PR-4 discipline).
//! N processes, one per rank, form a unidirectional ring: rank k writes
//! to rank (k+1) % n and reads from rank (k-1+n) % n.  Everything on
//! the wire is a length-prefixed FRAME:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "PWRG"
//!      4     2  version (little-endian u16, currently 2)
//!      6     1  frame type (Hello|Status|Slice|AvgSlice|Heartbeat|Abort
//!                           |Regroup|RegroupAck|Members)
//!      7     1  origin rank (ring POSITION in the current view)
//!      8     4  sync round the frame belongs to (u32)
//!     12     4  membership epoch / view number (u32)
//!     16     4  payload length in bytes (u32)
//!     20     8  FNV-1a checksum of the payload (u64)
//!     28     …  payload
//! ```
//!
//! The **membership epoch** fences views: epoch 0 is the launch ring;
//! every successful regroup (see below) increments it.  A receiver
//! silently drops frames stamped with an OLDER epoch (stale traffic
//! from a dead view) and treats a NEWER epoch as the recoverable
//! "a regroup is underway, join it" signal.
//!
//! Robustness model:
//!
//! * **Ring formation** — every rank binds its listener FIRST, then
//!   connects to its successor with bounded exponential backoff (the
//!   connect succeeds as soon as the peer has bound, via the kernel
//!   backlog), then accepts its predecessor.  A `Hello` exchange checks
//!   ring wiring, rank count and the config fingerprint before any
//!   training traffic.
//! * **Failure detection** — a heartbeat thread sends a `Heartbeat`
//!   frame to the successor every `heartbeat_ms`; reads carry a
//!   deadline of `io_timeout_ms` that any complete incoming frame
//!   resets.  A dead peer (closed socket) fails the read instantly; a
//!   wedged peer (alive but silent — see `PW2V_FAULT stall-after`)
//!   trips the deadline.
//! * **Failure propagation** — a failing rank best-effort sends an
//!   `Abort` frame carrying a reason; receivers forward it around the
//!   ring and return an error, so every survivor exits with a
//!   diagnostic instead of hanging in allreduce.  Peer-loss errors
//!   (closed socket, tripped deadline, torn frame, regroup announce)
//!   are additionally tagged [`PeerFailure`] so a recovery-capable
//!   driver can distinguish them from unrecoverable faults; `Abort`
//!   stays fatal in every mode.
//! * **Self-healing** — under `--on-failure shrink|rejoin` the driver
//!   reacts to a [`PeerFailure`] by calling [`Ring::regroup`]: the
//!   listener is retained for the whole run, survivors scan forward for
//!   their first live successor (probe = `Regroup` frame answered by
//!   `RegroupAck` on the same socket; a wedged peer accepts the connect
//!   via the kernel backlog but never acks, so the ack deadline skips
//!   it), then agree on the member set by circulating `Members` bitmap
//!   tokens around the tentative ring (own token returning = everyone
//!   seen).  Under rejoin, the full original membership is retried for
//!   a grace window before any skip, so a promptly respawned rank is
//!   readmitted.  A sole survivor forms a self-linked one-rank view.
//! * **Adaptive read deadline** — [`Ring::observe_round`] feeds an
//!   EWMA of round wall time (`srtt += (sample - srtt)/8`, TCP-RTT
//!   style); the effective frame deadline is `max(io_timeout_ms,
//!   4·srtt)`, so slow-but-alive rings stretch their own deadline while
//!   the configured floor still detects dead peers fast.
//! * **Deadlock freedom** — every rank runs send-then-recv in the same
//!   ring step, so a block larger than the kernel socket buffers would
//!   wedge all ranks in `write`.  Block transfers are therefore split
//!   into ≤[`CHUNK_PAYLOAD`]-byte frames with send/recv interleaved per
//!   chunk; both sides compute the expected byte counts locally (same
//!   due ranges, same partition rule), so chunks need no extra framing.
//!
//! The allreduce ([`Ring::allreduce_rows`]) is gather-circulate +
//! scatter rather than a true ring-allreduce: reduction arithmetic runs
//! only on the OWNER of a row (`row % n == rank`), accumulating the n
//! per-origin contributions in origin order with the same
//! `axpy`-into-scratch loop as the in-process collective
//! (`sync::average_row`).  That costs more bandwidth than ring
//! allreduce ((n-1)·P + (n-1)/n·P vs 2·(n-1)/n·P per rank) but makes
//! the result BITWISE IDENTICAL to thread mode — the acceptance
//! criterion this transport is built around.  `perfmodel/network.rs`
//! carries the analytic cost model; [`gather_scatter_wire_bytes`] is
//! the exact per-rank byte predictor that measured [`NetStats`] are
//! checked against.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dist::fault::FaultSpec;
use crate::linalg::vecops::axpy;
use crate::model::SharedModel;
use crate::util::fnv::fnv1a;

const MAGIC: [u8; 4] = *b"PWRG";
const VERSION: u16 = 2;
/// Frame header size on the wire.
pub const HEADER_BYTES: usize = 28;
/// Largest payload a single frame carries.  Must stay safely below the
/// smallest kernel socket buffer so one in-flight chunk per direction
/// can never wedge the ring (see module docs).
pub const CHUNK_PAYLOAD: usize = 16 * 1024;
/// Receive-side sanity bound on the header's length field.
const MAX_PAYLOAD: usize = 1 << 20;

/// Process exit code for `PW2V_FAULT kill-after=N`.
pub const EXIT_FAULT_KILL: i32 = 42;
/// Process exit code for `PW2V_FAULT torn-frame=N`.
pub const EXIT_FAULT_TORN: i32 = 43;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameType {
    /// Ring-formation handshake: `[nranks u32][fingerprint u64]`.
    Hello = 1,
    /// Small u64-array circulation (stop decision, resume negotiation).
    Status = 2,
    /// Gather-phase model block (raw replica rows).
    Slice = 3,
    /// Scatter-phase model block (averaged owner rows).
    AvgSlice = 4,
    /// Liveness beacon; resets the receiver's read deadline, carries no
    /// payload, and is invisible to fault frame counting.
    Heartbeat = 5,
    /// Failure propagation: payload is a UTF-8 reason.
    Abort = 6,
    /// Regroup probe / announce: `[fingerprint u64]`; the header epoch
    /// is the proposed view number.  Sent on the old successor link as
    /// an announce, and as the probe opening the bidirectional regroup
    /// handshake.
    Regroup = 7,
    /// Answer to a `Regroup` probe, sent back on the SAME socket:
    /// `[fingerprint u64]`; the header epoch is the acker's (possibly
    /// newer) target epoch, which the prober adopts.
    RegroupAck = 8,
    /// Membership token: `[ttl u8][bitmap 32B]` of original ranks.
    /// Each member injects its own token and forwards everyone else's
    /// with its own bit OR-ed in; a token returning to its origin
    /// carries the full member set of the tentative ring.
    Members = 9,
}

impl FrameType {
    fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => FrameType::Hello,
            2 => FrameType::Status,
            3 => FrameType::Slice,
            4 => FrameType::AvgSlice,
            5 => FrameType::Heartbeat,
            6 => FrameType::Abort,
            7 => FrameType::Regroup,
            8 => FrameType::RegroupAck,
            9 => FrameType::Members,
            other => anyhow::bail!("unknown frame type {other} (protocol corruption)"),
        })
    }
}

/// One decoded frame.
pub struct Frame {
    pub ftype: FrameType,
    pub origin: u8,
    pub round: u32,
    pub epoch: u32,
    pub payload: Vec<u8>,
}

/// A RECOVERABLE ring failure: the peer died, wedged, tore a frame, or
/// announced a regroup for a newer membership epoch.  Drivers running
/// `--on-failure shrink|rejoin` downcast to this marker (anywhere in an
/// `anyhow` chain) to decide recovery; everything NOT tagged — notably
/// an `Abort` frame — keeps PR-6 fail-stop semantics.
#[derive(Debug, Clone)]
pub struct PeerFailure {
    /// Epoch a regroup announce asked us to join (0 = none seen; the
    /// detector proposes `current + 1` itself).
    pub regroup_epoch: u32,
    pub reason: String,
}

impl std::fmt::Display for PeerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.reason)
    }
}

impl std::error::Error for PeerFailure {}

/// Does this error chain contain a recoverable [`PeerFailure`]?
pub fn peer_failure(err: &anyhow::Error) -> Option<&PeerFailure> {
    err.chain().find_map(|c| c.downcast_ref::<PeerFailure>())
}

/// `--dist tcp:<rank>@addr0,addr1,...` — this process is `rank`;
/// `addrs[k]` is where rank k listens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RingSpec {
    pub rank: usize,
    pub addrs: Vec<String>,
}

impl RingSpec {
    /// Parse a ring spec; a leading `tcp:` is accepted and ignored so
    /// callers may pass the full `--dist` value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.strip_prefix("tcp:").unwrap_or(s);
        let (rank, addrs) = s.split_once('@').ok_or_else(|| {
            anyhow::anyhow!("ring spec '{s}': expected <rank>@addr0,addr1,...")
        })?;
        let rank: usize = rank
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("ring spec rank '{rank}': {e}"))?;
        let addrs: Vec<String> = addrs
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        anyhow::ensure!(!addrs.is_empty(), "ring spec '{s}': no addresses");
        anyhow::ensure!(
            rank < addrs.len(),
            "ring spec rank {rank} out of range for {} addresses",
            addrs.len()
        );
        anyhow::ensure!(addrs.len() <= 255, "ring spec: at most 255 ranks");
        Ok(Self { rank, addrs })
    }

    pub fn nranks(&self) -> usize {
        self.addrs.len()
    }
}

/// Transport tuning knobs (all CLI-overridable; defaults documented in
/// EXPERIMENTS.md §Distributed-TCP).
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Ring-formation budget: how long to retry connecting to the
    /// successor (exponential backoff 10ms → 500ms) and to wait for the
    /// predecessor to connect.
    pub connect_timeout_ms: u64,
    /// Read/write deadline per frame once the ring is up; a peer silent
    /// for this long is declared dead/wedged.  This is the FLOOR of the
    /// adaptive deadline — [`Ring::observe_round`] stretches the
    /// effective deadline to `max(io_timeout_ms, 4·srtt)`.
    pub io_timeout_ms: u64,
    /// Heartbeat period (must be well under `io_timeout_ms`).
    pub heartbeat_ms: u64,
    /// `--on-failure rejoin` only: how long a regroup keeps retrying
    /// the FULL original membership (so a respawned rank is readmitted)
    /// before it starts skipping dead peers.
    pub rejoin_grace_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            connect_timeout_ms: 15_000,
            io_timeout_ms: 10_000,
            heartbeat_ms: 300,
            rejoin_grace_ms: 5_000,
        }
    }
}

/// Measured transport counters for one rank (calibrates
/// `perfmodel/network.rs`; surfaced in `DistOutcome`).
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    pub frames_sent: u64,
    pub frames_recv: u64,
    /// Header + payload bytes, every frame type.
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    /// Header + payload bytes of Slice/AvgSlice frames only — the
    /// quantity [`gather_scatter_wire_bytes`] predicts exactly.
    pub slice_bytes_sent: u64,
    pub heartbeats_sent: u64,
}

/// Encode one frame (header + payload) into a contiguous buffer.
fn encode_frame(ftype: FrameType, origin: u8, round: u32, epoch: u32, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.push(ftype as u8);
    buf.push(origin);
    buf.extend_from_slice(&round.to_le_bytes());
    buf.extend_from_slice(&epoch.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&fnv1a(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Writing half of the successor connection, shared between the trainer
/// and the heartbeat thread behind one mutex (a frame is always written
/// under a single lock hold, so frames never interleave).
struct FrameWriter {
    stream: TcpStream,
    fault: Option<FaultSpec>,
    /// Membership epoch stamped on every outgoing frame; bumped when a
    /// regroup installs a new view.
    epoch: u32,
    /// Data frames written so far (heartbeats excluded) — the counter
    /// `PW2V_FAULT` triggers key off, kept heartbeat-free so fault
    /// schedules are deterministic.
    data_frames: u64,
    frames_sent: u64,
    bytes_sent: u64,
    slice_bytes_sent: u64,
    heartbeats_sent: u64,
}

impl FrameWriter {
    fn send(&mut self, ftype: FrameType, origin: u8, round: u32, payload: &[u8]) -> anyhow::Result<()> {
        let buf = encode_frame(ftype, origin, round, self.epoch, payload);

        if ftype != FrameType::Heartbeat {
            match self.fault {
                Some(FaultSpec::KillAfterFrames(n)) if self.data_frames >= n => {
                    eprintln!("PW2V_FAULT kill-after={n}: exiting now");
                    std::process::exit(EXIT_FAULT_KILL);
                }
                Some(FaultSpec::KillEpoch(e)) if self.epoch == e => {
                    eprintln!("PW2V_FAULT kill-epoch={e}: exiting now");
                    std::process::exit(EXIT_FAULT_KILL);
                }
                Some(FaultSpec::TornFrame(n)) if self.data_frames == n => {
                    // Crash mid-write: header plus half the payload.
                    let torn = HEADER_BYTES + payload.len() / 2;
                    let _ = self.stream.write_all(&buf[..torn]);
                    let _ = self.stream.flush();
                    eprintln!("PW2V_FAULT torn-frame={n}: wrote {torn} bytes, exiting");
                    std::process::exit(EXIT_FAULT_TORN);
                }
                Some(FaultSpec::StallAfterFrames(n)) if self.data_frames >= n => {
                    // Wedge while HOLDING the writer lock: the heartbeat
                    // thread blocks on the same mutex, so heartbeats stop
                    // and peers must detect us via the read deadline.
                    eprintln!("PW2V_FAULT stall-after={n}: stalling (lock held)");
                    loop {
                        std::thread::sleep(Duration::from_secs(3600));
                    }
                }
                _ => {}
            }
            self.data_frames += 1;
        }

        self.stream.write_all(&buf)?;
        self.frames_sent += 1;
        self.bytes_sent += buf.len() as u64;
        match ftype {
            FrameType::Slice | FrameType::AvgSlice => {
                self.slice_bytes_sent += buf.len() as u64;
            }
            FrameType::Heartbeat => self.heartbeats_sent += 1,
            _ => {}
        }
        Ok(())
    }
}

/// Reading half of the predecessor connection.  Generic over the byte
/// source so the decode path is testable against hostile in-memory
/// buffers (fuzz tests feed `Cursor<Vec<u8>>`); the ring itself uses
/// `FrameReader<TcpStream>`.
struct FrameReader<R: Read> {
    stream: R,
    io_timeout: Duration,
    frames_recv: u64,
    bytes_recv: u64,
}

impl<R: Read> FrameReader<R> {
    fn new(stream: R, io_timeout: Duration) -> Self {
        Self {
            stream,
            io_timeout,
            frames_recv: 0,
            bytes_recv: 0,
        }
    }

    /// Fill `buf` completely, tolerating short reads and poll timeouts,
    /// failing once `deadline` passes with nothing left to read.
    fn read_full(&mut self, buf: &mut [u8], deadline: Instant) -> anyhow::Result<()> {
        let mut filled = 0;
        while filled < buf.len() {
            match self.stream.read(&mut buf[filled..]) {
                Ok(0) => anyhow::bail!("peer closed the connection"),
                Ok(k) => filled += k,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "peer silent for {}ms (dead or wedged)",
                        self.io_timeout.as_millis()
                    );
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Read and validate one frame (any type).
    fn recv(&mut self) -> anyhow::Result<Frame> {
        let deadline = Instant::now() + self.io_timeout;
        let mut head = [0u8; HEADER_BYTES];
        self.read_full(&mut head, deadline)?;
        anyhow::ensure!(head[..4] == MAGIC, "bad frame magic (protocol corruption)");
        let version = u16::from_le_bytes(head[4..6].try_into().unwrap());
        anyhow::ensure!(
            version == VERSION,
            "frame version {version} (expected {VERSION})"
        );
        let ftype = FrameType::from_u8(head[6])?;
        let origin = head[7];
        let round = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let epoch = u32::from_le_bytes(head[12..16].try_into().unwrap());
        // The length field is capped BEFORE the payload allocation, so a
        // hostile/corrupt header can never drive an OOM-sized `vec!`.
        let len = u32::from_le_bytes(head[16..20].try_into().unwrap()) as usize;
        anyhow::ensure!(len <= MAX_PAYLOAD, "frame length {len} exceeds protocol max");
        let want = u64::from_le_bytes(head[20..28].try_into().unwrap());
        let mut payload = vec![0u8; len];
        self.read_full(&mut payload, deadline)
            .map_err(|e| anyhow::anyhow!("truncated frame payload: {e}"))?;
        anyhow::ensure!(
            fnv1a(&payload) == want,
            "frame checksum mismatch (corrupt or torn frame)"
        );
        self.frames_recv += 1;
        self.bytes_recv += (HEADER_BYTES + len) as u64;
        Ok(Frame {
            ftype,
            origin,
            round,
            epoch,
            payload,
        })
    }
}

/// Established ring endpoint for one rank.
pub struct Ring {
    /// Position in the CURRENT view (0..n); equals the original rank in
    /// the launch view (epoch 0).
    rank: usize,
    /// Current view size.
    n: usize,
    /// Original launch rank — the fixed addressing identity used on
    /// regroup probes regardless of view.
    orig_rank: usize,
    /// Launch addresses, indexed by original rank.
    addrs: Vec<String>,
    /// Original ranks alive in the current view, sorted ascending.
    /// Ring order IS this order (position = index here).
    members: Vec<usize>,
    /// Membership epoch of the current view.
    epoch: u32,
    /// Launch fingerprint (config ^ vocab ^ launch nranks) — regroup
    /// handshakes always use this, so respawned ranks with the same
    /// argv can rejoin any view.
    fingerprint: u64,
    net: NetConfig,
    /// Retained for the whole run so regroups can re-form links; PR 6
    /// dropped it after the launch accept.
    listener: TcpListener,
    fault: Option<FaultSpec>,
    /// EWMA of observed round wall time (ms); 0 until the first sample.
    srtt_ms: f64,
    writer: Arc<Mutex<FrameWriter>>,
    reader: FrameReader<TcpStream>,
    hb_stop: Arc<AtomicBool>,
    hb_join: Option<std::thread::JoinHandle<()>>,
}

fn connect_retry(addr: &str, timeout: Duration) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(10);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                anyhow::ensure!(
                    Instant::now() + backoff < deadline,
                    "could not connect to successor {addr} within {}ms: {e}",
                    timeout.as_millis()
                );
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

fn accept_deadline(listener: &TcpListener, timeout: Duration) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                return Ok(s);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "predecessor did not connect within {}ms",
                    timeout.as_millis()
                );
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

fn spawn_heartbeat(
    writer: &Arc<Mutex<FrameWriter>>,
    heartbeat_ms: u64,
    origin: u8,
) -> (Arc<AtomicBool>, Option<std::thread::JoinHandle<()>>) {
    let stop = Arc::new(AtomicBool::new(false));
    let join = {
        let writer = Arc::clone(writer);
        let stop = Arc::clone(&stop);
        let period = Duration::from_millis(heartbeat_ms.max(1));
        Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(period);
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                if w.send(FrameType::Heartbeat, origin, 0, &[]).is_err() {
                    // Successor is gone; the trainer will find out
                    // through its own send/recv errors.
                    break;
                }
            }
        }))
    };
    (stop, join)
}

// ---------------------------------------------------------------------------
// Regroup: re-forming a smaller (or restored) view after a rank failure
// ---------------------------------------------------------------------------

/// A successfully formed view: the agreed member set plus its links.
struct View {
    epoch: u32,
    /// Original ranks, sorted ascending; ring order is this order.
    members: Vec<usize>,
    /// This process's index in `members`.
    position: usize,
    /// Write link to the view successor.
    out: TcpStream,
    /// Read link from the view predecessor.
    inc: TcpStream,
}

/// Read exactly one frame from `stream` within `budget` (short read
/// timeout polls underneath).  Counters are throwaway — this services
/// the regroup handshake, not the steady-state reader.
fn read_one_frame(stream: TcpStream, budget: Duration) -> anyhow::Result<(TcpStream, Frame)> {
    stream.set_read_timeout(Some(Duration::from_millis(20)))?;
    let mut r = FrameReader::new(stream, budget);
    let f = r.recv()?;
    Ok((r.stream, f))
}

/// One accept-poll during regroup: handle a single queued incoming
/// connection, if any.  Returns the probe (socket + frame) when a valid
/// same-or-newer-epoch `Regroup` arrived; `None` for no connection,
/// stale probes (acked with OUR epoch so the prober adopts upward), or
/// chatter such as a respawned rank's launch `Hello` (dropped — it
/// learns the epoch from our own probe instead).
fn poll_probe(
    listener: &TcpListener,
    fingerprint: u64,
    epoch: u32,
    orig_rank: usize,
) -> Option<(TcpStream, Frame)> {
    let (conn, _) = match listener.accept() {
        Ok(c) => c,
        Err(_) => return None,
    };
    conn.set_nodelay(true).ok();
    let (mut conn, f) = read_one_frame(conn, Duration::from_millis(500)).ok()?;
    if f.ftype != FrameType::Regroup || f.payload.len() != 8 {
        return None;
    }
    let fp = u64::from_le_bytes(f.payload[..8].try_into().ok()?);
    if fp != fingerprint {
        return None;
    }
    if f.epoch < epoch {
        // Stale probe: ack with OUR epoch so the prober adopts it and
        // re-probes; this socket is not a view link.
        let ack = encode_frame(
            FrameType::RegroupAck,
            orig_rank as u8,
            0,
            epoch,
            &fingerprint.to_le_bytes(),
        );
        conn.write_all(&ack).ok();
        return None;
    }
    Some((conn, f))
}

/// Forward-scan regroup: agree on the surviving member set for (at
/// least) epoch `start_epoch` and form its ring links.  See the module
/// docs for the protocol; `grace` keeps retrying the FULL original
/// membership before any peer is skipped (the rejoin window).
#[allow(clippy::too_many_arguments)]
fn form_view(
    listener: &TcpListener,
    addrs: &[String],
    orig_rank: usize,
    fingerprint: u64,
    net: &NetConfig,
    fault: Option<FaultSpec>,
    start_epoch: u32,
    grace: Duration,
) -> anyhow::Result<View> {
    if let Some(f) = fault {
        if f.wedges_regroup(start_epoch) {
            eprintln!("PW2V_FAULT wedge-regroup={start_epoch}: wedging (connects accepted, never acked)");
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
    }
    let n = addrs.len();
    let overall = Instant::now()
        + Duration::from_millis(net.connect_timeout_ms.max(1))
        + grace;
    let grace_until = Instant::now() + grace;
    let probe = |epoch: u32| {
        encode_frame(
            FrameType::Regroup,
            orig_rank as u8,
            0,
            epoch,
            &fingerprint.to_le_bytes(),
        )
    };
    listener.set_nonblocking(true)?;
    let mut epoch = start_epoch;

    'attempt: loop {
        anyhow::ensure!(
            Instant::now() < overall,
            "regroup for epoch {epoch} exhausted its window \
             (no agreeable surviving view)"
        );

        // --- Phase A: link formation -----------------------------------
        // Active side: probe candidates forward of us in launch order;
        // the first that answers RegroupAck is our view successor.
        // Passive side: accept probes; the latest valid prober is our
        // view predecessor.  Both run interleaved in one loop so probe
        // handshakes cannot deadlock.
        let mut pred: Option<(TcpStream, u8)> = None;
        let mut succ: Option<(TcpStream, usize)> = None;
        let mut skipped = vec![false; n];
        let mut k = 1usize; // candidate offset being probed
        let mut cand: Option<(TcpStream, usize, Instant)> = None; // awaiting ack
        let mut cand_deadline = Instant::now();
        let phase_a = loop {
            if Instant::now() >= overall {
                continue 'attempt;
            }
            // Passive: handle one queued incoming probe.
            if let Some((conn, f)) = poll_probe(listener, fingerprint, epoch, orig_rank) {
                let mut conn = conn;
                let ack = encode_frame(
                    FrameType::RegroupAck,
                    orig_rank as u8,
                    0,
                    f.epoch.max(epoch),
                    &fingerprint.to_le_bytes(),
                );
                if conn.write_all(&ack).is_ok() {
                    if f.epoch > epoch {
                        // Adopt the newer epoch: our old-epoch links are
                        // void, rescan; the prober stays as our pred.
                        epoch = f.epoch;
                        succ = None;
                        cand = None;
                        skipped.fill(false);
                        k = 1;
                    }
                    pred = Some((conn, f.origin));
                }
            }
            // Active: advance the candidate scan.
            if succ.is_none() {
                match cand.take() {
                    Some((stream, c, ack_by)) => {
                        // Awaiting the ack on the probe socket.
                        stream.set_read_timeout(Some(Duration::from_millis(20))).ok();
                        let mut r = FrameReader::new(stream, Duration::from_millis(25));
                        match r.recv() {
                            Ok(f)
                                if f.ftype == FrameType::RegroupAck
                                    && f.payload.len() == 8
                                    && u64::from_le_bytes(f.payload[..8].try_into().unwrap())
                                        == fingerprint =>
                            {
                                if f.epoch > epoch {
                                    // Acker is ahead: adopt and rescan.
                                    epoch = f.epoch;
                                    succ = None;
                                    pred = None;
                                    skipped.fill(false);
                                    k = 1;
                                } else {
                                    succ = Some((r.stream, c));
                                }
                            }
                            Ok(_) => {} // chatter: drop the socket, rescan this k
                            Err(_) if Instant::now() < ack_by => {
                                cand = Some((r.stream, c, ack_by));
                            }
                            Err(_) => {
                                // No ack in time: dead or wedged (a wedged
                                // peer accepts connects via the kernel
                                // backlog but never answers).  Inside the
                                // rejoin grace window the candidate is
                                // retried instead of skipped.
                                if Instant::now() >= grace_until {
                                    skipped[c] = true;
                                    k += 1;
                                }
                            }
                        }
                    }
                    None => {
                        let in_grace = Instant::now() < grace_until;
                        if in_grace {
                            // Rejoin grace: only the IMMEDIATE original
                            // successor is probed, and it is retried —
                            // never skipped — so a promptly respawned
                            // rank restores the full membership.
                            k = 1;
                        }
                        if k >= n {
                            // Scanned everyone once.
                            if pred.is_some() {
                                // A live prober proves a peer exists:
                                // retry the full membership.
                                k = 1;
                                skipped.fill(false);
                            } else {
                                break false; // sole survivor
                            }
                        } else {
                            let c = (orig_rank + k) % n;
                            if skipped[c] || c == orig_rank {
                                k += 1;
                            } else if Instant::now() >= cand_deadline {
                                cand_deadline = Instant::now() + Duration::from_millis(150);
                                let budget = Duration::from_millis(100);
                                if let Ok(sa) = addrs[c].to_socket_addrs() {
                                    let conn = sa
                                        .into_iter()
                                        .find_map(|a| TcpStream::connect_timeout(&a, budget).ok());
                                    match conn {
                                        Some(mut s) => {
                                            s.set_nodelay(true).ok();
                                            if s.write_all(&probe(epoch)).is_ok() {
                                                cand = Some((
                                                    s,
                                                    c,
                                                    Instant::now() + Duration::from_millis(600),
                                                ));
                                            } else if !in_grace {
                                                skipped[c] = true;
                                                k += 1;
                                            }
                                        }
                                        None if !in_grace => {
                                            skipped[c] = true;
                                            k += 1;
                                        }
                                        None => {} // grace: retry the connect
                                    }
                                } else {
                                    skipped[c] = true;
                                    k += 1;
                                }
                            }
                        }
                    }
                }
            }
            if let (Some(_), Some(_)) = (&pred, &succ) {
                break true;
            }
            std::thread::sleep(Duration::from_millis(2));
        };

        if !phase_a {
            // Sole survivor: form a one-rank self-linked view.  Drain
            // stale queued connects first; if a live probe shows up in
            // the drain, we are not alone — rescan.
            while let Some((conn, f)) = poll_probe(listener, fingerprint, epoch, orig_rank) {
                let ack = encode_frame(
                    FrameType::RegroupAck,
                    orig_rank as u8,
                    0,
                    f.epoch.max(epoch),
                    &fingerprint.to_le_bytes(),
                );
                let mut conn = conn;
                if conn.write_all(&ack).is_ok() {
                    epoch = epoch.max(f.epoch);
                    continue 'attempt;
                }
            }
            let sa = addrs[orig_rank]
                .to_socket_addrs()?
                .next()
                .ok_or_else(|| anyhow::anyhow!("unresolvable own address {}", addrs[orig_rank]))?;
            let out = TcpStream::connect_timeout(&sa, Duration::from_millis(1000))?;
            out.set_nodelay(true)?;
            let inc = accept_deadline(listener, Duration::from_millis(1000))?;
            inc.set_nodelay(true)?;
            inc.set_read_timeout(Some(Duration::from_millis(100)))?;
            out.set_write_timeout(Some(Duration::from_millis(net.io_timeout_ms.max(1))))?;
            eprintln!("rank {orig_rank}: regroup epoch {epoch}: sole survivor, continuing solo");
            return Ok(View {
                epoch,
                members: vec![orig_rank],
                position: 0,
                out,
                inc,
            });
        }

        // --- Phase B: membership agreement by token circulation --------
        let (out, succ_orig) = succ.take().map(|(s, c)| (s, c)).unwrap();
        let (inc, pred_origin) = pred.take().unwrap();
        out.set_write_timeout(Some(Duration::from_millis(net.io_timeout_ms.max(1))))?;
        inc.set_read_timeout(Some(Duration::from_millis(20)))?;
        match circulate_members(&out, inc, orig_rank, epoch, net) {
            Ok((members, inc)) => {
                // Validate the formed topology against the agreed set:
                // our successor/predecessor must be the cyclic
                // neighbours in sorted member order.
                let position = match members.iter().position(|&m| m == orig_rank) {
                    Some(p) => p,
                    None => {
                        epoch += 1;
                        continue 'attempt;
                    }
                };
                let m = members.len();
                let want_succ = members[(position + 1) % m];
                let want_pred = members[(position + m - 1) % m];
                if m < 2 || m > n || succ_orig != want_succ || pred_origin as usize != want_pred {
                    // Inconsistent topology (epoch race): next epoch.
                    epoch += 1;
                    continue 'attempt;
                }
                inc.set_read_timeout(Some(Duration::from_millis(100)))?;
                return Ok(View {
                    epoch,
                    members,
                    position,
                    out,
                    inc,
                });
            }
            Err(_) => {
                epoch += 1;
                continue 'attempt;
            }
        }
    }
}

/// Phase B of a regroup: every tentative-ring member injects a
/// `Members` token carrying its own bit; each forwards every foreign
/// token with its own bit OR-ed in and a decremented TTL.  A ring of m
/// members passes exactly m tokens through every node, and a node's own
/// returning token carries the full member bitmap.  Returns the agreed
/// member set (sorted original ranks) and gives the predecessor socket
/// back.
fn circulate_members(
    out: &TcpStream,
    inc: TcpStream,
    orig_rank: usize,
    epoch: u32,
    net: &NetConfig,
) -> anyhow::Result<(Vec<usize>, TcpStream)> {
    let n_max = 256usize;
    let mut bitmap = [0u8; 32];
    bitmap[orig_rank / 8] |= 1 << (orig_rank % 8);
    let mut token = Vec::with_capacity(33);
    token.push(u8::MAX); // TTL: generous, only guards against cycles
    token.extend_from_slice(&bitmap);
    let mut w = &*out;
    w.write_all(&encode_frame(
        FrameType::Members,
        orig_rank as u8,
        0,
        epoch,
        &token,
    ))?;
    let budget = Duration::from_millis(net.io_timeout_ms.max(1));
    let deadline = Instant::now() + budget;
    let mut r = FrameReader::new(inc, Duration::from_millis(500));
    let mut my_set: Option<[u8; 32]> = None;
    let mut seen = 0usize;
    loop {
        anyhow::ensure!(
            Instant::now() < deadline,
            "membership circulation timed out at epoch {epoch}"
        );
        let f = match r.recv() {
            Ok(f) => f,
            Err(_) => continue, // short poll timeout: retry until deadline
        };
        if f.epoch != epoch || f.ftype != FrameType::Members || f.payload.len() != 33 {
            anyhow::bail!("membership circulation desync at epoch {epoch}");
        }
        seen += 1;
        anyhow::ensure!(seen <= n_max, "membership token storm at epoch {epoch}");
        if f.origin as usize == orig_rank {
            let mut set = [0u8; 32];
            set.copy_from_slice(&f.payload[1..33]);
            my_set = Some(set);
        } else {
            let ttl = f.payload[0];
            anyhow::ensure!(ttl > 1, "membership token TTL exhausted");
            let mut fwd = f.payload.clone();
            fwd[0] = ttl - 1;
            for (i, b) in bitmap.iter().enumerate() {
                fwd[1 + i] |= b;
            }
            w.write_all(&encode_frame(FrameType::Members, f.origin, 0, epoch, &fwd))?;
        }
        if let Some(set) = my_set {
            let members: Vec<usize> = (0..n_max)
                .filter(|i| set[i / 8] & (1 << (i % 8)) != 0)
                .collect();
            if seen >= members.len() {
                return Ok((members, r.stream));
            }
        }
    }
}

impl Ring {
    /// Bind this rank's listener and form the ring.  `fingerprint`
    /// guards against mixed-config launches: all ranks must present the
    /// same value during the Hello exchange.
    pub fn establish(spec: &RingSpec, net: &NetConfig, fingerprint: u64) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(&spec.addrs[spec.rank])
            .map_err(|e| anyhow::anyhow!("rank {}: bind {}: {e}", spec.rank, spec.addrs[spec.rank]))?;
        Self::establish_on(listener, spec, net, fingerprint)
    }

    /// Form the ring over an already-bound listener (tests and benches
    /// bind `127.0.0.1:0` first to learn their ports).
    pub fn establish_on(
        listener: TcpListener,
        spec: &RingSpec,
        net: &NetConfig,
        fingerprint: u64,
    ) -> anyhow::Result<Self> {
        Self::establish_inner(listener, spec, net, fingerprint, false)
    }

    /// Like [`Ring::establish_on`], but recovery-aware: a `Regroup`
    /// frame arriving where the `Hello` was expected means a regroup at
    /// some epoch E is already underway (this process is a respawned
    /// rank joining late under `--on-failure rejoin`) — instead of
    /// failing, the endpoint joins that regroup directly.
    pub fn establish_elastic(
        listener: TcpListener,
        spec: &RingSpec,
        net: &NetConfig,
        fingerprint: u64,
    ) -> anyhow::Result<Self> {
        Self::establish_inner(listener, spec, net, fingerprint, true)
    }

    fn establish_inner(
        listener: TcpListener,
        spec: &RingSpec,
        net: &NetConfig,
        fingerprint: u64,
        elastic: bool,
    ) -> anyhow::Result<Self> {
        let rank = spec.rank;
        let n = spec.nranks();
        let connect_timeout = Duration::from_millis(net.connect_timeout_ms.max(1));
        let io_timeout = Duration::from_millis(net.io_timeout_ms.max(1));
        let fault = FaultSpec::from_env()?;

        // Listener is bound (above or by the caller) BEFORE we connect
        // out, so every rank's connect finds every listener regardless
        // of launch order.
        let succ = &spec.addrs[(rank + 1) % n];
        let out = connect_retry(succ, connect_timeout)?;
        out.set_nodelay(true)?;
        out.set_write_timeout(Some(io_timeout))?;

        let inc = accept_deadline(&listener, connect_timeout)?;
        inc.set_nodelay(true)?;
        // Short poll quantum; recv loops re-check their own deadline.
        inc.set_read_timeout(Some(Duration::from_millis(100)))?;

        let mut writer = FrameWriter {
            stream: out,
            fault,
            epoch: 0,
            data_frames: 0,
            frames_sent: 0,
            bytes_sent: 0,
            slice_bytes_sent: 0,
            heartbeats_sent: 0,
        };
        let mut reader = FrameReader::new(inc, io_timeout);

        // Hello exchange: wiring + config sanity before any training
        // traffic.
        let mut hello = Vec::with_capacity(12);
        hello.extend_from_slice(&(n as u32).to_le_bytes());
        hello.extend_from_slice(&fingerprint.to_le_bytes());
        writer.send(FrameType::Hello, rank as u8, 0, &hello)?;
        let f = reader.recv()?;
        if elastic && f.ftype == FrameType::Regroup && f.epoch > 0 {
            // A survivor probed us mid-regroup: we are a respawned rank
            // joining late.  Drop the half-formed launch links (the
            // prober retries within its grace window) and join the
            // regroup for the announced epoch through the listener.
            let target = f.epoch;
            drop(writer);
            drop(reader);
            let view = form_view(
                &listener,
                &spec.addrs,
                rank,
                fingerprint,
                net,
                fault,
                target,
                Duration::from_millis(net.rejoin_grace_ms),
            )?;
            return Self::from_view(listener, spec, net, fingerprint, fault, view);
        }
        anyhow::ensure!(
            f.ftype == FrameType::Hello,
            "rank {rank}: expected Hello, got {:?}",
            f.ftype
        );
        let expect_pred = (rank + n - 1) % n;
        anyhow::ensure!(
            f.origin as usize == expect_pred,
            "rank {rank}: predecessor claims rank {}, expected {expect_pred} (ring miswired)",
            f.origin
        );
        anyhow::ensure!(f.payload.len() == 12, "rank {rank}: malformed Hello");
        let peer_n = u32::from_le_bytes(f.payload[..4].try_into().unwrap()) as usize;
        anyhow::ensure!(
            peer_n == n,
            "rank {rank}: predecessor believes nranks={peer_n}, we have {n}"
        );
        let peer_fp = u64::from_le_bytes(f.payload[4..12].try_into().unwrap());
        anyhow::ensure!(
            peer_fp == fingerprint,
            "rank {rank}: config fingerprint mismatch with predecessor \
             (mixed binaries or flags across the ring?)"
        );

        let writer = Arc::new(Mutex::new(writer));
        let (hb_stop, hb_join) = spawn_heartbeat(&writer, net.heartbeat_ms, rank as u8);

        Ok(Self {
            rank,
            n,
            orig_rank: rank,
            addrs: spec.addrs.clone(),
            members: (0..n).collect(),
            epoch: 0,
            fingerprint,
            net: *net,
            listener,
            fault,
            srtt_ms: 0.0,
            writer,
            reader,
            hb_stop,
            hb_join,
        })
    }

    /// Build an endpoint directly from a formed (regrouped) view — the
    /// path a respawned rank takes when it joins a regroup instead of
    /// completing the launch Hello exchange.
    fn from_view(
        listener: TcpListener,
        spec: &RingSpec,
        net: &NetConfig,
        fingerprint: u64,
        fault: Option<FaultSpec>,
        view: View,
    ) -> anyhow::Result<Self> {
        let io_timeout = Duration::from_millis(net.io_timeout_ms.max(1));
        let writer = Arc::new(Mutex::new(FrameWriter {
            stream: view.out,
            fault,
            epoch: view.epoch,
            data_frames: 0,
            frames_sent: 0,
            bytes_sent: 0,
            slice_bytes_sent: 0,
            heartbeats_sent: 0,
        }));
        let (hb_stop, hb_join) = spawn_heartbeat(&writer, net.heartbeat_ms, view.position as u8);
        Ok(Self {
            rank: view.position,
            n: view.members.len(),
            orig_rank: spec.rank,
            addrs: spec.addrs.clone(),
            members: view.members,
            epoch: view.epoch,
            fingerprint,
            net: *net,
            listener,
            fault,
            srtt_ms: 0.0,
            writer,
            reader: FrameReader::new(view.inc, io_timeout),
            hb_stop,
            hb_join,
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.n
    }

    /// Membership epoch (view number) of the current ring.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Original ranks alive in the current view, sorted ascending;
    /// `rank()` is this process's index (position) in it.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Original launch rank of this process.
    pub fn orig_rank(&self) -> usize {
        self.orig_rank
    }

    /// Feed one completed sync round's wall time into the adaptive
    /// deadline: `srtt += (sample - srtt)/8` (TCP-RTT style EWMA), and
    /// the effective frame deadline becomes `max(io_timeout_ms,
    /// 4·srtt)` — the configured value is a FLOOR, never shortened.
    pub fn observe_round(&mut self, wall: Duration) {
        let ms = wall.as_secs_f64() * 1e3;
        self.srtt_ms = if self.srtt_ms == 0.0 {
            ms
        } else {
            self.srtt_ms + (ms - self.srtt_ms) / 8.0
        };
        let eff = (self.net.io_timeout_ms as f64).max(4.0 * self.srtt_ms);
        self.reader.io_timeout = Duration::from_millis(eff.ceil() as u64);
    }

    fn send_frame(&self, ftype: FrameType, origin: u8, round: u32, payload: &[u8]) -> anyhow::Result<()> {
        self.writer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .send(ftype, origin, round, payload)
    }

    /// Send one frame stamped with an EXPLICIT epoch (regroup announces
    /// target the NEXT view while the writer still carries the current
    /// one).
    fn send_frame_at(
        &self,
        ftype: FrameType,
        origin: u8,
        round: u32,
        epoch: u32,
        payload: &[u8],
    ) -> anyhow::Result<()> {
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let old = w.epoch;
        w.epoch = epoch;
        let res = w.send(ftype, origin, round, payload);
        w.epoch = old;
        res
    }

    /// Receive the next DATA frame: heartbeats are skipped (each resets
    /// the deadline simply by arriving), aborts are forwarded around
    /// the ring and surfaced as FATAL errors.  Frames from an older
    /// membership epoch are silently dropped (fencing); a newer epoch —
    /// or a `Regroup` announce — surfaces as a recoverable
    /// [`PeerFailure`], as do transport-level receive failures.
    fn recv_data(&mut self) -> anyhow::Result<Frame> {
        loop {
            let f = self.reader.recv().map_err(|e| {
                anyhow::Error::new(PeerFailure {
                    regroup_epoch: 0,
                    reason: format!("recv from predecessor failed: {e:#}"),
                })
            })?;
            if f.epoch < self.epoch {
                continue; // stale frame from a dead view: fenced off
            }
            if f.ftype == FrameType::Regroup || f.epoch > self.epoch {
                if f.ftype == FrameType::Regroup {
                    // Forward the announce so the whole ring learns
                    // fast; best-effort, the successor may be the dead
                    // peer itself.
                    let _ =
                        self.send_frame_at(FrameType::Regroup, f.origin, f.round, f.epoch, &f.payload);
                }
                return Err(anyhow::Error::new(PeerFailure {
                    regroup_epoch: f.epoch,
                    reason: format!(
                        "rank {} announced a regroup for epoch {} (current epoch {})",
                        f.origin, f.epoch, self.epoch
                    ),
                }));
            }
            match f.ftype {
                FrameType::Heartbeat => continue,
                FrameType::Abort => {
                    let reason = String::from_utf8_lossy(&f.payload).into_owned();
                    if f.origin as usize != self.rank {
                        // Forward so the whole ring learns; best-effort,
                        // the successor may already be gone.
                        let _ = self.send_frame(FrameType::Abort, f.origin, f.round, &f.payload);
                    }
                    anyhow::bail!("rank {} aborted: {reason}", f.origin);
                }
                _ => return Ok(f),
            }
        }
    }

    /// Tear down the current view and form the surviving one at (at
    /// least) `max(proposal, epoch + 1)`.  On success the endpoint
    /// carries the new epoch, member set and position, with the
    /// transport counters carried over; on failure the caller should
    /// degrade to abort semantics.
    pub fn regroup(&mut self, proposal: u32, grace_ms: u64) -> anyhow::Result<()> {
        let target = proposal.max(self.epoch + 1);
        // Announce the regroup on the old successor link so peers that
        // have not noticed the failure yet join fast; best-effort — the
        // successor may be the dead rank.
        let _ = self.send_frame_at(
            FrameType::Regroup,
            self.rank as u8,
            0,
            target,
            &self.fingerprint.to_le_bytes(),
        );
        // Stop the heartbeat thread before replacing the writer stream.
        self.hb_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.hb_join.take() {
            let _ = h.join();
        }
        let view = form_view(
            &self.listener,
            &self.addrs,
            self.orig_rank,
            self.fingerprint,
            &self.net,
            self.fault,
            target,
            Duration::from_millis(grace_ms),
        )?;
        {
            // Swap the link streams in place: cumulative counters (and
            // the deterministic data-frame fault counter) survive the
            // view change.
            let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
            w.stream = view.out;
            w.epoch = view.epoch;
        }
        self.reader.stream = view.inc;
        self.reader.io_timeout = Duration::from_millis(self.net.io_timeout_ms.max(1));
        self.srtt_ms = 0.0;
        self.rank = view.position;
        self.n = view.members.len();
        self.members = view.members;
        self.epoch = view.epoch;
        let (stop, join) = spawn_heartbeat(&self.writer, self.net.heartbeat_ms, self.rank as u8);
        self.hb_stop = stop;
        self.hb_join = join;
        Ok(())
    }

    /// Best-effort failure propagation: send an `Abort` with a reason.
    /// Never fails — the caller is already on its error path.
    pub fn abort(&self, reason: &str) {
        let payload = reason.as_bytes();
        let capped = &payload[..payload.len().min(4096)];
        let _ = self.send_frame(FrameType::Abort, self.rank as u8, 0, capped);
    }

    /// Exchange one logical block per ring step: stream `out` (as
    /// origin `origin_out`) to the successor in ≤[`CHUNK_PAYLOAD`]
    /// chunks while collecting exactly `in_len` bytes of origin
    /// `origin_in` from the predecessor, interleaved chunk-by-chunk so
    /// the ring can never wedge on full socket buffers.
    fn exchange_raw(
        &mut self,
        ftype: FrameType,
        round: u32,
        origin_out: usize,
        out: &[u8],
        origin_in: usize,
        in_len: usize,
    ) -> anyhow::Result<Vec<u8>> {
        let mut got = Vec::with_capacity(in_len);
        let mut sent = 0;
        while sent < out.len() || got.len() < in_len {
            if sent < out.len() {
                let end = (sent + CHUNK_PAYLOAD).min(out.len());
                self.send_frame(ftype, origin_out as u8, round, &out[sent..end])
                    .map_err(|e| {
                        anyhow::Error::new(PeerFailure {
                            regroup_epoch: 0,
                            reason: format!("send to successor failed: {e:#}"),
                        })
                    })?;
                sent = end;
            }
            if got.len() < in_len {
                let f = self.recv_data()?;
                anyhow::ensure!(
                    f.ftype == ftype && f.origin as usize == origin_in && f.round == round,
                    "rank {}: protocol desync (got {:?} origin {} round {}, \
                     expected {:?} origin {} round {})",
                    self.rank,
                    f.ftype,
                    f.origin,
                    f.round,
                    ftype,
                    origin_in,
                    round
                );
                anyhow::ensure!(
                    got.len() + f.payload.len() <= in_len,
                    "rank {}: oversized block from rank {origin_in}",
                    self.rank
                );
                got.extend_from_slice(&f.payload);
            }
        }
        Ok(got)
    }

    /// Circulate `vals` so every rank sees every rank's values (all
    /// ranks must pass the SAME element count).  Returns the per-origin
    /// values, own included.  This is the ring's replacement for the
    /// in-process barrier + shared state: the stop decision and resume
    /// negotiation both ride on it.
    pub fn circulate_u64s(&mut self, vals: &[u64], round: u32) -> anyhow::Result<Vec<Vec<u64>>> {
        let (n, k) = (self.n, vals.len());
        let mut blocks: Vec<Vec<u64>> = vec![Vec::new(); n];
        blocks[self.rank] = vals.to_vec();
        for s in 0..n - 1 {
            let so = (self.rank + n - s) % n;
            let out: Vec<u8> = blocks[so].iter().flat_map(|v| v.to_le_bytes()).collect();
            let io_ = (self.rank + n - 1 - s) % n;
            let got = self.exchange_raw(FrameType::Status, round, so, &out, io_, k * 8)?;
            blocks[io_] = got
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
        }
        Ok(blocks)
    }

    /// Synchronous allreduce-average of `due` rows of both matrices
    /// across the ring, bitwise-identical to the in-process
    /// `sync::average_row` collective (see module docs).
    ///
    /// Phase 1 (gather): circulate every rank's raw due-rows block, so
    /// each rank holds all n contributions.  Phase 2: each rank
    /// averages the rows it OWNS (`row % n == rank`), accumulating
    /// per-origin contributions in origin order 0..n — the exact
    /// model-order `axpy` loop of `average_row` — and writes the means
    /// into its replica.  Phase 3 (scatter): circulate the per-owner
    /// averaged blocks; every rank copies foreign owners' means into
    /// its replica.
    pub fn allreduce_rows(
        &mut self,
        model: &SharedModel,
        due: &[Range<u32>],
        round: u32,
    ) -> anyhow::Result<()> {
        let (n, rank) = (self.n, self.rank);
        let dim = model.dim();
        let row_bytes = 8 * dim; // M_in + M_out, f32 each
        let due_rows: Vec<u32> = due.iter().flat_map(|r| r.clone()).collect();
        for &r in &due_rows {
            anyhow::ensure!(
                (r as usize) < model.vocab(),
                "due row {r} out of range for vocab {}",
                model.vocab()
            );
        }
        if due_rows.is_empty() || n == 1 {
            return Ok(());
        }

        // My raw contribution, rows in due order, [M_in | M_out] per row.
        let mut mine = Vec::with_capacity(due_rows.len() * row_bytes);
        for &r in &due_rows {
            // SAFETY: this process's trainer is quiescent during the
            // sync phase and the heartbeat thread never touches the
            // model, so access is exclusive.
            for &x in unsafe { model.row_in(r) }.iter() {
                mine.extend_from_slice(&x.to_le_bytes());
            }
            for &x in unsafe { model.row_out(r) }.iter() {
                mine.extend_from_slice(&x.to_le_bytes());
            }
        }

        // Gather: after n-1 steps every rank holds all n blocks.
        let block_len = mine.len();
        let mut blocks: Vec<Vec<u8>> = vec![Vec::new(); n];
        blocks[rank] = mine;
        for s in 0..n - 1 {
            let so = (rank + n - s) % n;
            let io_ = (rank + n - 1 - s) % n;
            let out = std::mem::take(&mut blocks[so]);
            let got = self.exchange_raw(FrameType::Slice, round, so, &out, io_, block_len)?;
            blocks[so] = out;
            blocks[io_] = got;
        }

        // Average the rows this rank owns, origin order 0..n (the
        // model order of sync::average_row), writing means into the
        // local replica and into the outgoing averaged block.
        let inv = 1.0 / n as f32;
        let mut scratch = vec![0.0f32; dim];
        let mut tmp = vec![0.0f32; dim];
        let owned: Vec<(usize, u32)> = due_rows
            .iter()
            .enumerate()
            .filter(|(_, &r)| r as usize % n == rank)
            .map(|(j, &r)| (j, r))
            .collect();
        let mut avg_mine = Vec::with_capacity(owned.len() * row_bytes);
        for &(j, r) in &owned {
            for half in 0..2 {
                let off = j * row_bytes + half * 4 * dim;
                scratch.fill(0.0);
                for block in &blocks {
                    decode_f32(&block[off..off + 4 * dim], &mut tmp);
                    axpy(inv, &tmp, &mut scratch);
                }
                // SAFETY: as above; owners partition rows disjointly.
                let dst = if half == 0 {
                    unsafe { model.row_in(r) }
                } else {
                    unsafe { model.row_out(r) }
                };
                dst.copy_from_slice(&scratch);
                for &x in scratch.iter() {
                    avg_mine.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        drop(blocks);

        // Scatter: circulate per-owner averaged blocks; apply foreign
        // owners' means.
        let owned_count = |o: usize| due_rows.iter().filter(|&&r| r as usize % n == o).count();
        let mut avg: Vec<Vec<u8>> = vec![Vec::new(); n];
        avg[rank] = avg_mine;
        for s in 0..n - 1 {
            let so = (rank + n - s) % n;
            let io_ = (rank + n - 1 - s) % n;
            let out = std::mem::take(&mut avg[so]);
            let got = self.exchange_raw(
                FrameType::AvgSlice,
                round,
                so,
                &out,
                io_,
                owned_count(io_) * row_bytes,
            )?;
            avg[so] = out;
            // Apply immediately; keep the block around for forwarding.
            let mut k = 0;
            for &r in due_rows.iter().filter(|&&r| r as usize % n == io_) {
                decode_f32(&got[k * row_bytes..k * row_bytes + 4 * dim], &mut tmp);
                // SAFETY: as above.
                unsafe { model.row_in(r) }.copy_from_slice(&tmp);
                decode_f32(&got[k * row_bytes + 4 * dim..(k + 1) * row_bytes], &mut tmp);
                // SAFETY: as above.
                unsafe { model.row_out(r) }.copy_from_slice(&tmp);
                k += 1;
            }
            avg[io_] = got;
        }
        Ok(())
    }

    /// Snapshot the transport counters.
    pub fn stats(&self) -> NetStats {
        let w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        NetStats {
            frames_sent: w.frames_sent,
            frames_recv: self.reader.frames_recv,
            bytes_sent: w.bytes_sent,
            bytes_recv: self.reader.bytes_recv,
            slice_bytes_sent: w.slice_bytes_sent,
            heartbeats_sent: w.heartbeats_sent,
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.hb_join.take() {
            let _ = h.join();
        }
    }
}

fn decode_f32(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 4 * out.len());
    for (j, slot) in out.iter_mut().enumerate() {
        *slot = f32::from_le_bytes(bytes[4 * j..4 * j + 4].try_into().unwrap());
    }
}

/// Exact Slice/AvgSlice bytes (headers included) rank `rank` SENDS in
/// one [`Ring::allreduce_rows`] over `due`: the prediction that
/// measured [`NetStats::slice_bytes_sent`] must equal — pinned by
/// `wire_bytes_prediction_is_exact` and recheck-able against any run's
/// counters.
pub fn gather_scatter_wire_bytes(due: &[Range<u32>], n: usize, rank: usize, dim: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let due_total: u64 = due.iter().map(|r| r.len() as u64).sum();
    if due_total == 0 {
        return 0;
    }
    let row_bytes = 8 * dim as u64;
    let chunk = CHUNK_PAYLOAD as u64;
    let framed = |bytes: u64| -> u64 {
        if bytes == 0 {
            0
        } else {
            bytes + (bytes + chunk - 1) / chunk * HEADER_BYTES as u64
        }
    };
    // Gather: n-1 sends of the full due block.
    let mut total = (n as u64 - 1) * framed(due_total * row_bytes);
    // Scatter: origins (rank - s) % n for s in 0..n-1, each origin's
    // owned-rows block.
    for s in 0..n - 1 {
        let o = (rank + n - s) % n;
        let owned = due
            .iter()
            .flat_map(|r| r.clone())
            .filter(|&r| r as usize % n == o)
            .count() as u64;
        total += framed(owned * row_bytes);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn local_specs(n: usize) -> (Vec<TcpListener>, Vec<RingSpec>) {
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
            .collect();
        let specs = (0..n)
            .map(|rank| RingSpec {
                rank,
                addrs: addrs.clone(),
            })
            .collect();
        (listeners, specs)
    }

    fn fast_net() -> NetConfig {
        NetConfig {
            connect_timeout_ms: 5_000,
            io_timeout_ms: 5_000,
            heartbeat_ms: 50,
            rejoin_grace_ms: 0,
        }
    }

    #[test]
    fn ring_spec_parses_and_rejects() {
        let s = RingSpec::parse("tcp:1@127.0.0.1:7000,127.0.0.1:7001").unwrap();
        assert_eq!(s.rank, 1);
        assert_eq!(s.nranks(), 2);
        // Prefix optional.
        assert_eq!(RingSpec::parse("1@a:1,b:2").unwrap(), s_plain());
        assert!(RingSpec::parse("no-at-sign").is_err());
        assert!(RingSpec::parse("x@a:1").is_err());
        assert!(RingSpec::parse("2@a:1,b:2").is_err()); // rank out of range
        assert!(RingSpec::parse("0@").is_err());
    }

    fn s_plain() -> RingSpec {
        RingSpec {
            rank: 1,
            addrs: vec!["a:1".into(), "b:2".into()],
        }
    }

    #[test]
    fn frame_roundtrip_and_corruption_detection() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let out = TcpStream::connect(addr).unwrap();
        let (inc, _) = l.accept().unwrap();
        inc.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut w = FrameWriter {
            stream: out,
            fault: None,
            epoch: 3,
            data_frames: 0,
            frames_sent: 0,
            bytes_sent: 0,
            slice_bytes_sent: 0,
            heartbeats_sent: 0,
        };
        let mut r = FrameReader::new(inc, Duration::from_millis(500));

        w.send(FrameType::Status, 2, 7, &[1, 2, 3]).unwrap();
        w.send(FrameType::Heartbeat, 2, 0, &[]).unwrap();
        let f = r.recv().unwrap();
        assert_eq!(f.ftype, FrameType::Status);
        assert_eq!(f.origin, 2);
        assert_eq!(f.round, 7);
        assert_eq!(f.epoch, 3, "epoch must survive the wire roundtrip");
        assert_eq!(f.payload, vec![1, 2, 3]);
        let hb = r.recv().unwrap();
        assert_eq!(hb.ftype, FrameType::Heartbeat);
        assert_eq!(hb.epoch, 3);
        assert!(hb.payload.is_empty());

        // Corrupt frame: valid header, payload checksum wrong.
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.push(FrameType::Status as u8);
        raw.push(0);
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.extend_from_slice(&0u32.to_le_bytes()); // epoch
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&0xBAD0_BAD0_BAD0_BAD0u64.to_le_bytes());
        raw.extend_from_slice(&[9, 9]);
        w.stream.write_all(&raw).unwrap();
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");

        // Garbage magic.
        w.stream.write_all(&[0u8; HEADER_BYTES]).unwrap();
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn torn_frame_is_rejected_as_truncation() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let mut out = TcpStream::connect(addr).unwrap();
        let (inc, _) = l.accept().unwrap();
        inc.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut r = FrameReader::new(inc, Duration::from_millis(500));
        // Header promising 100 payload bytes, connection closed after 10.
        let payload = [7u8; 100];
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.push(FrameType::Slice as u8);
        raw.push(0);
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&0u32.to_le_bytes()); // epoch
        raw.extend_from_slice(&100u32.to_le_bytes());
        raw.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        raw.extend_from_slice(&payload[..10]);
        out.write_all(&raw).unwrap();
        drop(out);
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("truncated") || err.contains("closed"), "{err}");
    }

    #[test]
    fn hello_rejects_fingerprint_mismatch() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (i, (l, spec)) in listeners.into_iter().zip(specs).enumerate() {
            handles.push(std::thread::spawn(move || {
                Ring::establish_on(l, &spec, &fast_net(), 100 + i as u64).map(|_| ())
            }));
        }
        for h in handles {
            let res = h.join().unwrap();
            assert!(res.is_err(), "mixed fingerprints must not form a ring");
            let msg = format!("{:#}", res.unwrap_err());
            assert!(msg.contains("fingerprint"), "{msg}");
        }
    }

    #[test]
    fn circulate_sees_every_rank() {
        let (listeners, specs) = local_specs(3);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 1).unwrap();
                let rank = ring.rank() as u64;
                ring.circulate_u64s(&[rank * 10, rank * 10 + 1], 1).unwrap()
            }));
        }
        for h in handles {
            let blocks = h.join().unwrap();
            for (o, vals) in blocks.iter().enumerate() {
                let o = o as u64;
                assert_eq!(vals, &vec![o * 10, o * 10 + 1]);
            }
        }
    }

    #[test]
    fn three_rank_allreduce_matches_in_process_average_bitwise() {
        let (vocab, dim, n) = (37usize, 12usize, 3usize);
        // Expected means, computed with the exact average_row arithmetic
        // (same axpy, same origin order) on copies of the initial rows.
        let inits: Vec<SharedModel> = (0..n)
            .map(|i| SharedModel::init(vocab, dim, 1000 + i as u64))
            .collect();
        let inv = 1.0 / n as f32;
        let mut want_in = vec![vec![0.0f32; dim]; vocab];
        let mut want_out = vec![vec![0.0f32; dim]; vocab];
        for r in 0..vocab as u32 {
            for m in &inits {
                axpy(inv, m.m_in().row(r), &mut want_in[r as usize]);
                axpy(inv, m.m_out().row(r), &mut want_out[r as usize]);
            }
        }

        let (listeners, specs) = local_specs(n);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let model = SharedModel::init(37, 12, 1000 + rank as u64);
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 7).unwrap();
                let due = vec![0..37u32];
                ring.allreduce_rows(&model, &due, 1).unwrap();
                let stats = ring.stats();
                (rank, model, stats)
            }));
        }
        let due = vec![0..vocab as u32];
        for h in handles {
            let (rank, model, stats) = h.join().unwrap();
            for r in 0..vocab as u32 {
                for j in 0..dim {
                    assert_eq!(
                        model.m_in().row(r)[j].to_bits(),
                        want_in[r as usize][j].to_bits(),
                        "rank {rank} M_in[{r}][{j}]"
                    );
                    assert_eq!(
                        model.m_out().row(r)[j].to_bits(),
                        want_out[r as usize][j].to_bits(),
                        "rank {rank} M_out[{r}][{j}]"
                    );
                }
            }
            // Measured slice traffic equals the analytic predictor
            // exactly — this is the calibration contract.
            assert_eq!(
                stats.slice_bytes_sent,
                gather_scatter_wire_bytes(&due, n, rank, dim),
                "rank {rank} wire bytes"
            );
            assert!(stats.frames_sent > 0 && stats.frames_recv > 0);
        }
    }

    #[test]
    fn abort_reaches_peer_with_reason() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 3).unwrap();
                if rank == 1 {
                    ring.abort("injected failure for test");
                    Ok(())
                } else {
                    ring.recv_data().map(|_| ())
                }
            }));
        }
        let r1 = handles.pop().unwrap().join().unwrap();
        let r0 = handles.pop().unwrap().join().unwrap();
        assert!(r1.is_ok());
        let err = format!("{:#}", r0.unwrap_err());
        assert!(err.contains("rank 1 aborted"), "{err}");
        assert!(err.contains("injected failure"), "{err}");
    }

    #[test]
    fn dead_peer_trips_read_deadline() {
        let (listeners, specs) = local_specs(2);
        let mut net = fast_net();
        net.io_timeout_ms = 400;
        net.heartbeat_ms = 50;
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            let net = net;
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let mut ring = Ring::establish_on(l, &spec, &net, 9).unwrap();
                if rank == 1 {
                    // Die silently without aborting: drop the ring (the
                    // closed socket is what rank 0 must detect).
                    drop(ring);
                    Ok(())
                } else {
                    let t0 = Instant::now();
                    let res = ring.recv_data().map(|_| ());
                    assert!(
                        t0.elapsed() < Duration::from_millis(2 * net.io_timeout_ms as u64 + 1000),
                        "detection took {:?}",
                        t0.elapsed()
                    );
                    res
                }
            }));
        }
        let r1 = handles.pop().unwrap().join().unwrap();
        let r0 = handles.pop().unwrap().join().unwrap();
        assert!(r1.is_ok());
        let err = format!("{:#}", r0.unwrap_err());
        assert!(
            err.contains("closed") || err.contains("silent"),
            "unexpected diagnostic: {err}"
        );
    }

    #[test]
    fn wire_bytes_predictor_edges() {
        assert_eq!(gather_scatter_wire_bytes(&[], 3, 0, 8), 0);
        assert_eq!(gather_scatter_wire_bytes(&[0..10], 1, 0, 8), 0);
        // 2 ranks, 3 rows, dim 1: block = 3*8 = 24 bytes, one chunk.
        // Gather: 1 send of 24+28; scatter: origin = rank itself owns
        // ceil/floor split of rows by parity.
        let due = vec![0..3u32];
        let b = gather_scatter_wire_bytes(&due, 2, 0, 1);
        // rank 0 owns rows 0 and 2 (2 rows): scatter block 2*8=16 + 28.
        assert_eq!(b, (24 + 28) + (16 + 28));
        let b1 = gather_scatter_wire_bytes(&due, 2, 1, 1);
        // rank 1 owns row 1: scatter block 8 + 28.
        assert_eq!(b1, (24 + 28) + (8 + 28));
    }

    #[test]
    fn chunking_splits_large_blocks() {
        // A block of 40 KiB must cost 3 headers.
        let rows = (40 * 1024) / 8; // dim 1 → 8 bytes/row
        let due = vec![0..rows as u32];
        let b = gather_scatter_wire_bytes(&due, 2, 0, 1);
        let chunk = CHUNK_PAYLOAD as u64;
        let nchunks = |bytes: u64| (bytes + chunk - 1) / chunk;
        let block = rows as u64 * 8;
        let own = due
            .iter()
            .flat_map(|r| r.clone())
            .filter(|&r| r % 2 == 0)
            .count() as u64
            * 8;
        let hdr = HEADER_BYTES as u64;
        let expect = (block + nchunks(block) * hdr) + (own + nchunks(own) * hdr);
        assert_eq!(b, expect);
        assert_eq!(nchunks(block), 3);
    }

    // -- PR 7: decode hardening, epoch fencing, adaptive deadline, regroup --

    #[test]
    fn oversized_length_header_errs_before_allocating() {
        // Valid magic/version/type with a length field far beyond
        // MAX_PAYLOAD: the reader must reject from the header alone —
        // if it allocated from the length prefix first, this test would
        // OOM rather than fail an assertion.
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.push(FrameType::Slice as u8);
        raw.push(0);
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.extend_from_slice(&u32::MAX.to_le_bytes());
        raw.extend_from_slice(&0u64.to_le_bytes());
        let mut r = FrameReader::new(std::io::Cursor::new(raw), Duration::from_millis(50));
        let err = r.recv().unwrap_err().to_string();
        assert!(err.contains("exceeds protocol max"), "{err}");
    }

    #[test]
    fn fuzzed_frames_never_panic_and_corruption_is_caught() {
        // Deterministic xorshift64* stream: random bytes, truncations
        // of a valid frame, and single-bit flips.  Every input must
        // yield a clean Err — except flips inside the type/origin/
        // round/epoch fields (bytes 6..16), which can legally decode as
        // a different valid frame; even those must never panic.
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state.wrapping_mul(0x2545_F491_4F6C_DD1D)
        };
        let valid = encode_frame(FrameType::Status, 1, 7, 2, &[1, 2, 3, 4, 5, 6, 7, 8]);
        for trial in 0..3000usize {
            let (bytes, flipped_byte) = match trial % 3 {
                0 => {
                    let len = (next() % 96) as usize;
                    ((0..len).map(|_| next() as u8).collect::<Vec<u8>>(), None)
                }
                1 => {
                    let cut = next() as usize % valid.len();
                    (valid[..cut].to_vec(), None)
                }
                _ => {
                    let mut b = valid.clone();
                    let bit = next() as usize % (b.len() * 8);
                    b[bit / 8] ^= 1 << (bit % 8);
                    (b, Some(bit / 8))
                }
            };
            let mut r = FrameReader::new(std::io::Cursor::new(bytes), Duration::from_millis(10));
            let res = r.recv();
            match flipped_byte {
                Some(b) if (6..16).contains(&b) => {} // may decode differently
                _ => assert!(res.is_err(), "trial {trial}: corrupt input accepted"),
            }
        }
    }

    #[test]
    fn adaptive_deadline_tracks_ewma_with_floor() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let mut net = fast_net();
                net.io_timeout_ms = 100; // low floor so growth is visible
                let mut ring = Ring::establish_on(l, &spec, &net, 11).unwrap();
                if ring.rank() == 0 {
                    // First sample seeds srtt directly: deadline 4·srtt.
                    ring.observe_round(Duration::from_millis(1000));
                    assert_eq!(ring.srtt_ms, 1000.0);
                    assert_eq!(ring.reader.io_timeout, Duration::from_millis(4000));
                    // EWMA step: srtt += (0 - srtt)/8.
                    ring.observe_round(Duration::from_millis(0));
                    assert_eq!(ring.srtt_ms, 875.0);
                    assert_eq!(ring.reader.io_timeout, Duration::from_millis(3500));
                    // Fast rounds decay toward — but never below — the
                    // configured floor.
                    for _ in 0..200 {
                        ring.observe_round(Duration::from_millis(0));
                    }
                    assert_eq!(ring.reader.io_timeout, Duration::from_millis(100));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stale_epochs_are_fenced_and_newer_epochs_surface_as_recoverable() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 21)?;
                if ring.rank() == 1 {
                    // A frame from a dead view (epoch 0 < receiver's 2),
                    // the frame the receiver should actually see, then a
                    // newer-epoch frame.
                    ring.send_frame_at(FrameType::Status, 1, 5, 0, &[1])?;
                    ring.send_frame_at(FrameType::Status, 1, 6, 2, &[2])?;
                    ring.send_frame_at(FrameType::Status, 1, 7, 3, &[3])?;
                    // Hold the link open until the peer read everything.
                    std::thread::sleep(Duration::from_millis(600));
                    Ok(())
                } else {
                    ring.epoch = 2; // as if this side regrouped twice
                    let f = ring.recv_data()?;
                    assert_eq!((f.round, f.epoch, &f.payload[..]), (6, 2, &[2u8][..]));
                    let err = ring.recv_data().unwrap_err();
                    let pf = peer_failure(&err).expect("newer epoch must be recoverable");
                    assert_eq!(pf.regroup_epoch, 3);
                    Ok(())
                }
            }));
        }
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn survivors_regroup_into_smaller_working_ring() {
        let (listeners, specs) = local_specs(3);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 31).unwrap();
                if rank == 1 {
                    drop(ring); // die silently (listener closes too)
                    return None;
                }
                // Let the dead rank's sockets close, then heal.
                std::thread::sleep(Duration::from_millis(100));
                ring.regroup(1, 0).unwrap();
                assert_eq!(ring.members(), &[0, 2]);
                assert_eq!(ring.nranks(), 2);
                assert!(ring.epoch() >= 1);
                // The healed ring is fully operational.
                let pos = ring.rank() as u64;
                Some((pos, ring.circulate_u64s(&[pos + 40], 1).unwrap()))
            }));
        }
        for h in handles {
            if let Some((pos, blocks)) = h.join().unwrap() {
                assert_eq!(blocks.len(), 2);
                for (o, vals) in blocks.iter().enumerate() {
                    assert_eq!(vals, &vec![o as u64 + 40], "position {pos}");
                }
            }
        }
    }

    #[test]
    fn sole_survivor_continues_solo() {
        let (listeners, specs) = local_specs(2);
        let mut handles = Vec::new();
        for (l, spec) in listeners.into_iter().zip(specs) {
            handles.push(std::thread::spawn(move || {
                let rank = spec.rank;
                let mut ring = Ring::establish_on(l, &spec, &fast_net(), 41).unwrap();
                if rank == 1 {
                    drop(ring);
                    return;
                }
                std::thread::sleep(Duration::from_millis(100));
                ring.regroup(1, 0).unwrap();
                assert_eq!(ring.members(), &[0]);
                assert_eq!(ring.nranks(), 1);
                // Collectives degenerate to the identity at n = 1.
                let blocks = ring.circulate_u64s(&[7, 8], 2).unwrap();
                assert_eq!(blocks, vec![vec![7, 8]]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
