//! The replica drivers: thread mode (N node threads in one process) and
//! TCP ring mode (N OS processes), both running the same
//! barrier-synchronous allreduce protocol (paper Sec. III-E).
//!
//! Protocol per round, every node:
//!
//! 1. train ~`sync_interval` corpus words on its shard (GEMM backend over
//!    the zero-allocation arena pipeline, exactly like the shared-memory
//!    trainer's inner loop);
//! 2. stop decision: in thread mode a barrier orders a shared done
//!    counter; on the ring every rank circulates a (done, words) status.
//!    If EVERY node has exhausted its shard×epochs, stop;
//! 3. otherwise allreduce: the round's due rows (policy) are partitioned
//!    round-robin across nodes by `row % n`, each row's owner averages
//!    the n contributions in node order, and every replica receives the
//!    means.
//!
//! Nodes that finish early keep joining rounds (contributing their frozen
//! replica) until all are done, so every node executes the same round
//! sequence.  The merged result is a final full average of all replicas.
//!
//! **Phase 1 is shared code** ([`TrainLeg`]), and the learning-rate
//! schedule is per-node (each node's schedule spans its shard×epochs
//! words), so a node's training leg is a deterministic function of
//! (config, shard, node index) — no cross-thread state.  Because both
//! collectives also reduce in the same node order with the same `axpy`
//! arithmetic, a TCP ring under any policy produces BITWISE-IDENTICAL
//! replicas to thread mode, round by round (pinned by
//! `tcp_ring_matches_thread_mode_bitwise`).
//!
//! **Failure semantics**: thread mode fails FAST — a replica that errors
//! or panics poisons the shared [`AbortBarrier`] through an RAII guard,
//! every peer's next `wait()` returns an error, and the driver reports
//! the root cause (preferring it over the echoed poison errors).  Ring
//! mode propagates an `Abort` frame and every surviving process exits
//! non-zero within the heartbeat deadline (see `dist::net`).
//!
//! **Checkpoints** (ring mode): every `--checkpoint-every` rounds each
//! rank flushes its partial superbatch (the flush is part of the round
//! schedule, so checkpointed runs are deterministic), joins the round's
//! allreduce, and atomically writes a two-slot checkpoint carrying the
//! model plus all mutable trainer state (round, epoch, reader position,
//! lr progress, RNG).  `--resume` negotiates the newest round EVERY rank
//! can load (slot retention bounds the skew to one checkpoint period)
//! and continues; a resumed run is bitwise-identical to the same run
//! left uninterrupted (pinned by `tcp_checkpoint_resume_is_bitwise`).

use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use super::barrier::{AbortBarrier, Poisoned};
use super::fault::FaultSpec;
use super::net::{gather_scatter_wire_bytes, peer_failure, NetConfig, NetStats, Ring, RingSpec};
use super::node::{DistConfig, OnFailure};
use super::sync::{average_row, SyncPolicy};
use crate::config::TrainConfig;
use crate::corpus::reader::MAX_SENTENCE_LEN;
use crate::corpus::shard::{shards_for_len, Shard};
use crate::corpus::source::{Corpus, SourceReader};
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::model::io as model_io;
use crate::model::io::Checkpoint;
use crate::model::{set_access_node, ShardMap, SharedModel};
use crate::runtime::topology::{self, Topology};
use crate::sampling::batch::{BatchBuilder, SuperbatchArena};
use crate::sampling::unigram::UnigramSampler;
use crate::train::lr::LrState;
use crate::train::route::{Exchange, Outbox, RouteSink, RowRouter};
use crate::train::sgd_gemm::GemmBackend;
use crate::train::Backend;
use crate::util::rng::Xoshiro256ss;

/// Per-node synchronization accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncStats {
    /// Allreduce rounds this node joined.
    pub rounds: u64,
    /// Model rows (× both matrices) due across those rounds.
    pub rows_synced: u64,
    /// Bytes this node moves on the wire: the ring-allreduce model
    /// (`2·(N-1)/N × payload`) in thread mode, the exact gather+scatter
    /// frame bytes in TCP mode.
    pub wire_bytes: u64,
}

/// Result of a distributed run.
#[derive(Debug)]
pub struct DistOutcome {
    /// The merged (full-average) model.
    pub model: SharedModel,
    /// Corpus words processed across all nodes (× epochs).
    pub words: u64,
    /// Wall-clock seconds.
    pub secs: f64,
    /// Per-node sync accounting (TCP mode: this rank's only).
    pub sync_stats: Vec<SyncStats>,
    /// Measured transport counters (TCP mode only).
    pub net: Option<NetStats>,
}

/// Checkpoint/resume policy for the TCP driver.
#[derive(Clone, Debug, Default)]
pub struct CheckpointPolicy {
    /// Base path; per-rank two-slot files live at
    /// `<base>.rank<k>.{a,b}`.  `None` disables checkpointing.
    pub base: Option<std::path::PathBuf>,
    /// Checkpoint every this many sync rounds (≥ 1).
    pub every: u64,
    /// Resume from the newest round every rank can load.
    pub resume: bool,
}

impl CheckpointPolicy {
    pub fn disabled() -> Self {
        Self {
            base: None,
            every: 1,
            resume: false,
        }
    }
}

/// Start state of one training ATTEMPT — either the launch attempt
/// (fresh init / `--resume`) or a post-recovery attempt: the model every
/// member starts from, the corpus passes already completed by previous
/// attempts, and the words those attempts already accounted.
///
/// A recovery attempt is deliberately a FRESH run over the remaining
/// passes: new shard geometry over the surviving world size, new
/// per-position RNG streams, and an lr schedule spanning only the
/// remaining words (restarting at the configured peak rate).  That makes
/// a healed run bitwise-equal to a clean run launched from the same
/// rollback state — the recovery-determinism test oracle.
#[derive(Debug)]
pub struct AttemptStart {
    /// The (merged) model every member begins the attempt with.
    pub model: SharedModel,
    /// Corpus passes completed before this attempt.
    pub epochs_done: usize,
    /// Raw words accounted by previous attempts (survivors' checkpoint
    /// totals; a dead rank's post-checkpoint words are lost — see
    /// EXPERIMENTS.md §Elastic-recovery for the honest accounting).
    pub words_base: u64,
}

/// Fingerprint stamped into an attempt's checkpoints: the launch
/// fingerprint for epoch 0 (PR-6 layout, `--resume` compatible), salted
/// with the membership epoch for recovery attempts so rollback never
/// crosses attempts.
fn attempt_fp(fp: u64, ck_epoch: u32) -> u64 {
    fp ^ (ck_epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The per-node learning-rate schedule: spans this node's share of the
/// corpus (`ceil(total/n)` words), so the schedule is a pure function of
/// node-local progress — deterministic, and identical between thread and
/// TCP mode by construction.
fn node_lr_state(cfg: &TrainConfig, scale_lr: bool, total_words: u64, n: usize) -> LrState {
    let n = n.max(1);
    let per_node = (total_words + n as u64 - 1) / n as u64;
    if scale_lr {
        LrState::dist_scaled(cfg.lr, cfg.lr_min_frac, per_node, n)
    } else {
        LrState::linear(cfg.lr, cfg.lr_min_frac, per_node)
    }
}

/// One node's phase-1 training leg: reader, epoch/position accounting,
/// RNG, arena pipeline and lr schedule.  Shared verbatim by the thread
/// and TCP drivers so their training arithmetic cannot drift apart —
/// the TCP↔thread bitwise-parity guarantee rests on this being the SAME
/// code, not equivalent code.
struct TrainLeg<'a> {
    cfg: &'a TrainConfig,
    source: &'a Corpus<'a>,
    shard: Shard,
    subsampler: &'a Subsampler,
    backend: GemmBackend,
    builder: BatchBuilder<'a>,
    arena: SuperbatchArena,
    sent: Vec<u32>,
    reader: SourceReader<'a>,
    rng: Xoshiro256ss,
    lr: LrState,
    epoch: usize,
    /// Sentences consumed in the current epoch (checkpoint replay
    /// position).
    sentences_in_epoch: u64,
    exhausted: bool,
    /// Raw words read since the last lr advance.
    raw_words: u64,
    /// Cumulative raw words this node has processed.
    words: u64,
}

impl<'a> TrainLeg<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        cfg: &'a TrainConfig,
        source: &'a Corpus<'a>,
        shard: Shard,
        sampler: &'a UnigramSampler,
        subsampler: &'a Subsampler,
        lr: LrState,
        idx: usize,
    ) -> anyhow::Result<Self> {
        let backend = GemmBackend::new(cfg.dim, cfg.batch, cfg.samples())
            .with_sigmoid(cfg.sigmoid_mode)
            .with_kernel(cfg.kernel)
            .with_reuse(cfg.reuse);
        let rng = Xoshiro256ss::new(cfg.seed ^ (idx as u64 * 0x5D1_77F + 13));
        let builder =
            BatchBuilder::new(sampler, cfg.window, cfg.batch, cfg.negative)
                .with_reuse(cfg.reuse);
        // Sentence-slack sizing: same overshoot bound as the
        // shared-memory trainer (fill_arena appends whole sentences).
        let arena =
            SuperbatchArena::with_sentence_slack(cfg.superbatch, cfg.batch, cfg.samples());
        let reader = source.open_range(shard.start, shard.end)?;
        Ok(Self {
            cfg,
            source,
            shard,
            subsampler,
            backend,
            builder,
            arena,
            sent: Vec::with_capacity(MAX_SENTENCE_LEN),
            reader,
            rng,
            lr,
            epoch: 0,
            sentences_in_epoch: 0,
            exhausted: false,
            raw_words: 0,
            words: 0,
        })
    }

    fn advance_lr(&mut self) -> f32 {
        let lr = self.lr.advance(self.raw_words);
        self.words += self.raw_words;
        self.raw_words = 0;
        lr
    }

    /// Train ~`interval` raw corpus words (whole sentences) into
    /// `model`.  On shard×epochs exhaustion, flushes the tail and marks
    /// the leg exhausted; subsequent calls are no-ops.
    fn train_chunk(
        &mut self,
        interval: u64,
        model: &SharedModel,
        outbox: &mut Option<Outbox<'_>>,
    ) -> anyhow::Result<()> {
        let mut processed = 0u64;
        while !self.exhausted && processed < interval {
            match self.reader.next_sentence_into(&mut self.sent)? {
                false => {
                    self.epoch += 1;
                    self.sentences_in_epoch = 0;
                    if self.epoch >= self.cfg.epochs {
                        self.exhausted = true;
                        break;
                    }
                    self.reader = self.source.open_range(self.shard.start, self.shard.end)?;
                    continue;
                }
                true => {}
            }
            self.sentences_in_epoch += 1;
            processed += self.sent.len() as u64;
            self.raw_words += self.sent.len() as u64;
            self.subsampler.filter(&mut self.sent, &mut self.rng);
            match outbox.as_mut() {
                None => self
                    .builder
                    .fill_arena(&self.sent, &mut self.rng, &mut self.arena),
                Some(ob) => {
                    let mut sink = RouteSink::new(&mut self.arena, ob);
                    self.builder
                        .fill_arena_routed(&self.sent, &mut self.rng, &mut sink);
                }
            }
            if self.arena.len() >= self.cfg.superbatch {
                let lr = self.advance_lr();
                self.backend.process_arena(model.store(), &self.arena, lr)?;
                self.arena.clear();
            }
        }
        if self.exhausted {
            self.flush_partial(model)?;
        }
        Ok(())
    }

    /// Process whatever sits in the arena and account pending words.
    /// Called on exhaustion and before every checkpoint (the flush is
    /// part of the deterministic round schedule).
    fn flush_partial(&mut self, model: &SharedModel) -> anyhow::Result<()> {
        if !self.arena.is_empty() {
            let lr = self.advance_lr();
            self.backend.process_arena(model.store(), &self.arena, lr)?;
            self.arena.clear();
        } else if self.raw_words > 0 {
            self.advance_lr();
        }
        Ok(())
    }

    /// Restore the leg to a checkpointed position: epoch, reader
    /// position (sentences are SKIPPED without consuming trainer RNG —
    /// reading touches no randomness), RNG state and lr progress.
    fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        self.epoch = ck.epoch as usize;
        self.exhausted = self.epoch >= self.cfg.epochs;
        self.rng = Xoshiro256ss::from_state(ck.rng);
        self.lr.restore(ck.lr_words);
        self.words = ck.words_done;
        self.raw_words = 0;
        self.sentences_in_epoch = 0;
        self.arena.clear();
        if !self.exhausted {
            self.reader = self.source.open_range(self.shard.start, self.shard.end)?;
            for i in 0..ck.sentences_in_epoch {
                anyhow::ensure!(
                    self.reader.next_sentence_into(&mut self.sent)?,
                    "checkpoint reader position {i}/{} is beyond the shard \
                     (corpus changed since the checkpoint?)",
                    ck.sentences_in_epoch
                );
            }
            self.sentences_in_epoch = ck.sentences_in_epoch;
        }
        Ok(())
    }
}

/// Train `dist.nodes` model replicas over shards of `corpus` with
/// periodic sub-model (or full) synchronization, and merge.
pub fn train_distributed(
    cfg: &TrainConfig,
    dist: &DistConfig,
    corpus: &Path,
    vocab: &Vocab,
) -> anyhow::Result<DistOutcome> {
    cfg.validate()?;
    anyhow::ensure!(dist.nodes >= 1, "need at least one node");
    anyhow::ensure!(dist.sync_interval >= 1, "sync_interval must be >= 1");
    // Same dispatch policy as the shared-memory trainer (`--simd`).
    crate::linalg::simd::configure(cfg.simd)?;
    let n = dist.nodes;

    let sampler = UnigramSampler::alias(vocab, cfg.unigram_power);
    let subsampler = Subsampler::new(vocab, cfg.sample);
    let total_words = vocab.total_words() * cfg.epochs as u64;
    // Same ingest policy as the shared-memory trainer: the encoded-cache
    // backends shard over text-byte geometry, so node shards are
    // identical across `--corpus-cache` modes.
    let source = Corpus::open(corpus, vocab, &cfg.corpus_cache)?;
    let shards = shards_for_len(source.shard_len(), n);
    // Every replica starts from the SAME init (the paper's replicas do).
    // Under `--numa {auto,<nodes>}` each replica becomes NODE-LOCAL:
    // allocation here maps untouched zero pages, and the replica's own
    // pinned thread performs the (bitwise-identical) init, so first-touch
    // places the whole replica on its node.  Cross-socket traffic then
    // flows only through the existing batched allreduce rounds instead of
    // per-row Hogwild scatters.  `--numa off` keeps the pre-NUMA
    // main-thread init bit-for-bit.
    let topo = topology::resolve(cfg.numa)?;
    let mut models: Vec<SharedModel> = (0..n)
        .map(|_| match &topo {
            None => SharedModel::init(vocab.len(), cfg.dim, cfg.seed),
            Some(_) => SharedModel::alloc(vocab.len(), cfg.dim),
        })
        .collect();

    let barrier = AbortBarrier::new(n);
    let done_nodes = AtomicUsize::new(0);
    let start = Instant::now();

    let results: Vec<std::thread::Result<anyhow::Result<(SyncStats, u64)>>> =
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (idx, shard) in shards.iter().enumerate() {
                let (models, barrier, done_nodes) = (&models[..], &barrier, &done_nodes);
                let (sampler, subsampler) = (&sampler, &subsampler);
                let source = &source;
                let policy = dist.policy.clone();
                let topo = topo.as_ref();
                let fault = dist.fault;
                let scale_lr = dist.scale_lr;
                handles.push(scope.spawn(move || {
                    node_loop(NodeCtx {
                        cfg,
                        dist_interval: dist.sync_interval,
                        policy,
                        idx,
                        shard: *shard,
                        source,
                        vocab,
                        models,
                        barrier,
                        done_nodes,
                        sampler,
                        subsampler,
                        topo,
                        fault,
                        scale_lr,
                        total_words,
                    })
                }));
            }
            handles.into_iter().map(|h| h.join()).collect()
        });

    // Prefer the ROOT CAUSE: a node's own error or panic over the
    // poison echoes every released peer reports.
    let mut stats = Vec::with_capacity(n);
    let mut words = 0u64;
    let (mut root, mut poison) = (None, None);
    let mut panicked = false;
    for r in results {
        match r {
            Err(_) => panicked = true,
            Ok(Ok((st, w))) => {
                stats.push(st);
                words += w;
            }
            Ok(Err(e)) => {
                if e.downcast_ref::<Poisoned>().is_some() {
                    poison.get_or_insert(e);
                } else {
                    root.get_or_insert(e);
                }
            }
        }
    }
    if let Some(e) = root {
        return Err(e);
    }
    if panicked {
        anyhow::bail!("a replica thread panicked (see stderr); run aborted");
    }
    if let Some(e) = poison {
        return Err(e);
    }

    // Final full merge: one full-model averaging round (same collective
    // as the per-round sync), then replica 0 is the merged model.
    if n > 1 {
        let mut scratch = vec![0.0f32; cfg.dim];
        for r in 0..vocab.len() as u32 {
            average_row(&models, r, &mut scratch);
        }
    }

    Ok(DistOutcome {
        model: models.swap_remove(0),
        words,
        secs: start.elapsed().as_secs_f64(),
        sync_stats: stats,
        net: None,
    })
}

/// Borrowed context for one node thread (keeps the spawn closure tidy).
struct NodeCtx<'a> {
    cfg: &'a TrainConfig,
    dist_interval: u64,
    policy: SyncPolicy,
    idx: usize,
    shard: Shard,
    source: &'a Corpus<'a>,
    vocab: &'a Vocab,
    models: &'a [SharedModel],
    barrier: &'a AbortBarrier,
    done_nodes: &'a AtomicUsize,
    sampler: &'a UnigramSampler,
    subsampler: &'a Subsampler,
    /// `Some` = NUMA mode: pin this node thread and first-touch its
    /// replica before training.
    topo: Option<&'a Topology>,
    fault: Option<FaultSpec>,
    scale_lr: bool,
    total_words: u64,
}

fn node_loop(ctx: NodeCtx<'_>) -> anyhow::Result<(SyncStats, u64)> {
    // Poison the barrier on ANY unclean exit — `?`-errors and panics
    // both — so peers blocked in `wait()` fail fast instead of hanging
    // (the PR-5 `ProducerGuard` discipline).
    let guard = ctx.barrier.guard(&format!("replica {}", ctx.idx));
    let out = node_loop_inner(&ctx)?;
    guard.disarm();
    Ok(out)
}

fn node_loop_inner(ctx: &NodeCtx<'_>) -> anyhow::Result<(SyncStats, u64)> {
    let cfg = ctx.cfg;
    let n = ctx.models.len();
    let model = &ctx.models[ctx.idx];
    if let Some(t) = ctx.topo {
        // Pin FIRST, then init + allocate scratch: the replica's pages
        // and this worker's arena land on the pinned node.  The init is
        // bitwise-identical to `SharedModel::init(_, _, cfg.seed)`; other
        // replicas read this one only inside allreduce rounds, which the
        // phase-2 barrier orders after every node's init + training leg.
        t.pin_to_node(ctx.idx % t.nodes());
        // Debug remote-row counter context (no-op in release; replica
        // models are flat, so nothing counts — replica-per-node is the
        // ~0%-remote configuration by construction).
        set_access_node(Some(ctx.idx % t.nodes()));
        model.first_touch_init(cfg.seed);
    }
    let lr = node_lr_state(cfg, ctx.scale_lr, ctx.total_words, n);
    let mut leg = TrainLeg::new(
        cfg,
        ctx.source,
        ctx.shard,
        ctx.sampler,
        ctx.subsampler,
        lr,
        ctx.idx,
    )?;
    // `--route` on the replica driver: a replica is ONE pinned worker
    // over ONE node-local model, so ownership routing collapses to the
    // local path by construction — the router classifies every window
    // back to its single consumer.  We still drive the routed fill so
    // the knob exercises the same generator end to end (identical RNG
    // consumption and window order ⇒ replica results stay bitwise
    // unchanged; windows simply never enter a mailbox).
    let routed = cfg.route.head_k(ctx.vocab).map(|head_k| {
        (
            RowRouter::new(ShardMap::contiguous(ctx.vocab.len(), 1), head_k),
            Exchange::new(1, 1, 1, cfg.batch, cfg.samples()),
        )
    });
    let mut outbox = routed.as_ref().map(|(r, e)| Outbox::new(e, r, 0));
    let mut scratch = vec![0.0f32; cfg.dim];
    let mut stats = SyncStats::default();
    let mut signalled_done = false;
    let mut round: u32 = 1;

    loop {
        // Phase 1: train ~sync_interval words of this node's shard.
        leg.train_chunk(ctx.dist_interval, model, &mut outbox)?;
        if let Some(f) = ctx.fault {
            if f.panics_replica(ctx.idx) && round == 1 {
                panic!(
                    "PW2V_FAULT panic-replica={}: injected replica panic",
                    ctx.idx
                );
            }
        }
        if leg.exhausted && !signalled_done {
            ctx.done_nodes.fetch_add(1, Ordering::SeqCst);
            signalled_done = true;
        }

        // Phase 2: uniform stop decision.  The barrier orders every
        // node's `done_nodes` update before every node's read, so all
        // replicas take the same branch.
        ctx.barrier.wait()?;
        if ctx.done_nodes.load(Ordering::SeqCst) == n {
            break;
        }

        // Phase 3: allreduce the round's due rows; rows are partitioned
        // round-robin across nodes so writes never collide.
        let due = ctx.policy.rows_due(ctx.vocab.len(), round);
        let mut due_rows = 0u64;
        for range in &due {
            due_rows += range.len() as u64;
            for r in range.clone() {
                if r as usize % n == ctx.idx {
                    average_row(ctx.models, r, &mut scratch);
                }
            }
        }
        stats.rounds += 1;
        stats.rows_synced += 2 * due_rows;
        // Ring allreduce wire cost per node: 2·(N-1)/N × payload.
        let payload = 2 * due_rows * cfg.dim as u64 * 4;
        stats.wire_bytes += 2 * payload * (n as u64 - 1) / n as u64;
        ctx.barrier.wait()?;
        round += 1;
    }
    Ok((stats, leg.words))
}

/// Train this process's replica as rank `spec.rank` of a TCP ring,
/// binding the listener from the spec (see [`train_tcp_ring_on`]).
#[allow(clippy::too_many_arguments)]
pub fn train_tcp_ring(
    cfg: &TrainConfig,
    dist: &DistConfig,
    spec: &RingSpec,
    net: &NetConfig,
    ckpt: &CheckpointPolicy,
    corpus: &Path,
    vocab: &Vocab,
) -> anyhow::Result<DistOutcome> {
    train_tcp_ring_on(None, cfg, dist, spec, net, ckpt, corpus, vocab)
}

/// [`train_tcp_ring`] over an optionally pre-bound listener (tests bind
/// `127.0.0.1:0` to learn ports before launching ranks).
///
/// Under `--on-failure {shrink,rejoin}` this is the self-healing driver:
/// the training loop runs inside a recovery loop that, on a recoverable
/// peer failure, regroups the ring into the surviving view, elects the
/// rollback checkpoint round, merges the survivors' rollback models and
/// restarts a fresh attempt over the remaining corpus passes.  Any
/// failure during recovery itself degrades to abort semantics.
#[allow(clippy::too_many_arguments)]
pub fn train_tcp_ring_on(
    listener: Option<TcpListener>,
    cfg: &TrainConfig,
    dist: &DistConfig,
    spec: &RingSpec,
    net: &NetConfig,
    ckpt: &CheckpointPolicy,
    corpus: &Path,
    vocab: &Vocab,
) -> anyhow::Result<DistOutcome> {
    cfg.validate()?;
    anyhow::ensure!(dist.sync_interval >= 1, "sync_interval must be >= 1");
    anyhow::ensure!(ckpt.every >= 1, "checkpoint interval must be >= 1");
    anyhow::ensure!(
        !ckpt.resume || ckpt.base.is_some(),
        "--resume requires --checkpoint"
    );
    anyhow::ensure!(
        dist.on_failure == OnFailure::Abort || ckpt.base.is_some(),
        "--on-failure {:?} requires --checkpoint (recovery rolls back to checkpoints)",
        dist.on_failure
    );
    crate::linalg::simd::configure(cfg.simd)?;
    let n = spec.nranks();
    let rank = spec.rank;
    // Ring-wide config guard: mixed flags across ranks are refused at
    // Hello time, before any training traffic.
    let fp = cfg.fingerprint() ^ vocab.fingerprint() ^ n as u64;

    let sampler = UnigramSampler::alias(vocab, cfg.unigram_power);
    let subsampler = Subsampler::new(vocab, cfg.sample);
    let source = Corpus::open(corpus, vocab, &cfg.corpus_cache)?;

    // Deterministic "respawned rank joins late" delay
    // (`PW2V_FAULT respawn-after=MS`), injected before ring formation.
    if let Some(f) = FaultSpec::from_env()? {
        if let Some(ms) = f.respawn_delay_ms() {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }

    let elastic = dist.on_failure != OnFailure::Abort;
    let mut ring = match (listener, elastic) {
        (Some(l), false) => Ring::establish_on(l, spec, net, fp)?,
        (Some(l), true) => Ring::establish_elastic(l, spec, net, fp)?,
        (None, false) => Ring::establish(spec, net, fp)?,
        (None, true) => {
            // A respawned rank re-binds the port its dead predecessor
            // process freed moments ago; lingering half-closed sockets
            // can hold the address briefly, so retry within the connect
            // budget instead of failing the rejoin.
            let deadline =
                Instant::now() + std::time::Duration::from_millis(net.connect_timeout_ms.max(1));
            let l = loop {
                match TcpListener::bind(&spec.addrs[rank]) {
                    Ok(l) => break l,
                    Err(_) if Instant::now() < deadline => {
                        std::thread::sleep(std::time::Duration::from_millis(50));
                    }
                    Err(e) => {
                        anyhow::bail!("rank {rank}: bind {}: {e}", spec.addrs[rank])
                    }
                }
            };
            Ring::establish_elastic(l, spec, net, fp)?
        }
    };
    let start = Instant::now();
    let res = drive_ring(
        &mut ring,
        cfg,
        dist,
        net,
        ckpt,
        fp,
        &source,
        vocab,
        &sampler,
        &subsampler,
    );
    match res {
        Ok((model, words, stats)) => Ok(DistOutcome {
            model,
            words,
            secs: start.elapsed().as_secs_f64(),
            sync_stats: vec![stats],
            net: Some(ring.stats()),
        }),
        Err(e) => {
            // Propagate the failure around the ring so every survivor
            // exits with a diagnostic instead of hanging in allreduce.
            ring.abort(&format!("rank {rank}: {e:#}"));
            Err(e.context(format!("rank {rank} failed")))
        }
    }
}

/// Run a ring attempt from an explicit [`AttemptStart`] instead of a
/// fresh init: every rank of `spec` trains the REMAINING
/// `cfg.epochs - start.epochs_done` corpus passes from `start.model`
/// over `spec.nranks()` shards.  This is exactly the attempt a healed
/// run restarts after rollback, exposed so tests can build the
/// reference run the recovery-determinism guarantee is stated against.
#[allow(clippy::too_many_arguments)]
pub fn train_tcp_ring_from(
    listener: Option<TcpListener>,
    cfg: &TrainConfig,
    dist: &DistConfig,
    spec: &RingSpec,
    net: &NetConfig,
    ckpt: &CheckpointPolicy,
    corpus: &Path,
    vocab: &Vocab,
    start: AttemptStart,
) -> anyhow::Result<DistOutcome> {
    cfg.validate()?;
    anyhow::ensure!(dist.sync_interval >= 1, "sync_interval must be >= 1");
    anyhow::ensure!(ckpt.every >= 1, "checkpoint interval must be >= 1");
    crate::linalg::simd::configure(cfg.simd)?;
    let rank = spec.rank;
    let fp = cfg.fingerprint() ^ vocab.fingerprint() ^ spec.nranks() as u64;
    let sampler = UnigramSampler::alias(vocab, cfg.unigram_power);
    let subsampler = Subsampler::new(vocab, cfg.sample);
    let source = Corpus::open(corpus, vocab, &cfg.corpus_cache)?;
    let mut ring = match listener {
        Some(l) => Ring::establish_on(l, spec, net, fp)?,
        None => Ring::establish(spec, net, fp)?,
    };
    let t0 = Instant::now();
    let res = tcp_node_loop(
        &mut ring,
        cfg,
        dist,
        ckpt,
        fp,
        &source,
        vocab,
        &sampler,
        &subsampler,
        Some(start),
        0,
    );
    match res {
        Ok((model, words, stats)) => Ok(DistOutcome {
            model,
            words,
            secs: t0.elapsed().as_secs_f64(),
            sync_stats: vec![stats],
            net: Some(ring.stats()),
        }),
        Err(e) => {
            ring.abort(&format!("rank {rank}: {e:#}"));
            Err(e.context(format!("rank {rank} failed")))
        }
    }
}

/// The recovery loop around [`tcp_node_loop`]: run attempts until one
/// completes.  Under `--on-failure abort` any error is final (the PR-6
/// path, bit for bit).  Under shrink/rejoin a recoverable
/// [`peer_failure`] triggers regroup → rollback election → a fresh
/// attempt over the healed view; any OTHER error — including a failure
/// during the recovery itself — propagates, degrading to abort
/// semantics.
#[allow(clippy::too_many_arguments)]
fn drive_ring(
    ring: &mut Ring,
    cfg: &TrainConfig,
    dist: &DistConfig,
    net: &NetConfig,
    ckpt: &CheckpointPolicy,
    fp: u64,
    source: &Corpus<'_>,
    vocab: &Vocab,
    sampler: &UnigramSampler,
    subsampler: &Subsampler,
) -> anyhow::Result<(SharedModel, u64, SyncStats)> {
    // Sync accounting accumulated across attempts.
    let mut acc = SyncStats::default();
    // Checkpoint namespace of the attempt currently on disk:
    // (membership epoch, this process's position in that view).
    let mut prev_ck = (0u32, ring.orig_rank());
    // Progress base the NEXT attempt inherits.
    let (mut base_epochs, mut base_words) = (0usize, 0u64);
    let mut start: Option<AttemptStart> = None;

    if ring.epoch() > 0 {
        // `establish_elastic` joined a regroup directly: this is a
        // respawned rank re-admitted under `--on-failure rejoin`.
        // Recover before training (its launch-attempt checkpoints feed
        // the election like every other member's).
        let s = elect_rollback(ring, cfg, ckpt, fp, vocab, prev_ck, base_epochs, base_words)?;
        (base_epochs, base_words) = (s.epochs_done, s.words_base);
        prev_ck = (ring.epoch(), ring.rank());
        start = Some(s);
    }

    loop {
        // Launch attempt = epoch 0 (PR-6 checkpoint layout); healed
        // attempts namespace their checkpoints by membership epoch.
        let ck_epoch = ring.epoch();
        let res = tcp_node_loop(
            ring, cfg, dist, ckpt, fp, source, vocab, sampler, subsampler,
            start.take(), ck_epoch,
        );
        let err = match res {
            Ok((model, words, stats)) => {
                acc.rounds += stats.rounds;
                acc.rows_synced += stats.rows_synced;
                acc.wire_bytes += stats.wire_bytes;
                return Ok((model, words, acc));
            }
            Err(e) => e,
        };
        if dist.on_failure == OnFailure::Abort {
            return Err(err);
        }
        let Some(pf) = peer_failure(&err) else {
            return Err(err); // not a peer failure: abort semantics
        };
        let proposal = pf.regroup_epoch;
        eprintln!(
            "rank {}: peer failure at epoch {} ({}); regrouping",
            ring.orig_rank(),
            ring.epoch(),
            pf.reason
        );
        let grace = match dist.on_failure {
            OnFailure::Rejoin => net.rejoin_grace_ms,
            _ => 0,
        };
        ring.regroup(proposal, grace)
            .map_err(|e| e.context("regroup after peer failure (degrading to abort)"))?;
        let s = elect_rollback(ring, cfg, ckpt, fp, vocab, prev_ck, base_epochs, base_words)
            .map_err(|e| e.context("rollback recovery (degrading to abort)"))?;
        (base_epochs, base_words) = (s.epochs_done, s.words_base);
        prev_ck = (ring.epoch(), ring.rank());
        start = Some(s);
    }
}

/// Rollback election on a freshly healed view: agree on the newest
/// checkpoint round EVERY member can load from its previous attempt,
/// load + verify it, merge the members' rollback models into one (a
/// full-model allreduce — every member ends bitwise-identical), and
/// account the progress the merged state embodies.
#[allow(clippy::too_many_arguments)]
fn elect_rollback(
    ring: &mut Ring,
    cfg: &TrainConfig,
    ckpt: &CheckpointPolicy,
    fp: u64,
    vocab: &Vocab,
    prev_ck: (u32, usize),
    base_epochs: usize,
    base_words: u64,
) -> anyhow::Result<AttemptStart> {
    let base = ckpt
        .base
        .as_deref()
        .ok_or_else(|| anyhow::anyhow!("recovery requires --checkpoint"))?;
    let (prev_epoch, prev_pos) = prev_ck;
    // Round election (same shape as `--resume` negotiation, but over
    // the healed membership and the previous attempt's namespace).
    let latest = model_io::latest_checkpoint_epoch(base, prev_epoch, prev_pos)
        .map(|c| c.round)
        .unwrap_or(0);
    let all = ring.circulate_u64s(&[latest], 0)?;
    let target = all.iter().map(|v| v[0]).min().unwrap_or(0);
    anyhow::ensure!(
        target > 0,
        "cannot roll back: a member of the healed view has no loadable \
         checkpoint (latest rounds per member: {:?})",
        all.iter().map(|v| v[0]).collect::<Vec<_>>()
    );
    let ck = checkpoint_at_round(base, prev_epoch, prev_pos, target).ok_or_else(|| {
        anyhow::anyhow!(
            "no checkpoint at elected rollback round {target} \
             (attempt epoch {prev_epoch}, position {prev_pos}; have latest {latest})"
        )
    })?;
    anyhow::ensure!(
        ck.fingerprint == attempt_fp(fp, prev_epoch),
        "rollback checkpoint was written under a different config or \
         attempt (fingerprint mismatch) — refusing to recover"
    );
    anyhow::ensure!(
        ck.m_in.vocab() == vocab.len() && ck.m_in.dim() == cfg.dim,
        "rollback checkpoint model is {}x{}, expected {}x{}",
        ck.m_in.vocab(),
        ck.m_in.dim(),
        vocab.len(),
        cfg.dim
    );
    // Attempt-relative progress: every member of one attempt started
    // from the same base, so min/sum over the view compose with it.
    let agg = ring.circulate_u64s(&[ck.epoch as u64, ck.words_done], 1)?;
    let epochs_min = agg.iter().map(|v| v[0]).min().unwrap_or(0) as usize;
    let words: u64 = agg.iter().map(|v| v[1]).sum();
    let model = SharedModel::new(ck.m_in, ck.m_out);
    if ring.nranks() > 1 && vocab.len() > 0 {
        ring.allreduce_rows(&model, &[0..vocab.len() as u32], 2)?;
    }
    eprintln!(
        "rank {}: rolled back to round {target} of attempt epoch {prev_epoch}: \
         {} member(s), {} corpus pass(es) done, continuing as position {}",
        ring.orig_rank(),
        ring.nranks(),
        base_epochs + epochs_min,
        ring.rank()
    );
    Ok(AttemptStart {
        model,
        epochs_done: base_epochs + epochs_min,
        words_base: base_words + words,
    })
}

/// Newest checkpoint with EXACTLY the negotiated round among a
/// position's two slots in attempt-epoch `epoch`'s namespace.
fn checkpoint_at_round(base: &Path, epoch: u32, pos: usize, round: u64) -> Option<Checkpoint> {
    for slot in 0..2 {
        if let Ok(ck) =
            model_io::load_checkpoint(model_io::checkpoint_slot_path_epoch(base, epoch, pos, slot))
        {
            if ck.round == round {
                return Some(ck);
            }
        }
    }
    None
}

#[allow(clippy::too_many_arguments)]
fn tcp_node_loop(
    ring: &mut Ring,
    cfg: &TrainConfig,
    dist: &DistConfig,
    ckpt: &CheckpointPolicy,
    fp: u64,
    source: &Corpus<'_>,
    vocab: &Vocab,
    sampler: &UnigramSampler,
    subsampler: &Subsampler,
    start: Option<AttemptStart>,
    ck_epoch: u32,
) -> anyhow::Result<(SharedModel, u64, SyncStats)> {
    let n = ring.nranks();
    let rank = ring.rank();
    // Shard geometry follows the CURRENT view: a healed attempt
    // re-shards the corpus over the shrunken (or restored) world size.
    let shard = shards_for_len(source.shard_len(), n)[rank];
    // A recovery attempt is a FRESH run over the remaining corpus
    // passes: epochs shrink by what the rollback state embodies, and
    // the lr schedule restarts at peak over that remaining work (the
    // honest accounting — see EXPERIMENTS.md §Elastic recovery).
    let mut acfg = cfg.clone();
    let words_base = match &start {
        Some(s) => {
            acfg.epochs = cfg.epochs.saturating_sub(s.epochs_done);
            s.words_base
        }
        None => 0,
    };
    let cfg = &acfg;
    let total_words = vocab.total_words() * cfg.epochs as u64;
    let lr = node_lr_state(cfg, dist.scale_lr, total_words, n);
    let mut leg = TrainLeg::new(cfg, source, shard, sampler, subsampler, lr, rank)?;
    if cfg.epochs == 0 {
        // Nothing left to train (the failure hit after the last epoch
        // boundary a checkpoint captured): the exhaustion check fires
        // only at EOF, so flag it up front or this attempt would run
        // one full extra pass.
        leg.exhausted = true;
    }
    let mut round: u32 = 1;

    let model = if let Some(s) = start {
        // Healed attempt: every member starts from the SAME merged
        // rollback model (elect_rollback allreduced it).
        s.model
    } else if ckpt.resume {
        let base = ckpt
            .base
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("--resume requires --checkpoint"))?;
        // Negotiate the newest round EVERY rank can load.  Two slots
        // always suffice: ranks checkpoint the same rounds, so the
        // latest-round skew across a crash is at most one period, and
        // the previous period is still on disk in the other slot.
        let latest = model_io::latest_checkpoint(base, rank)
            .map(|c| c.round)
            .unwrap_or(0);
        let all = ring.circulate_u64s(&[latest], 0)?;
        let target = all.iter().map(|v| v[0]).min().unwrap_or(0);
        anyhow::ensure!(
            target > 0,
            "resume requested but at least one rank has no loadable checkpoint \
             (latest rounds per rank: {:?})",
            all.iter().map(|v| v[0]).collect::<Vec<_>>()
        );
        let ck = checkpoint_at_round(base, 0, rank, target).ok_or_else(|| {
            anyhow::anyhow!(
                "rank {rank}: no checkpoint at negotiated round {target} \
                 (have latest {latest})"
            )
        })?;
        anyhow::ensure!(
            ck.fingerprint == fp,
            "checkpoint was written under a different config/corpus \
             (fingerprint mismatch) — refusing to resume"
        );
        anyhow::ensure!(
            ck.rank as usize == rank && ck.nranks as usize == n,
            "checkpoint is for rank {}/{} but this process is rank {rank}/{n}",
            ck.rank,
            ck.nranks
        );
        anyhow::ensure!(
            ck.m_in.vocab() == vocab.len() && ck.m_in.dim() == cfg.dim,
            "checkpoint model is {}x{}, expected {}x{}",
            ck.m_in.vocab(),
            ck.m_in.dim(),
            vocab.len(),
            cfg.dim
        );
        leg.restore(&ck)?;
        round = u32::try_from(target)
            .map_err(|_| anyhow::anyhow!("checkpoint round {target} out of range"))?
            + 1;
        SharedModel::new(ck.m_in, ck.m_out)
    } else {
        SharedModel::init(vocab.len(), cfg.dim, cfg.seed)
    };

    // Same routed-fill no-op as the thread driver (one worker, one
    // replica) so the knob stays parity-exact across transports.
    let routed = cfg.route.head_k(vocab).map(|head_k| {
        (
            RowRouter::new(ShardMap::contiguous(vocab.len(), 1), head_k),
            Exchange::new(1, 1, 1, cfg.batch, cfg.samples()),
        )
    });
    let mut outbox = routed.as_ref().map(|(r, e)| Outbox::new(e, r, 0));
    let mut stats = SyncStats::default();

    let words_global;
    loop {
        let round_t0 = Instant::now();
        // Phase 1 — IDENTICAL code to thread mode (TrainLeg).
        leg.train_chunk(dist.sync_interval, &model, &mut outbox)?;
        let ck_due = ckpt.base.is_some() && round as u64 % ckpt.every == 0;
        if ck_due {
            // Deterministic flush: checkpointed state never carries a
            // partial arena, and the flush is part of the schedule, so
            // any two runs with the same checkpoint cadence stay
            // bitwise-identical (crashed+resumed or not).
            leg.flush_partial(&model)?;
        }

        // Phase 2 — stop decision: circulate (done, words).
        let st = ring.circulate_u64s(&[leg.exhausted as u64, leg.words], round)?;
        if st.iter().all(|v| v[0] == 1) {
            words_global = words_base + st.iter().map(|v| v[1]).sum::<u64>();
            break;
        }

        // Phase 3 — the round's allreduce.
        let due = dist.policy.rows_due(vocab.len(), round);
        ring.allreduce_rows(&model, &due, round)?;
        let due_rows: u64 = due.iter().map(|r| r.len() as u64).sum();
        stats.rounds += 1;
        stats.rows_synced += 2 * due_rows;
        stats.wire_bytes += gather_scatter_wire_bytes(&due, n, rank, cfg.dim);
        // Feed the adaptive deadline: a full round (train + circulate +
        // allreduce) is the unit of progress peers wait on.
        ring.observe_round(round_t0.elapsed());

        if ck_due {
            let base = ckpt
                .base
                .as_deref()
                .ok_or_else(|| anyhow::anyhow!("checkpoint due without a base path"))?;
            let slot = ((round as u64 / ckpt.every) % 2) as usize;
            let snapshot = Checkpoint {
                rank: rank as u32,
                nranks: n as u32,
                round: round as u64,
                epoch: leg.epoch as u32,
                sentences_in_epoch: leg.sentences_in_epoch,
                words_done: leg.words,
                lr_words: leg.lr.words_done(),
                rng: leg.rng.state(),
                // Salted per attempt: a healed run's checkpoints never
                // collide with (or pass verification as) the previous
                // attempt's, and pre-failure files stay intact.
                fingerprint: attempt_fp(fp, ck_epoch),
                m_in: model.m_in().clone(),
                m_out: model.m_out().clone(),
            };
            model_io::save_checkpoint(
                model_io::checkpoint_slot_path_epoch(base, ck_epoch, rank, slot),
                &snapshot,
            )?;
        }
        round += 1;
    }

    // Final full merge: every rank ends with the same merged model,
    // bitwise equal to thread mode's merged replica 0.
    if n > 1 && vocab.len() > 0 {
        ring.allreduce_rows(&model, &[0..vocab.len() as u32], round)?;
    }
    Ok((model, words_global, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{LatentModel, SyntheticConfig};

    fn tiny_corpus(seed: u64) -> (std::path::PathBuf, Vocab) {
        let mut scfg = SyntheticConfig::test_tiny();
        scfg.tokens = 40_000;
        scfg.seed = seed;
        let lm = LatentModel::new(scfg);
        let path = std::env::temp_dir().join(format!(
            "pw2v_dist_corpus_{seed}_{}.txt",
            std::process::id()
        ));
        lm.write_corpus(&path).unwrap();
        let vocab = Vocab::build_from_file(&path, 1).unwrap();
        (path, vocab)
    }

    /// Run an n-rank loopback ring in-process: one thread per rank,
    /// ports learned by binding `127.0.0.1:0` first.
    fn run_ring(
        n: usize,
        cfg: &TrainConfig,
        dist: &DistConfig,
        ckpt: &CheckpointPolicy,
        path: &std::path::Path,
        vocab: &Vocab,
    ) -> Vec<anyhow::Result<DistOutcome>> {
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
            .collect();
        let net = NetConfig {
            connect_timeout_ms: 10_000,
            io_timeout_ms: 10_000,
            heartbeat_ms: 50,
            rejoin_grace_ms: 0,
        };
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (rank, l) in listeners.into_iter().enumerate() {
                let addrs = addrs.clone();
                let (cfg, dist, ckpt) = (cfg.clone(), dist.clone(), ckpt.clone());
                handles.push(scope.spawn(move || {
                    let spec = RingSpec { rank, addrs };
                    train_tcp_ring_on(
                        Some(l),
                        &cfg,
                        &dist,
                        &spec,
                        &net,
                        &ckpt,
                        path,
                        vocab,
                    )
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn replicas_train_and_account_traffic() {
        let (path, vocab) = tiny_corpus(41);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 4_000;
        dist.policy = SyncPolicy::submodel_for_vocab(vocab.len());
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(out.sync_stats.len(), 3);
        assert!(out.net.is_none());
        // Every node joined the same number of rounds.
        let r0 = out.sync_stats[0].rounds;
        assert!(r0 >= 1, "no sync rounds at interval 4k over 40k words");
        for st in &out.sync_stats {
            assert_eq!(st.rounds, r0);
            assert!(st.rows_synced > 0);
            assert!(st.wire_bytes > 0);
            // Sub-model sync must move fewer rows than full sync would.
            assert!(st.rows_synced < st.rounds * 2 * vocab.len() as u64);
        }
        // All corpus words processed (each node its shard, one epoch).
        assert_eq!(out.words, vocab.total_words());
        // The merged model moved away from init.
        let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        assert_ne!(out.model.m_in().data(), init.m_in().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_node_has_no_wire_traffic() {
        let (path, vocab) = tiny_corpus(43);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(1);
        dist.sync_interval = 5_000;
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(out.words, vocab.total_words());
        assert_eq!(out.sync_stats[0].wire_bytes, 0);
        std::fs::remove_file(&path).ok();
    }

    /// Per-node lr schedules make the whole thread-mode run a pure
    /// function of (config, corpus): two runs are bitwise identical.
    #[test]
    fn thread_mode_is_deterministic_run_to_run() {
        let (path, vocab) = tiny_corpus(67);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 4_000;
        let a = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        let b = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(a.words, b.words);
        assert_eq!(a.model.m_in().data(), b.model.m_in().data());
        assert_eq!(a.model.m_out().data(), b.model.m_out().data());
        std::fs::remove_file(&path).ok();
    }

    /// The replica protocol over the encoded cache: identical word
    /// accounting and a usable merged model (node shards are text-byte
    /// based on both ingest paths, so the streams match sentence for
    /// sentence).
    #[test]
    fn replicas_train_from_encoded_cache() {
        let (path, vocab) = tiny_corpus(59);
        let cache =
            crate::corpus::encoded::EncodedCorpus::cache_path_for(&path);
        std::fs::remove_file(&cache).ok();
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        cfg.corpus_cache = crate::config::CorpusCacheMode::Auto;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 8_000;
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(out.words, vocab.total_words());
        assert!(cache.exists());
        let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        assert_ne!(out.model.m_in().data(), init.m_in().data());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    /// `--route` on the replica driver is a provable no-op: one worker
    /// per replica means every window classifies back to its own arena,
    /// and the routed generator consumes the RNG identically — replicas
    /// (and their barrier-ordered merge) stay bitwise unchanged.
    #[test]
    fn route_knob_is_bitwise_noop_on_replicas() {
        let (path, vocab) = tiny_corpus(61);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 8_000;
        let off = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        cfg.route = crate::train::route::RouteMode::Owner;
        let routed = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(off.words, routed.words);
        assert_eq!(off.model.m_in().data(), routed.model.m_in().data());
        assert_eq!(off.model.m_out().data(), routed.model.m_out().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn full_policy_moves_whole_model() {
        let (path, vocab) = tiny_corpus(47);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 8_000;
        dist.policy = SyncPolicy::Full;
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        let st = &out.sync_stats[0];
        assert_eq!(st.rows_synced, st.rounds * 2 * vocab.len() as u64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replicas_converge_toward_each_other() {
        // After syncing, replicas share the hot head: their row-0 vectors
        // must be closer to each other than independently trained models.
        let (path, vocab) = tiny_corpus(53);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        cfg.epochs = 2;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 2_000; // many rounds over 80k words
        dist.policy = SyncPolicy::submodel_for_vocab(vocab.len());
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert!(out.sync_stats[0].rounds > 5);
        assert_eq!(out.words, 2 * vocab.total_words());
        std::fs::remove_file(&path).ok();
    }

    /// The replica-panic deadlock fix: a panicking replica poisons the
    /// barrier, peers fail fast, and the driver reports the panic — the
    /// whole run errors out instead of hanging tier-1 forever.
    #[test]
    fn panicking_replica_fails_fast_instead_of_hanging() {
        let (path, vocab) = tiny_corpus(71);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 4_000;
        dist.fault = Some(FaultSpec::PanicReplica(1));
        let t0 = Instant::now();
        let err = train_distributed(&cfg, &dist, &path, &vocab).unwrap_err();
        assert!(
            t0.elapsed().as_secs() < 60,
            "fail-fast took {:?}",
            t0.elapsed()
        );
        assert!(
            format!("{err:#}").contains("panicked"),
            "unexpected error: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    /// THE acceptance criterion: a loopback TCP ring under full sync
    /// produces bitwise-identical embeddings to thread mode.
    #[test]
    fn tcp_ring_matches_thread_mode_bitwise() {
        let (path, vocab) = tiny_corpus(73);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 4_000;
        dist.policy = SyncPolicy::Full;
        let threads = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        let outs = run_ring(3, &cfg, &dist, &CheckpointPolicy::disabled(), &path, &vocab);
        for (rank, out) in outs.into_iter().enumerate() {
            let out = out.unwrap();
            assert_eq!(out.words, threads.words, "rank {rank} words");
            assert_eq!(
                out.model.m_in().data(),
                threads.model.m_in().data(),
                "rank {rank} M_in differs from thread mode"
            );
            assert_eq!(
                out.model.m_out().data(),
                threads.model.m_out().data(),
                "rank {rank} M_out differs from thread mode"
            );
            let net = out.net.expect("tcp mode reports net stats");
            assert!(net.frames_sent > 0 && net.bytes_sent > 0);
        }
        std::fs::remove_file(&path).ok();
    }

    /// Sub-model policy parity too: the rotating cold-tail slices pick
    /// the same rows on both transports (same round numbering).
    #[test]
    fn tcp_ring_matches_thread_mode_under_submodel_policy() {
        let (path, vocab) = tiny_corpus(79);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 6_000;
        dist.policy = SyncPolicy::submodel_for_vocab(vocab.len());
        let threads = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        let outs = run_ring(2, &cfg, &dist, &CheckpointPolicy::disabled(), &path, &vocab);
        for out in outs {
            let out = out.unwrap();
            assert_eq!(out.model.m_in().data(), threads.model.m_in().data());
        }
        std::fs::remove_file(&path).ok();
    }

    /// The resume parity guarantee: run A checkpoints and completes;
    /// run B resumes from A's mid-run checkpoints and must land on the
    /// SAME final model, bit for bit.
    #[test]
    fn tcp_checkpoint_resume_is_bitwise() {
        let (path, vocab) = tiny_corpus(83);
        let base = std::env::temp_dir().join(format!(
            "pw2v_ck_resume_{}",
            std::process::id()
        ));
        for rank in 0..3 {
            for slot in 0..2 {
                std::fs::remove_file(model_io::checkpoint_slot_path(&base, rank, slot)).ok();
            }
        }
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 3_000;
        dist.policy = SyncPolicy::Full;
        let ckpt = CheckpointPolicy {
            base: Some(base.clone()),
            every: 2,
            resume: false,
        };
        let full: Vec<DistOutcome> = run_ring(3, &cfg, &dist, &ckpt, &path, &vocab)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        // Checkpoints must exist (≥ 2 rounds ran) and be loadable.
        let ck = model_io::latest_checkpoint(&base, 0).expect("checkpoint written");
        assert!(ck.round >= 2);

        let resume = CheckpointPolicy {
            base: Some(base.clone()),
            every: 2,
            resume: true,
        };
        let resumed: Vec<DistOutcome> = run_ring(3, &cfg, &dist, &resume, &path, &vocab)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(resumed[0].words, full[0].words);
        assert_eq!(
            resumed[0].model.m_in().data(),
            full[0].model.m_in().data(),
            "resumed run diverged from the uninterrupted run"
        );
        assert_eq!(
            resumed[0].model.m_out().data(),
            full[0].model.m_out().data()
        );
        for rank in 0..3 {
            for slot in 0..2 {
                std::fs::remove_file(model_io::checkpoint_slot_path(&base, rank, slot)).ok();
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_without_checkpoints_is_refused() {
        let (path, vocab) = tiny_corpus(89);
        let base = std::env::temp_dir().join(format!(
            "pw2v_ck_missing_{}",
            std::process::id()
        ));
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 5_000;
        let ckpt = CheckpointPolicy {
            base: Some(base.clone()),
            every: 2,
            resume: true,
        };
        let outs = run_ring(2, &cfg, &dist, &ckpt, &path, &vocab);
        for out in outs {
            let err = format!("{:#}", out.unwrap_err());
            assert!(err.contains("no loadable checkpoint"), "{err}");
        }
        std::fs::remove_file(&path).ok();
    }

    /// Recovery needs checkpoints to roll back to: shrink/rejoin without
    /// `--checkpoint` is refused up front, before any networking.
    #[test]
    fn on_failure_without_checkpoint_is_refused() {
        let (path, vocab) = tiny_corpus(97);
        let cfg = TrainConfig::test_tiny();
        let mut dist = DistConfig::for_nodes(2);
        dist.on_failure = OnFailure::Shrink;
        let spec = RingSpec {
            rank: 0,
            addrs: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
        };
        let err = train_tcp_ring_on(
            None,
            &cfg,
            &dist,
            &spec,
            &NetConfig::default(),
            &CheckpointPolicy::disabled(),
            &path,
            &vocab,
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("requires --checkpoint"),
            "{err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    /// The elastic driver with NO failure is a bitwise no-op: a healthy
    /// run under `--on-failure shrink` lands on the same model as the
    /// PR-6 abort path (establish_elastic, drive_ring, adaptive
    /// deadlines — none of it may perturb training arithmetic).
    #[test]
    fn elastic_driver_without_failure_is_bitwise_noop() {
        let (path, vocab) = tiny_corpus(101);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 5_000;
        let mk_base = |tag: &str| {
            let b = std::env::temp_dir().join(format!("pw2v_ck_{tag}_{}", std::process::id()));
            for rank in 0..2 {
                for slot in 0..2 {
                    std::fs::remove_file(model_io::checkpoint_slot_path(&b, rank, slot)).ok();
                }
            }
            b
        };
        let base_a = mk_base("noop_abort");
        let ck_a = CheckpointPolicy {
            base: Some(base_a.clone()),
            every: 2,
            resume: false,
        };
        let abort: Vec<_> = run_ring(2, &cfg, &dist, &ck_a, &path, &vocab)
            .into_iter()
            .map(|o| o.unwrap())
            .collect();
        dist.on_failure = OnFailure::Shrink;
        let base_s = mk_base("noop_shrink");
        let ck_s = CheckpointPolicy {
            base: Some(base_s.clone()),
            every: 2,
            resume: false,
        };
        let healed: Vec<_> = run_ring(2, &cfg, &dist, &ck_s, &path, &vocab)
            .into_iter()
            .map(|o| o.unwrap())
            .collect();
        for (a, h) in abort.iter().zip(&healed) {
            assert_eq!(a.words, h.words);
            assert_eq!(a.model.m_in().data(), h.model.m_in().data());
            assert_eq!(a.model.m_out().data(), h.model.m_out().data());
        }
        for b in [&base_a, &base_s] {
            for rank in 0..2 {
                for slot in 0..2 {
                    std::fs::remove_file(model_io::checkpoint_slot_path(b, rank, slot)).ok();
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
