//! The replica driver: N node threads, private model replicas, barrier-
//! synchronous allreduce rounds (paper Sec. III-E).
//!
//! Protocol per round, every node:
//!
//! 1. train ~`sync_interval` corpus words on its shard (GEMM backend over
//!    the zero-allocation arena pipeline, exactly like the shared-memory
//!    trainer's inner loop);
//! 2. barrier; if EVERY node has exhausted its shard×epochs, stop;
//! 3. otherwise allreduce: the round's due rows (policy) are partitioned
//!    round-robin across nodes, and each node averages its rows across
//!    all replicas in place; barrier; next round.
//!
//! Nodes that finish early keep joining rounds (contributing their frozen
//! replica) until all are done, so every node executes the same barrier
//! sequence — the same discipline an MPI implementation needs.  Traffic
//! accounting assumes a ring allreduce (`2·(N-1)/N × payload` per node
//! per round), matching the cluster cost model in `perfmodel::network`.
//!
//! The merged result is a final full average of all replicas.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;
use std::time::Instant;

use super::node::DistConfig;
use super::sync::{average_row, SyncPolicy};
use crate::config::TrainConfig;
use crate::corpus::reader::MAX_SENTENCE_LEN;
use crate::corpus::shard::{shards_for_len, Shard};
use crate::corpus::source::Corpus;
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::model::{set_access_node, ShardMap, SharedModel};
use crate::runtime::topology::{self, Topology};
use crate::sampling::batch::{BatchBuilder, SuperbatchArena};
use crate::sampling::unigram::UnigramSampler;
use crate::train::lr::LrState;
use crate::train::route::{Exchange, Outbox, RouteSink, RowRouter};
use crate::train::sgd_gemm::GemmBackend;
use crate::train::Backend;
use crate::util::rng::Xoshiro256ss;

/// Per-node synchronization accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncStats {
    /// Allreduce rounds this node joined.
    pub rounds: u64,
    /// Model rows (× both matrices) due across those rounds.
    pub rows_synced: u64,
    /// Bytes this node moves on the wire under a ring allreduce.
    pub wire_bytes: u64,
}

/// Result of a distributed run.
#[derive(Debug)]
pub struct DistOutcome {
    /// The merged (full-average) model.
    pub model: SharedModel,
    /// Corpus words processed across all nodes (× epochs).
    pub words: u64,
    /// Wall-clock seconds.
    pub secs: f64,
    /// Per-node sync accounting.
    pub sync_stats: Vec<SyncStats>,
}

/// Train `dist.nodes` model replicas over shards of `corpus` with
/// periodic sub-model (or full) synchronization, and merge.
pub fn train_distributed(
    cfg: &TrainConfig,
    dist: &DistConfig,
    corpus: &Path,
    vocab: &Vocab,
) -> anyhow::Result<DistOutcome> {
    cfg.validate()?;
    anyhow::ensure!(dist.nodes >= 1, "need at least one node");
    anyhow::ensure!(dist.sync_interval >= 1, "sync_interval must be >= 1");
    // Same dispatch policy as the shared-memory trainer (`--simd`).
    crate::linalg::simd::configure(cfg.simd)?;
    let n = dist.nodes;

    let sampler = UnigramSampler::alias(vocab, cfg.unigram_power);
    let subsampler = Subsampler::new(vocab, cfg.sample);
    let total_words = vocab.total_words() * cfg.epochs as u64;
    let lr_state = if dist.scale_lr {
        LrState::dist_scaled(cfg.lr, cfg.lr_min_frac, total_words, n)
    } else {
        LrState::linear(cfg.lr, cfg.lr_min_frac, total_words)
    };
    // Same ingest policy as the shared-memory trainer: the encoded-cache
    // backends shard over text-byte geometry, so node shards are
    // identical across `--corpus-cache` modes.
    let source = Corpus::open(corpus, vocab, &cfg.corpus_cache)?;
    let shards = shards_for_len(source.shard_len(), n);
    // Every replica starts from the SAME init (the paper's replicas do).
    // Under `--numa {auto,<nodes>}` each replica becomes NODE-LOCAL:
    // allocation here maps untouched zero pages, and the replica's own
    // pinned thread performs the (bitwise-identical) init, so first-touch
    // places the whole replica on its node.  Cross-socket traffic then
    // flows only through the existing batched allreduce rounds instead of
    // per-row Hogwild scatters.  `--numa off` keeps the pre-NUMA
    // main-thread init bit-for-bit.
    let topo = topology::resolve(cfg.numa)?;
    let mut models: Vec<SharedModel> = (0..n)
        .map(|_| match &topo {
            None => SharedModel::init(vocab.len(), cfg.dim, cfg.seed),
            Some(_) => SharedModel::alloc(vocab.len(), cfg.dim),
        })
        .collect();

    let barrier = Barrier::new(n);
    let done_nodes = AtomicUsize::new(0);
    let words_done = AtomicUsize::new(0);
    let start = Instant::now();

    let stats: Vec<SyncStats> = std::thread::scope(
        |scope| -> anyhow::Result<Vec<SyncStats>> {
            let mut handles = Vec::new();
            for (idx, shard) in shards.iter().enumerate() {
                let (models, barrier, done_nodes, words_done, lr_state) = (
                    &models[..],
                    &barrier,
                    &done_nodes,
                    &words_done,
                    &lr_state,
                );
                let (sampler, subsampler) = (&sampler, &subsampler);
                let source = &source;
                let policy = dist.policy.clone();
                let topo = topo.as_ref();
                handles.push(scope.spawn(move || {
                    node_loop(NodeCtx {
                        cfg,
                        dist_interval: dist.sync_interval,
                        policy,
                        idx,
                        shard: *shard,
                        source,
                        vocab,
                        models,
                        barrier,
                        done_nodes,
                        words_done,
                        lr_state,
                        sampler,
                        subsampler,
                        topo,
                    })
                }));
            }
            let mut stats = Vec::with_capacity(n);
            for h in handles {
                stats.push(
                    h.join()
                        .map_err(|_| anyhow::anyhow!("node thread panicked"))??,
                );
            }
            Ok(stats)
        },
    )?;

    // Final full merge: one full-model averaging round (same collective
    // as the per-round sync), then replica 0 is the merged model.
    if n > 1 {
        let mut scratch = vec![0.0f32; cfg.dim];
        for r in 0..vocab.len() as u32 {
            average_row(&models, r, &mut scratch);
        }
    }

    Ok(DistOutcome {
        model: models.swap_remove(0),
        words: words_done.load(Ordering::Relaxed) as u64,
        secs: start.elapsed().as_secs_f64(),
        sync_stats: stats,
    })
}

/// Borrowed context for one node thread (keeps the spawn closure tidy).
struct NodeCtx<'a> {
    cfg: &'a TrainConfig,
    dist_interval: u64,
    policy: SyncPolicy,
    idx: usize,
    shard: Shard,
    source: &'a Corpus<'a>,
    vocab: &'a Vocab,
    models: &'a [SharedModel],
    barrier: &'a Barrier,
    done_nodes: &'a AtomicUsize,
    words_done: &'a AtomicUsize,
    lr_state: &'a LrState,
    sampler: &'a UnigramSampler,
    subsampler: &'a Subsampler,
    /// `Some` = NUMA mode: pin this node thread and first-touch its
    /// replica before training.
    topo: Option<&'a Topology>,
}

fn node_loop(ctx: NodeCtx<'_>) -> anyhow::Result<SyncStats> {
    let cfg = ctx.cfg;
    let n = ctx.models.len();
    let model = &ctx.models[ctx.idx];
    if let Some(t) = ctx.topo {
        // Pin FIRST, then init + allocate scratch: the replica's pages
        // and this worker's arena land on the pinned node.  The init is
        // bitwise-identical to `SharedModel::init(_, _, cfg.seed)`; other
        // replicas read this one only inside allreduce rounds, which the
        // phase-2 barrier orders after every node's init + training leg.
        t.pin_to_node(ctx.idx % t.nodes());
        // Debug remote-row counter context (no-op in release; replica
        // models are flat, so nothing counts — replica-per-node is the
        // ~0%-remote configuration by construction).
        set_access_node(Some(ctx.idx % t.nodes()));
        model.first_touch_init(cfg.seed);
    }
    let mut backend = GemmBackend::new(cfg.dim, cfg.batch, cfg.samples())
        .with_sigmoid(cfg.sigmoid_mode)
        .with_kernel(cfg.kernel);
    let mut rng =
        Xoshiro256ss::new(cfg.seed ^ (ctx.idx as u64 * 0x5D1_77F + 13));
    let builder =
        BatchBuilder::new(ctx.sampler, cfg.window, cfg.batch, cfg.negative);
    // `--route` on the replica driver: a replica is ONE pinned worker
    // over ONE node-local model, so ownership routing collapses to the
    // local path by construction — the router classifies every window
    // back to its single consumer.  We still drive the routed fill so
    // the knob exercises the same generator end to end (identical RNG
    // consumption and window order ⇒ replica results stay bitwise
    // unchanged; windows simply never enter a mailbox).
    let routed = cfg.route.head_k(ctx.vocab).map(|head_k| {
        (
            RowRouter::new(ShardMap::contiguous(ctx.vocab.len(), 1), head_k),
            Exchange::new(1, 1, 1, cfg.batch, cfg.samples()),
        )
    });
    let mut outbox = routed.as_ref().map(|(r, e)| Outbox::new(e, r, 0));
    // Sentence-slack sizing: same overshoot bound as the shared-memory
    // trainer (fill_arena appends whole sentences).
    let mut arena = SuperbatchArena::with_sentence_slack(
        cfg.superbatch,
        cfg.batch,
        cfg.samples(),
    );
    let mut sent: Vec<u32> = Vec::with_capacity(MAX_SENTENCE_LEN);
    let mut scratch = vec![0.0f32; cfg.dim];
    let mut stats = SyncStats::default();

    let mut reader = ctx.source.open_range(ctx.shard.start, ctx.shard.end)?;
    let mut epoch = 0usize;
    let mut exhausted = false;
    let mut signalled_done = false;
    let mut raw_words = 0u64;
    let mut round: u32 = 1;
    // A node that fails must KEEP joining barriers (acting exhausted) or
    // the other N-1 nodes deadlock in `Barrier::wait`; the error is held
    // here and returned once the whole group stops.
    let mut failure: Option<anyhow::Error> = None;

    loop {
        // Phase 1: train ~sync_interval words of this node's shard.
        let mut processed = 0u64;
        while !exhausted && processed < ctx.dist_interval {
            match reader.next_sentence_into(&mut sent) {
                Err(e) => {
                    failure = Some(e);
                    exhausted = true;
                    break;
                }
                Ok(false) => {
                    epoch += 1;
                    if epoch >= cfg.epochs {
                        exhausted = true;
                        break;
                    }
                    match ctx.source.open_range(ctx.shard.start, ctx.shard.end)
                    {
                        Ok(r) => reader = r,
                        Err(e) => {
                            failure = Some(e);
                            exhausted = true;
                            break;
                        }
                    }
                    continue;
                }
                Ok(true) => {}
            }
            processed += sent.len() as u64;
            raw_words += sent.len() as u64;
            ctx.subsampler.filter(&mut sent, &mut rng);
            match outbox.as_mut() {
                None => builder.fill_arena(&sent, &mut rng, &mut arena),
                Some(ob) => {
                    let mut sink = RouteSink::new(&mut arena, ob);
                    builder.fill_arena_routed(&sent, &mut rng, &mut sink);
                }
            }
            if arena.len() >= cfg.superbatch {
                let lr = ctx.lr_state.advance(raw_words);
                ctx.words_done
                    .fetch_add(raw_words as usize, Ordering::Relaxed);
                raw_words = 0;
                if let Err(e) = backend.process_arena(model.store(), &arena, lr) {
                    failure = Some(e);
                    exhausted = true;
                }
                arena.clear();
                if exhausted {
                    break;
                }
            }
        }
        if exhausted && failure.is_none() && !arena.is_empty() {
            let lr = ctx.lr_state.advance(raw_words);
            ctx.words_done
                .fetch_add(raw_words as usize, Ordering::Relaxed);
            raw_words = 0;
            if let Err(e) = backend.process_arena(model.store(), &arena, lr) {
                failure = Some(e);
            }
            arena.clear();
        } else if exhausted && raw_words > 0 {
            ctx.lr_state.advance(raw_words);
            ctx.words_done
                .fetch_add(raw_words as usize, Ordering::Relaxed);
            raw_words = 0;
        }
        if exhausted && !signalled_done {
            ctx.done_nodes.fetch_add(1, Ordering::SeqCst);
            signalled_done = true;
        }

        // Phase 2: uniform stop decision.  The barrier orders every
        // node's `done_nodes` update before every node's read, so all
        // replicas take the same branch.
        ctx.barrier.wait();
        if ctx.done_nodes.load(Ordering::SeqCst) == n {
            break;
        }

        // Phase 3: allreduce the round's due rows; rows are partitioned
        // round-robin across nodes so writes never collide.
        let due = ctx.policy.rows_due(ctx.vocab.len(), round);
        let mut due_rows = 0u64;
        for range in &due {
            due_rows += range.len() as u64;
            for r in range.clone() {
                if r as usize % n == ctx.idx {
                    average_row(ctx.models, r, &mut scratch);
                }
            }
        }
        stats.rounds += 1;
        stats.rows_synced += 2 * due_rows;
        // Ring allreduce wire cost per node: 2·(N-1)/N × payload.
        let payload = 2 * due_rows * cfg.dim as u64 * 4;
        stats.wire_bytes += 2 * payload * (n as u64 - 1) / n as u64;
        ctx.barrier.wait();
        round += 1;
    }
    match failure {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{LatentModel, SyntheticConfig};

    fn tiny_corpus(seed: u64) -> (std::path::PathBuf, Vocab) {
        let mut scfg = SyntheticConfig::test_tiny();
        scfg.tokens = 40_000;
        scfg.seed = seed;
        let lm = LatentModel::new(scfg);
        let path = std::env::temp_dir().join(format!(
            "pw2v_dist_corpus_{seed}_{}.txt",
            std::process::id()
        ));
        lm.write_corpus(&path).unwrap();
        let vocab = Vocab::build_from_file(&path, 1).unwrap();
        (path, vocab)
    }

    #[test]
    fn replicas_train_and_account_traffic() {
        let (path, vocab) = tiny_corpus(41);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 4_000;
        dist.policy = SyncPolicy::submodel_for_vocab(vocab.len());
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(out.sync_stats.len(), 3);
        // Every node joined the same number of rounds.
        let r0 = out.sync_stats[0].rounds;
        assert!(r0 >= 1, "no sync rounds at interval 4k over 40k words");
        for st in &out.sync_stats {
            assert_eq!(st.rounds, r0);
            assert!(st.rows_synced > 0);
            assert!(st.wire_bytes > 0);
            // Sub-model sync must move fewer rows than full sync would.
            assert!(st.rows_synced < st.rounds * 2 * vocab.len() as u64);
        }
        // All corpus words processed (each node its shard, one epoch).
        assert_eq!(out.words, vocab.total_words());
        // The merged model moved away from init.
        let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        assert_ne!(out.model.m_in().data(), init.m_in().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_node_has_no_wire_traffic() {
        let (path, vocab) = tiny_corpus(43);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(1);
        dist.sync_interval = 5_000;
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(out.words, vocab.total_words());
        assert_eq!(out.sync_stats[0].wire_bytes, 0);
        std::fs::remove_file(&path).ok();
    }

    /// The replica protocol over the encoded cache: identical word
    /// accounting and a usable merged model (node shards are text-byte
    /// based on both ingest paths, so the streams match sentence for
    /// sentence).
    #[test]
    fn replicas_train_from_encoded_cache() {
        let (path, vocab) = tiny_corpus(59);
        let cache =
            crate::corpus::encoded::EncodedCorpus::cache_path_for(&path);
        std::fs::remove_file(&cache).ok();
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        cfg.corpus_cache = crate::config::CorpusCacheMode::Auto;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 8_000;
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(out.words, vocab.total_words());
        assert!(cache.exists());
        let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        assert_ne!(out.model.m_in().data(), init.m_in().data());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    /// `--route` on the replica driver is a provable no-op: one worker
    /// per replica means every window classifies back to its own arena,
    /// and the routed generator consumes the RNG identically — replicas
    /// (and their barrier-ordered merge) stay bitwise unchanged.
    #[test]
    fn route_knob_is_bitwise_noop_on_replicas() {
        let (path, vocab) = tiny_corpus(61);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 8_000;
        let off = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        cfg.route = crate::train::route::RouteMode::Owner;
        let routed = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert_eq!(off.words, routed.words);
        assert_eq!(off.model.m_in().data(), routed.model.m_in().data());
        assert_eq!(off.model.m_out().data(), routed.model.m_out().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn full_policy_moves_whole_model() {
        let (path, vocab) = tiny_corpus(47);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 8_000;
        dist.policy = SyncPolicy::Full;
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        let st = &out.sync_stats[0];
        assert_eq!(st.rows_synced, st.rounds * 2 * vocab.len() as u64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replicas_converge_toward_each_other() {
        // After syncing, replicas share the hot head: their row-0 vectors
        // must be closer to each other than independently trained models.
        let (path, vocab) = tiny_corpus(53);
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        cfg.epochs = 2;
        let mut dist = DistConfig::for_nodes(2);
        dist.sync_interval = 2_000; // many rounds over 80k words
        dist.policy = SyncPolicy::submodel_for_vocab(vocab.len());
        let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
        assert!(out.sync_stats[0].rounds > 5);
        assert_eq!(out.words, 2 * vocab.total_words());
        std::fs::remove_file(&path).ok();
    }
}
