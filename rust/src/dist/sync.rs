//! Synchronization policies and the in-process row-averaging collective.
//!
//! The paper's network-traffic reduction (Sec. III-E): instead of
//! allreducing the full 2·V·D model every round (~2.5 GB at 1B-benchmark
//! scale), each round moves the HOT head of the frequency-sorted
//! vocabulary (ids are count-sorted, so the head is a prefix) plus one
//! rotating slice of the cold tail, so every row still syncs periodically
//! while per-round payload drops by ~8×.

use std::ops::Range;

use crate::linalg::vecops::axpy;
use crate::model::SharedModel;

/// Which model rows a synchronization round moves.
#[derive(Clone, Debug)]
pub enum SyncPolicy {
    /// Average every row every round (the bandwidth-bound baseline).
    Full,
    /// The paper's sub-model scheme: rows `0..hot_rows` every round, plus
    /// cold-tail slice `(round-1) % cold_parts` of the remainder.
    SubModel { hot_rows: usize, cold_parts: u32 },
}

impl SyncPolicy {
    /// Sub-model policy sized for the paper's 1B-benchmark vocabulary.
    pub fn submodel_default() -> Self {
        Self::submodel_for_vocab(1_115_011)
    }

    /// Sub-model policy for a vocabulary of `vocab` rows: hot head =
    /// 1/16th of the vocabulary, cold tail rotated over 16 rounds
    /// (≈12% of rows per round; every row syncs at least every 16
    /// rounds).
    pub fn submodel_for_vocab(vocab: usize) -> Self {
        Self::SubModel {
            hot_rows: (vocab / 16).max(1),
            cold_parts: 16,
        }
    }

    /// The (disjoint, ascending) row ranges due in 1-based `round`.
    pub fn rows_due(&self, vocab: usize, round: u32) -> Vec<Range<u32>> {
        match *self {
            SyncPolicy::Full => {
                if vocab == 0 {
                    vec![]
                } else {
                    vec![0..vocab as u32]
                }
            }
            SyncPolicy::SubModel {
                hot_rows,
                cold_parts,
            } => {
                let hot = hot_rows.min(vocab) as u32;
                let mut out = Vec::with_capacity(2);
                if hot > 0 {
                    out.push(0..hot);
                }
                let cold = vocab as u32 - hot;
                let parts = cold_parts.max(1);
                let idx = round.wrapping_sub(1) % parts;
                let lo = hot + (cold as u64 * idx as u64 / parts as u64) as u32;
                let hi =
                    hot + (cold as u64 * (idx as u64 + 1) / parts as u64) as u32;
                if hi > lo {
                    out.push(lo..hi);
                }
                out
            }
        }
    }

    /// Total rows due in `round` (one matrix).
    pub fn rows_due_count(&self, vocab: usize, round: u32) -> u64 {
        self.rows_due(vocab, round)
            .iter()
            .map(|r| r.len() as u64)
            .sum()
    }
}

/// Average row `r` of both matrices across all `models`, writing the mean
/// back into every replica.  `scratch` must hold `dim` f32s.
///
/// Callers partition rows disjointly across nodes (see
/// `train::allreduce_rows`), so no two threads ever touch the same row —
/// the Hogwild raw-row access is race-free here by construction.
///
/// Public because it is the collective's ARITHMETIC ground truth: the
/// TCP ring's `allreduce_rows` (and hence the rollback merge in
/// elastic recovery) is pinned bitwise-identical to this loop, and the
/// recovery-determinism suite reconstructs merges with it.
pub fn average_row(models: &[SharedModel], r: u32, scratch: &mut [f32]) {
    let inv = 1.0 / models.len() as f32;
    // M_in
    scratch.fill(0.0);
    for m in models {
        // SAFETY: rows are partitioned across sync workers (see above).
        axpy(inv, unsafe { m.row_in(r) }, scratch);
    }
    for m in models {
        // SAFETY: as above.
        unsafe { m.row_in(r) }.copy_from_slice(scratch);
    }
    // M_out
    scratch.fill(0.0);
    for m in models {
        // SAFETY: as above.
        axpy(inv, unsafe { m.row_out(r) }, scratch);
    }
    for m in models {
        // SAFETY: as above.
        unsafe { m.row_out(r) }.copy_from_slice(scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_policy_is_everything_every_round() {
        let p = SyncPolicy::Full;
        for round in 1..5 {
            assert_eq!(p.rows_due(100, round), vec![0..100]);
            assert_eq!(p.rows_due_count(100, round), 100);
        }
    }

    #[test]
    fn submodel_hot_rows_every_round_cold_rotates() {
        let vocab = 1600usize;
        let p = SyncPolicy::submodel_for_vocab(vocab);
        let SyncPolicy::SubModel { hot_rows, cold_parts } = p.clone() else {
            panic!("expected submodel");
        };
        assert_eq!(hot_rows, 100);
        // The hot head is in every round; cold slices tile the tail
        // exactly once per `cold_parts` rounds.
        let mut covered = vec![0u32; vocab];
        for round in 1..=cold_parts {
            let due = p.rows_due(vocab, round);
            assert_eq!(due[0], 0..100, "round {round}");
            for range in &due {
                for r in range.clone() {
                    covered[r as usize] += 1;
                }
            }
        }
        for (r, &c) in covered.iter().enumerate() {
            if r < 100 {
                assert_eq!(c, cold_parts, "hot row {r}");
            } else {
                assert_eq!(c, 1, "cold row {r}");
            }
        }
    }

    #[test]
    fn submodel_per_round_fraction_is_small() {
        let vocab = 1_115_011usize;
        let p = SyncPolicy::submodel_default();
        let avg: f64 = (1..=16)
            .map(|r| p.rows_due_count(vocab, r) as f64)
            .sum::<f64>()
            / 16.0;
        let frac = avg / vocab as f64;
        assert!((0.10..0.15).contains(&frac), "per-round fraction {frac}");
    }

    #[test]
    fn tiny_vocab_edge_cases() {
        let p = SyncPolicy::submodel_for_vocab(1);
        assert_eq!(p.rows_due_count(1, 1), 1);
        let p = SyncPolicy::SubModel { hot_rows: 10, cold_parts: 4 };
        // hot larger than vocab: clamps, no cold tail.
        assert_eq!(p.rows_due(5, 3), vec![0..5]);
        assert!(SyncPolicy::Full.rows_due(0, 1).is_empty());
    }

    #[test]
    fn average_row_averages_both_matrices() {
        let models: Vec<SharedModel> =
            (0..4).map(|s| SharedModel::init(8, 4, s as u64)).collect();
        let want_in: Vec<f32> = (0..4)
            .map(|l| {
                models.iter().map(|m| m.m_in().row(3)[l]).sum::<f32>() / 4.0
            })
            .collect();
        let mut scratch = vec![0.0f32; 4];
        average_row(&models, 3, &mut scratch);
        for m in &models {
            for l in 0..4 {
                assert!((m.m_in().row(3)[l] - want_in[l]).abs() < 1e-6);
            }
        }
    }
}
