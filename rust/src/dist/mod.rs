//! Distributed data-parallel training (paper Sec. III-E).
//!
//! N replica "nodes" (threads standing in for MPI ranks; the in-process
//! shared-memory transport plays the fabric) train private full model
//! replicas on disjoint corpus shards.  Every `sync_interval` words each
//! node joins a synchronous allreduce round that AVERAGES model rows
//! across replicas — either the full model (`SyncPolicy::Full`, the
//! naive scheme whose traffic kills scaling) or the paper's SUB-MODEL
//! scheme: the hot head of the frequency-sorted vocabulary every round,
//! plus a rotating slice of the cold tail, cutting per-round traffic to a
//! few percent of the model.
//!
//! The learning rate uses the paper's distributed trick (`LrState::
//! dist_scaled`): the start rate scales with N and the decay sharpens, so
//! accuracy holds as nodes are added (Table IV; ablated by
//! `benches/table4_dist_accuracy.rs` with `scale_lr = false`).
//!
//! A second transport lifts the same allreduce onto a real TCP ring
//! ([`net`], driver `train::train_tcp_ring`): N OS processes, one per
//! rank, exchanging length-prefixed model-slice frames over loopback or
//! a real network, with heartbeat-based failure detection, ABORT
//! propagation, crash-consistent checkpoints and deterministic fault
//! injection ([`fault`]).  Under `SyncPolicy::Full` the ring produces
//! bitwise-identical embeddings to thread mode (pinned by
//! `tests/dist_tcp.rs`).
//!
//! The ring is SELF-HEALING when asked (`--on-failure {shrink,rejoin}`):
//! every frame carries a membership epoch so stale traffic is fenced, a
//! failed peer triggers a regroup protocol electing the surviving view,
//! survivors roll back to the newest checkpoint round all of them hold,
//! re-shard the corpus over the shrunken world size, and continue — a
//! healed run is bitwise-equal to a clean run launched from the same
//! rollback state (pinned by `tests/dist_fault.rs`).  Frame deadlines
//! adapt to measured round time (EWMA, configured timeout as floor).
//!
//! Module map: [`node`] — per-replica configuration; [`sync`] — sync
//! policies and the row-averaging collective; [`barrier`] — poisonable
//! in-process barrier (fail-fast on replica panic); [`net`] — TCP ring
//! transport, regroup protocol and epoch fencing; [`fault`] —
//! `PW2V_FAULT` injection; [`train`] — the replica drivers
//! [`train_distributed`] and [`train_tcp_ring`], plus the recovery loop
//! around them.

pub mod barrier;
pub mod fault;
pub mod net;
pub mod node;
pub mod sync;
pub mod train;

pub use fault::FaultSpec;
pub use net::{peer_failure, NetConfig, NetStats, PeerFailure, RingSpec};
pub use node::{DistConfig, OnFailure};
pub use sync::{average_row, SyncPolicy};
pub use train::{
    train_distributed, train_tcp_ring, train_tcp_ring_from, train_tcp_ring_on, AttemptStart,
    CheckpointPolicy, DistOutcome, SyncStats,
};
