//! Distributed data-parallel training (paper Sec. III-E).
//!
//! N replica "nodes" (threads standing in for MPI ranks; the in-process
//! shared-memory transport plays the fabric) train private full model
//! replicas on disjoint corpus shards.  Every `sync_interval` words each
//! node joins a synchronous allreduce round that AVERAGES model rows
//! across replicas — either the full model (`SyncPolicy::Full`, the
//! naive scheme whose traffic kills scaling) or the paper's SUB-MODEL
//! scheme: the hot head of the frequency-sorted vocabulary every round,
//! plus a rotating slice of the cold tail, cutting per-round traffic to a
//! few percent of the model.
//!
//! The learning rate uses the paper's distributed trick (`LrState::
//! dist_scaled`): the start rate scales with N and the decay sharpens, so
//! accuracy holds as nodes are added (Table IV; ablated by
//! `benches/table4_dist_accuracy.rs` with `scale_lr = false`).
//!
//! Module map: [`node`] — per-replica configuration; [`sync`] — sync
//! policies and the row-averaging collective; [`train`] — the replica
//! driver [`train_distributed`].

pub mod node;
pub mod sync;
pub mod train;

pub use node::DistConfig;
pub use sync::SyncPolicy;
pub use train::{train_distributed, DistOutcome, SyncStats};
