//! Poisonable sync barrier for the thread-mode replica driver.
//!
//! `std::sync::Barrier` cannot be poisoned: a replica that panics or
//! errors between rounds leaves its peers blocked in `wait()` forever,
//! which in tier-1 means a hung test run instead of a failure.
//! [`AbortBarrier`] is the same generation-counted barrier, plus a
//! poison state — once any participant poisons it, every current and
//! future `wait()` returns an error naming the culprit, so the whole
//! replica group fails fast.
//!
//! Poisoning is wired through [`BarrierGuard`] (the PR-5 `ProducerGuard`
//! idiom): each node loop arms a guard on entry and disarms it only on
//! clean exit, so both `?`-errors and panics (unwinding drops the guard)
//! release waiting peers.

use std::sync::{Condvar, Mutex};

/// Error marker for "a peer poisoned the barrier", as opposed to a
/// node's own root-cause failure.  The driver prefers reporting a
/// non-`Poisoned` error when one exists, since the poison is only the
/// echo of the real failure.
#[derive(Debug)]
pub struct Poisoned(pub String);

impl std::fmt::Display for Poisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sync barrier poisoned: {}", self.0)
    }
}

impl std::error::Error for Poisoned {}

struct State {
    /// Participants still to arrive in the current generation.
    waiting: usize,
    /// Incremented each time a generation completes (wraps are fine).
    generation: u64,
    /// Who poisoned the barrier and why, if anyone.
    poison: Option<String>,
}

/// A reusable N-party barrier that can be poisoned by a failing party.
pub struct AbortBarrier {
    n: usize,
    state: Mutex<State>,
    cv: Condvar,
}

impl AbortBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "barrier needs at least one participant");
        Self {
            n,
            state: Mutex::new(State {
                waiting: n,
                generation: 0,
                poison: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` participants arrive, or until the barrier is
    /// poisoned — whichever happens first.  After poisoning, every call
    /// (including from threads not yet waiting) returns `Err` wrapping
    /// [`Poisoned`].
    pub fn wait(&self) -> anyhow::Result<()> {
        // The Mutex can only be std-poisoned if a thread panicked while
        // holding it; our state stays coherent (all mutations are
        // single assignments), so recover the guard and continue.
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(why) = &st.poison {
            anyhow::bail!(Poisoned(why.clone()));
        }
        st.waiting -= 1;
        if st.waiting == 0 {
            st.waiting = self.n;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = st.generation;
        while st.generation == gen && st.poison.is_none() {
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
        match &st.poison {
            Some(why) if st.generation == gen => anyhow::bail!(Poisoned(why.clone())),
            _ => Ok(()),
        }
    }

    /// Poison the barrier: wake every waiter with an error and make all
    /// future waits fail.  Idempotent — the first reason wins.
    pub fn poison(&self, reason: &str) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.poison.is_none() {
            st.poison = Some(reason.to_string());
        }
        self.cv.notify_all();
    }

    pub fn is_poisoned(&self) -> bool {
        self.state
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .poison
            .is_some()
    }

    /// Arm an RAII guard that poisons this barrier on drop unless
    /// [`BarrierGuard::disarm`]ed first.
    pub fn guard<'a>(&'a self, name: &str) -> BarrierGuard<'a> {
        BarrierGuard {
            barrier: self,
            name: name.to_string(),
            armed: true,
        }
    }
}

/// Poisons the barrier on drop unless disarmed (clean exit).  Covers
/// both `?`-error returns and panics in the node loop.
pub struct BarrierGuard<'a> {
    barrier: &'a AbortBarrier,
    name: String,
    armed: bool,
}

impl BarrierGuard<'_> {
    /// Mark a clean exit: dropping the guard no longer poisons.
    pub fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for BarrierGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.barrier
                .poison(&format!("{} exited uncleanly", self.name));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn cycles_like_a_plain_barrier() {
        let b = Arc::new(AbortBarrier::new(3));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    b.wait().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn poison_releases_waiters_and_future_waits() {
        let b = Arc::new(AbortBarrier::new(2));
        let waiter = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || b.wait())
        };
        // Give the waiter time to actually block, then poison instead
        // of arriving.
        std::thread::sleep(std::time::Duration::from_millis(50));
        b.poison("node 1 failed: injected");
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.downcast_ref::<Poisoned>().is_some(), "{err:#}");
        assert!(err.to_string().contains("injected"), "{err:#}");
        // A latecomer fails immediately too.
        assert!(b.wait().is_err());
        assert!(b.is_poisoned());
    }

    #[test]
    fn guard_poisons_on_panic_but_not_on_disarm() {
        let b = Arc::new(AbortBarrier::new(2));
        {
            let g = b.guard("node 0");
            g.disarm();
        }
        assert!(!b.is_poisoned());

        let b2 = Arc::clone(&b);
        let t = std::thread::spawn(move || {
            let _g = b2.guard("node 1");
            panic!("simulated replica failure");
        });
        assert!(t.join().is_err());
        assert!(b.is_poisoned());
        let err = b.wait().unwrap_err();
        assert!(err.to_string().contains("node 1"), "{err:#}");
    }

    #[test]
    fn first_poison_reason_wins() {
        let b = AbortBarrier::new(1);
        b.poison("first");
        b.poison("second");
        let err = b.wait().unwrap_err();
        assert!(err.to_string().contains("first"), "{err:#}");
    }
}
