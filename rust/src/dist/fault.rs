//! Deterministic fault injection for the distributed runtime
//! (`PW2V_FAULT`), so every failure path — dead peer, torn frame,
//! wedged peer, panicking replica — is exercisable in CI instead of
//! waiting for a real cluster to produce it.
//!
//! The spec is parsed once per process; in the TCP ring each process is
//! launched with its own environment, so a test kills exactly the rank
//! it intends to.  Frame counts are DATA frames only (hello, status,
//! slices, abort) — heartbeats come from a timer thread and would make
//! `kill-after=N` racy.
//!
//! Supported specs:
//!
//! * `kill-after=N` — exit(42) abruptly once N data frames were sent
//!   (the "node died" scenario; peers must detect and abort);
//! * `torn-frame=N` — write data frame N only partially (header + half
//!   the payload), flush, then exit(43) (crash mid-write; the reader
//!   must reject the torn frame, not consume garbage);
//! * `stall-after=N` — after N data frames, hold the connection's write
//!   lock and sleep forever.  The heartbeat thread shares that lock, so
//!   heartbeats stop too: this is the "wedged, not dead" peer that only
//!   deadline-based detection catches;
//! * `panic-replica=I` — thread-mode: replica I panics at its first
//!   sync round, exercising the barrier poison guard (peers must fail
//!   fast, not block forever in the barrier);
//! * `kill-epoch=E` — exit(42) at the first data frame sent while the
//!   ring is at membership epoch E, exercising a fault *during* a
//!   recovered attempt (fault-during-fault-handling);
//! * `wedge-regroup=E` — sleep forever at the start of the regroup for
//!   epoch E: the wedged rank still accepts TCP connects (kernel
//!   backlog) but never answers the probe handshake, so survivors must
//!   exclude it by probe-ack deadline, not by connect failure;
//! * `respawn-after=MS` — sleep MS milliseconds at startup before ring
//!   formation, the deterministic "respawned rank joins late" delay the
//!   rejoin grace window is tested against.

use std::str::FromStr;

/// One injected fault (see module docs for the trigger semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// Exit abruptly after N data frames were sent.
    KillAfterFrames(u64),
    /// Truncate data frame N mid-payload, then exit.
    TornFrame(u64),
    /// After N data frames, stop sending anything (including
    /// heartbeats) without exiting.
    StallAfterFrames(u64),
    /// Thread mode: replica I panics at its first sync round.
    PanicReplica(usize),
    /// Exit abruptly at the first data frame sent at membership epoch E.
    KillEpoch(u32),
    /// Sleep forever at the start of the regroup for epoch E.
    WedgeRegroup(u32),
    /// Sleep MS milliseconds at startup before ring formation.
    RespawnAfterMs(u64),
}

impl FromStr for FaultSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        let (kind, val) = s
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("fault spec '{s}': expected kind=N"))?;
        let n: u64 = val
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("fault spec '{s}': bad count ({e})"))?;
        match kind.trim() {
            "kill-after" => Ok(FaultSpec::KillAfterFrames(n)),
            "torn-frame" => Ok(FaultSpec::TornFrame(n)),
            "stall-after" => Ok(FaultSpec::StallAfterFrames(n)),
            "panic-replica" => Ok(FaultSpec::PanicReplica(n as usize)),
            "kill-epoch" => Ok(FaultSpec::KillEpoch(n as u32)),
            "wedge-regroup" => Ok(FaultSpec::WedgeRegroup(n as u32)),
            "respawn-after" => Ok(FaultSpec::RespawnAfterMs(n)),
            other => anyhow::bail!(
                "unknown fault kind '{other}' \
                 (kill-after|torn-frame|stall-after|panic-replica\
                 |kill-epoch|wedge-regroup|respawn-after)"
            ),
        }
    }
}

impl FaultSpec {
    /// Parse `PW2V_FAULT` from the environment (`Ok(None)` when unset).
    pub fn from_env() -> anyhow::Result<Option<Self>> {
        match std::env::var("PW2V_FAULT") {
            Ok(s) if s.trim().is_empty() => Ok(None),
            Ok(s) => Ok(Some(s.parse()?)),
            Err(_) => Ok(None),
        }
    }

    /// Should replica `idx` panic at its first sync round (thread mode)?
    pub fn panics_replica(&self, idx: usize) -> bool {
        matches!(self, FaultSpec::PanicReplica(i) if *i == idx)
    }

    /// Startup delay injected before ring formation (`respawn-after`).
    pub fn respawn_delay_ms(&self) -> Option<u64> {
        match self {
            FaultSpec::RespawnAfterMs(ms) => Some(*ms),
            _ => None,
        }
    }

    /// Should the regroup for `epoch` wedge (sleep forever)?
    pub fn wedges_regroup(&self, epoch: u32) -> bool {
        matches!(self, FaultSpec::WedgeRegroup(e) if *e == epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        assert_eq!(
            "kill-after=5".parse::<FaultSpec>().unwrap(),
            FaultSpec::KillAfterFrames(5)
        );
        assert_eq!(
            "torn-frame=12".parse::<FaultSpec>().unwrap(),
            FaultSpec::TornFrame(12)
        );
        assert_eq!(
            "stall-after=0".parse::<FaultSpec>().unwrap(),
            FaultSpec::StallAfterFrames(0)
        );
        assert_eq!(
            "panic-replica=1".parse::<FaultSpec>().unwrap(),
            FaultSpec::PanicReplica(1)
        );
        assert_eq!(
            "kill-epoch=1".parse::<FaultSpec>().unwrap(),
            FaultSpec::KillEpoch(1)
        );
        assert_eq!(
            "wedge-regroup=2".parse::<FaultSpec>().unwrap(),
            FaultSpec::WedgeRegroup(2)
        );
        assert_eq!(
            "respawn-after=250".parse::<FaultSpec>().unwrap(),
            FaultSpec::RespawnAfterMs(250)
        );
    }

    #[test]
    fn recovery_fault_helpers_target_their_kind() {
        assert_eq!(FaultSpec::RespawnAfterMs(40).respawn_delay_ms(), Some(40));
        assert_eq!(FaultSpec::KillEpoch(1).respawn_delay_ms(), None);
        assert!(FaultSpec::WedgeRegroup(1).wedges_regroup(1));
        assert!(!FaultSpec::WedgeRegroup(1).wedges_regroup(2));
        assert!(!FaultSpec::KillAfterFrames(3).wedges_regroup(1));
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!("kill-after".parse::<FaultSpec>().is_err());
        assert!("kill-after=x".parse::<FaultSpec>().is_err());
        assert!("explode=3".parse::<FaultSpec>().is_err());
    }

    #[test]
    fn panic_targets_one_replica() {
        let f = FaultSpec::PanicReplica(2);
        assert!(f.panics_replica(2));
        assert!(!f.panics_replica(0));
        assert!(!FaultSpec::KillAfterFrames(1).panics_replica(0));
    }
}
