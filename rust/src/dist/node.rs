//! Per-replica distributed configuration.

use super::fault::FaultSpec;
use super::sync::SyncPolicy;

/// What the TCP driver does when a peer fails mid-run (`--on-failure`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnFailure {
    /// Fail-stop (the PR-6 semantics, bit-for-bit): propagate `Abort`
    /// around the ring and exit non-zero.
    #[default]
    Abort,
    /// Self-heal: survivors regroup into a smaller ring at the next
    /// membership epoch, roll back to the newest checkpoint round every
    /// survivor holds, re-shard the corpus over the shrunken world
    /// size, and continue.  Requires `--checkpoint`.
    Shrink,
    /// Like `Shrink`, but survivors hold the regroup open for the
    /// rejoin grace window first, so a promptly respawned rank (same
    /// argv) is re-admitted and the ORIGINAL membership is restored.
    Rejoin,
}

impl std::str::FromStr for OnFailure {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.trim() {
            "abort" => Ok(Self::Abort),
            "shrink" => Ok(Self::Shrink),
            "rejoin" => Ok(Self::Rejoin),
            other => anyhow::bail!("unknown --on-failure '{other}' (abort|shrink|rejoin)"),
        }
    }
}

/// Configuration of one distributed run (shared by all replicas).
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Replica count N.
    pub nodes: usize,
    /// Words each node processes between synchronization rounds.
    pub sync_interval: u64,
    /// Which rows each round synchronizes.
    pub policy: SyncPolicy,
    /// Apply the paper's node-scaled learning rate (Sec. III-E).
    pub scale_lr: bool,
    /// Injected fault for the thread-mode driver (tests set this
    /// programmatically; the CLI wires `PW2V_FAULT` through).  TCP-mode
    /// wire faults are read from the environment by the transport
    /// itself.
    pub fault: Option<FaultSpec>,
    /// TCP-mode failure policy (thread mode always fails fast).
    pub on_failure: OnFailure,
}

impl DistConfig {
    /// The paper's operating point for N nodes: sub-model sync, scaled
    /// lr, and a sync interval that SHRINKS with the node count — the
    /// Sec. IV-C "further increase model synchronization frequency"
    /// needed to hold accuracy at scale, and what bends Fig. 4
    /// sub-linear at 32 BDW / 16 KNL nodes.  The floor keeps very large
    /// clusters from syncing pathologically often.
    pub fn for_nodes(nodes: usize) -> Self {
        let nodes = nodes.max(1);
        Self {
            nodes,
            sync_interval: (12_000_000 / nodes as u64).max(500_000),
            policy: SyncPolicy::submodel_default(),
            scale_lr: true,
            fault: None,
            on_failure: OnFailure::Abort,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_shrinks_with_nodes_to_floor() {
        let iv = |n| DistConfig::for_nodes(n).sync_interval;
        assert_eq!(iv(1), 12_000_000);
        assert_eq!(iv(4), 3_000_000);
        assert_eq!(iv(8), 1_500_000);
        assert!(iv(8) < iv(4) && iv(4) < iv(1));
        assert_eq!(iv(32), 500_000); // floor
        assert_eq!(iv(64), 500_000);
    }

    #[test]
    fn defaults_are_paper_scheme() {
        let d = DistConfig::for_nodes(4);
        assert_eq!(d.nodes, 4);
        assert!(d.scale_lr);
        assert!(!matches!(d.policy, SyncPolicy::Full));
        assert_eq!(d.on_failure, OnFailure::Abort);
    }

    #[test]
    fn on_failure_parses_and_rejects() {
        assert_eq!("abort".parse::<OnFailure>().unwrap(), OnFailure::Abort);
        assert_eq!("shrink".parse::<OnFailure>().unwrap(), OnFailure::Shrink);
        assert_eq!("rejoin".parse::<OnFailure>().unwrap(), OnFailure::Rejoin);
        assert!("retry".parse::<OnFailure>().is_err());
    }
}
