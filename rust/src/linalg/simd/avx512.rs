//! AVX-512 implementations of the hot-path kernels: 16-lane twins of
//! `avx2.rs` (`std::arch` intrinsics, unaligned loads throughout — the
//! gathered blocks and arena slices carry no alignment guarantee).
//!
//! The companion many-core paper (arxiv 1611.06172) runs the same
//! register-tiled SGNS scheme on 16-lane vectors; this module is that
//! retarget.  Structure mirrors `avx2.rs` kernel for kernel — D-axis
//! blocks widen from 8 to 16 lanes, horizontal sums become the
//! deterministic `_mm512_reduce_add_*` reductions, and the int8 dot eats
//! 32 codes per step (`avx512bw` word-madd) — so the two files review
//! side by side.
//!
//! Safety: every `pub` function here is `#[target_feature(enable =
//! "avx512f,avx512bw")]` and must only be called after `simd::level()`
//! resolved to [`super::SimdLevel::Avx512`], i.e. after CPUID reported
//! both features.  The dispatchers in `simd::mod` are the only callers
//! and enforce this; `--simd auto` never selects this tier (512-bit
//! downclocking — see EXPERIMENTS.md §AVX-512), so it runs only when
//! explicitly requested.
//!
//! Numerics: `_mm512_reduce_add_ps` is a fixed tree reduction, so the
//! f32 kernels are deterministic run-to-run but reassociate relative to
//! scalar — the same bounded drift budget as AVX2 (≤ 1e-4 relative,
//! asserted in `tests/props.rs`).  The int8 dot is pure integer
//! arithmetic and matches scalar EXACTLY.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

/// Integer dot `<a, b>` over int8 codes: 32 codes per step, sign-extended
/// to i16 lanes and pair-summed into i32 by `madd` — integer arithmetic
/// is associative, so this is EXACTLY the scalar result (the store layer
/// caps the length so the i32 accumulators cannot overflow even at
/// |code| = 127 throughout).
#[target_feature(enable = "avx512f", enable = "avx512bw")]
pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm512_setzero_si512();
    let mut i = 0usize;
    while i + 32 <= n {
        let va = _mm512_cvtepi8_epi16(_mm256_loadu_si256(pa.add(i) as *const __m256i));
        let vb = _mm512_cvtepi8_epi16(_mm256_loadu_si256(pb.add(i) as *const __m256i));
        acc = _mm512_add_epi32(acc, _mm512_madd_epi16(va, vb));
        i += 32;
    }
    let mut s = _mm512_reduce_add_epi32(acc);
    while i < n {
        s += *pa.add(i) as i32 * *pb.add(i) as i32;
        i += 1;
    }
    s
}

/// Dot product `<a, b>`.
#[target_feature(enable = "avx512f", enable = "avx512bw")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm512_fmadd_ps(
            _mm512_loadu_ps(pa.add(i)),
            _mm512_loadu_ps(pb.add(i)),
            acc0,
        );
        acc1 = _mm512_fmadd_ps(
            _mm512_loadu_ps(pa.add(i + 16)),
            _mm512_loadu_ps(pb.add(i + 16)),
            acc1,
        );
        i += 32;
    }
    if i + 16 <= n {
        acc0 = _mm512_fmadd_ps(
            _mm512_loadu_ps(pa.add(i)),
            _mm512_loadu_ps(pb.add(i)),
            acc0,
        );
        i += 16;
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
    while i < n {
        s += *pa.add(i) * *pb.add(i);
        i += 1;
    }
    s
}

/// `y += alpha * x`.
#[target_feature(enable = "avx512f", enable = "avx512bw")]
pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (px, py) = (x.as_ptr(), y.as_mut_ptr());
    let va = _mm512_set1_ps(alpha);
    let mut i = 0usize;
    while i + 16 <= n {
        let v = _mm512_fmadd_ps(va, _mm512_loadu_ps(px.add(i)), _mm512_loadu_ps(py.add(i)));
        _mm512_storeu_ps(py.add(i), v);
        i += 16;
    }
    while i < n {
        *py.add(i) += alpha * *px.add(i);
        i += 1;
    }
}

/// Four simultaneous dots of `pa[..k]` against `pb0..pb3[..k]`: one load
/// of the shared row feeds 4 FMA chains (the `Wo` reuse of GEMM 1).
#[inline]
#[target_feature(enable = "avx512f", enable = "avx512bw")]
unsafe fn dot4(
    pa: *const f32,
    pb0: *const f32,
    pb1: *const f32,
    pb2: *const f32,
    pb3: *const f32,
    k: usize,
) -> (f32, f32, f32, f32) {
    let mut a0 = _mm512_setzero_ps();
    let mut a1 = _mm512_setzero_ps();
    let mut a2 = _mm512_setzero_ps();
    let mut a3 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= k {
        let va = _mm512_loadu_ps(pa.add(i));
        a0 = _mm512_fmadd_ps(va, _mm512_loadu_ps(pb0.add(i)), a0);
        a1 = _mm512_fmadd_ps(va, _mm512_loadu_ps(pb1.add(i)), a1);
        a2 = _mm512_fmadd_ps(va, _mm512_loadu_ps(pb2.add(i)), a2);
        a3 = _mm512_fmadd_ps(va, _mm512_loadu_ps(pb3.add(i)), a3);
        i += 16;
    }
    let (mut s0, mut s1, mut s2, mut s3) = (
        _mm512_reduce_add_ps(a0),
        _mm512_reduce_add_ps(a1),
        _mm512_reduce_add_ps(a2),
        _mm512_reduce_add_ps(a3),
    );
    while i < k {
        let x = *pa.add(i);
        s0 += x * *pb0.add(i);
        s1 += x * *pb1.add(i);
        s2 += x * *pb2.add(i);
        s3 += x * *pb3.add(i);
        i += 1;
    }
    (s0, s1, s2, s3)
}

/// `c[m,n] = alpha * a[m,k] · b[n,k]ᵀ + beta * c` (rows-dot-rows).
#[target_feature(enable = "avx512f", enable = "avx512bw")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_nt(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    for i in 0..m {
        let ar = pa.add(i * k);
        let crow = c.as_mut_ptr().add(i * n);
        let mut j = 0usize;
        while j + 4 <= n {
            let (d0, d1, d2, d3) = dot4(
                ar,
                pb.add(j * k),
                pb.add((j + 1) * k),
                pb.add((j + 2) * k),
                pb.add((j + 3) * k),
                k,
            );
            *crow.add(j) = alpha * d0 + beta * *crow.add(j);
            *crow.add(j + 1) = alpha * d1 + beta * *crow.add(j + 1);
            *crow.add(j + 2) = alpha * d2 + beta * *crow.add(j + 2);
            *crow.add(j + 3) = alpha * d3 + beta * *crow.add(j + 3);
            j += 4;
        }
        while j < n {
            let d = dot(
                std::slice::from_raw_parts(ar, k),
                std::slice::from_raw_parts(pb.add(j * k), k),
            );
            *crow.add(j) = alpha * d + beta * *crow.add(j);
            j += 1;
        }
    }
}

/// `c[m,n] = alpha * a[m,k] · b[k,n] + beta * c`, vectorised along `n`
/// with the `k` reduction in registers (coefficient broadcast per source
/// row).
#[target_feature(enable = "avx512f", enable = "avx512bw")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_nn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    let pb = b.as_ptr();
    for i in 0..m {
        let arow = a.as_ptr().add(i * k);
        let crow = c.as_mut_ptr().add(i * n);
        accumulate_rows_ptr(n, k, alpha, arow, 1, pb, beta, crow);
    }
}

/// `c[m,n] = alpha * a[k,m]ᵀ · b[k,n] + beta * c`; the coefficient for
/// output row `j` is the strided column `a[:, j]`.
#[target_feature(enable = "avx512f", enable = "avx512bw")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_tn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    let pb = b.as_ptr();
    for j in 0..m {
        let crow = c.as_mut_ptr().add(j * n);
        accumulate_rows_ptr(n, k, alpha, a.as_ptr().add(j), m, pb, beta, crow);
    }
}

/// `crow[0..n] = beta*crow + alpha * Σ_l coeff[l*stride] · b[l, 0..n]`,
/// one vectorised sweep over `n` per 16-lane block with all `k`
/// coefficients applied in registers.
#[inline]
#[target_feature(enable = "avx512f", enable = "avx512bw")]
#[allow(clippy::too_many_arguments)]
unsafe fn accumulate_rows_ptr(
    n: usize,
    k: usize,
    alpha: f32,
    coeff: *const f32,
    stride: usize,
    b: *const f32,
    beta: f32,
    crow: *mut f32,
) {
    let mut j = 0usize;
    while j + 16 <= n {
        let mut acc = if beta == 0.0 {
            _mm512_setzero_ps()
        } else {
            _mm512_mul_ps(_mm512_set1_ps(beta), _mm512_loadu_ps(crow.add(j)))
        };
        let mut l = 0usize;
        while l + 2 <= k {
            let c0 = _mm512_set1_ps(alpha * *coeff.add(l * stride));
            let c1 = _mm512_set1_ps(alpha * *coeff.add((l + 1) * stride));
            acc = _mm512_fmadd_ps(c0, _mm512_loadu_ps(b.add(l * n + j)), acc);
            acc = _mm512_fmadd_ps(c1, _mm512_loadu_ps(b.add((l + 1) * n + j)), acc);
            l += 2;
        }
        if l < k {
            let c0 = _mm512_set1_ps(alpha * *coeff.add(l * stride));
            acc = _mm512_fmadd_ps(c0, _mm512_loadu_ps(b.add(l * n + j)), acc);
        }
        _mm512_storeu_ps(crow.add(j), acc);
        j += 16;
    }
    while j < n {
        let mut s = if beta == 0.0 { 0.0 } else { beta * *crow.add(j) };
        for l in 0..k {
            s += alpha * *coeff.add(l * stride) * *b.add(l * n + j);
        }
        *crow.add(j) = s;
        j += 1;
    }
}

/// Vector `exp` (Cephes polynomial, range-reduced by `ln 2`, identical
/// constants to `avx2::exp256`): relative error ≲ 2e-7 over the clamped
/// domain.
#[inline]
#[target_feature(enable = "avx512f", enable = "avx512bw")]
unsafe fn exp512(x: __m512) -> __m512 {
    // Clamp so 2^n stays in normal f32 range (σ saturates there anyway).
    let x = _mm512_min_ps(x, _mm512_set1_ps(88.0));
    let x = _mm512_max_ps(x, _mm512_set1_ps(-88.0));
    // n = round(x / ln 2); roundscale imm 0x08 = nearest-even, no exc.
    let log2e = _mm512_set1_ps(std::f32::consts::LOG2_E);
    let n = _mm512_roundscale_ps::<0x08>(_mm512_mul_ps(x, log2e));
    // r = x - n*ln2, split high/low for extra bits.
    let ln2_hi = _mm512_set1_ps(0.693_359_375);
    let ln2_lo = _mm512_set1_ps(-2.121_944_4e-4);
    let r = _mm512_fnmadd_ps(n, ln2_hi, x);
    let r = _mm512_fnmadd_ps(n, ln2_lo, r);
    // e^r ≈ 1 + r + r²·P(r) (Cephes cephes_exp_p coefficients).
    let mut p = _mm512_set1_ps(1.987_569_1e-4);
    p = _mm512_fmadd_ps(p, r, _mm512_set1_ps(1.398_199_9e-3));
    p = _mm512_fmadd_ps(p, r, _mm512_set1_ps(8.333_452e-3));
    p = _mm512_fmadd_ps(p, r, _mm512_set1_ps(4.166_579_6e-2));
    p = _mm512_fmadd_ps(p, r, _mm512_set1_ps(1.666_666_5e-1));
    p = _mm512_fmadd_ps(p, r, _mm512_set1_ps(5.000_000_1e-1));
    let r2 = _mm512_mul_ps(r, r);
    let y = _mm512_fmadd_ps(p, r2, _mm512_add_ps(r, _mm512_set1_ps(1.0)));
    // Scale by 2^n via exponent-field construction.
    let ni = _mm512_cvtps_epi32(n);
    let pow2 = _mm512_castsi512_ps(_mm512_slli_epi32::<23>(_mm512_add_epi32(
        ni,
        _mm512_set1_epi32(127),
    )));
    _mm512_mul_ps(y, pow2)
}

/// Fused single-pass SGNS window kernel, 16-lane twin of
/// `avx2::sgns_fused` (see `scalar::sgns_fused` for the reference
/// semantics): logits via dot4 column blocking, in-place error
/// transform, then ONE register-tiled sweep over the D axis per
/// output-slot block with the block's `wo` rows and `dwo` accumulators
/// live in zmm registers while all `b` input rows stream through.
/// Duplicate slots take the sequential (reference-order) fallback, as in
/// the AVX2 kernel.
#[target_feature(enable = "avx512f", enable = "avx512bw")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn sgns_fused(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    let b = wi.len() / d;

    // Phase 1: logits tile, dot4-blocked over the slot columns.
    {
        let pwi = wi.as_ptr();
        let pwo = wo.as_ptr();
        for i in 0..b {
            let ar = pwi.add(i * d);
            let mut j = 0usize;
            while j + 4 <= s {
                let (d0, d1, d2, d3) = dot4(
                    ar,
                    pwo.add(slots[j] as usize * d),
                    pwo.add(slots[j + 1] as usize * d),
                    pwo.add(slots[j + 2] as usize * d),
                    pwo.add(slots[j + 3] as usize * d),
                    d,
                );
                err[i * s + j] = d0;
                err[i * s + j + 1] = d1;
                err[i * s + j + 2] = d2;
                err[i * s + j + 3] = d3;
                j += 4;
            }
            while j < s {
                err[i * s + j] = dot(
                    std::slice::from_raw_parts(ar, d),
                    std::slice::from_raw_parts(pwo.add(slots[j] as usize * d), d),
                );
                j += 1;
            }
        }
    }

    // Phase 2: vectorised error transform over the L1-resident tile.
    sgns_err(&mut err[..b * s], s, lr);

    // Duplicate slots: the register-tiled phase 3 would lose one
    // accumulator at store time, so take the sequential path instead.
    let has_dup = slots
        .iter()
        .enumerate()
        .any(|(j, sj)| slots[..j].contains(sj));
    if has_dup {
        for i in 0..b {
            let wi_row = &wi[i * d..(i + 1) * d];
            dwi[i * d..(i + 1) * d].fill(0.0);
            for (j, &slot) in slots.iter().enumerate() {
                let e = err[i * s + j];
                let r = slot as usize * d;
                axpy(e, &wo[r..r + d], &mut dwi[i * d..(i + 1) * d]);
                axpy(e, wi_row, &mut dwo[r..r + d]);
            }
        }
        return;
    }

    // Phase 3: register-tiled gradient sweep, slot blocks of 4/2/1.
    let pwi = wi.as_ptr();
    let pwo = wo.as_ptr();
    let pdwi = dwi.as_mut_ptr();
    let pdwo = dwo.as_mut_ptr();
    let perr = err.as_ptr();
    let mut j0 = 0usize;
    while j0 < s {
        let first = j0 == 0;
        if s - j0 >= 4 {
            let r0 = slots[j0] as usize * d;
            let r1 = slots[j0 + 1] as usize * d;
            let r2 = slots[j0 + 2] as usize * d;
            let r3 = slots[j0 + 3] as usize * d;
            let mut l = 0usize;
            while l + 16 <= d {
                let w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                let w1 = _mm512_loadu_ps(pwo.add(r1 + l));
                let w2 = _mm512_loadu_ps(pwo.add(r2 + l));
                let w3 = _mm512_loadu_ps(pwo.add(r3 + l));
                let mut a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                let mut a1 = _mm512_loadu_ps(pdwo.add(r1 + l));
                let mut a2 = _mm512_loadu_ps(pdwo.add(r2 + l));
                let mut a3 = _mm512_loadu_ps(pdwo.add(r3 + l));
                for i in 0..b {
                    let e = perr.add(i * s + j0);
                    let vwi = _mm512_loadu_ps(pwi.add(i * d + l));
                    let e0 = _mm512_set1_ps(*e);
                    let e1 = _mm512_set1_ps(*e.add(1));
                    let e2 = _mm512_set1_ps(*e.add(2));
                    let e3 = _mm512_set1_ps(*e.add(3));
                    let mut g = if first {
                        _mm512_setzero_ps()
                    } else {
                        _mm512_loadu_ps(pdwi.add(i * d + l))
                    };
                    g = _mm512_fmadd_ps(e0, w0, g);
                    g = _mm512_fmadd_ps(e1, w1, g);
                    g = _mm512_fmadd_ps(e2, w2, g);
                    g = _mm512_fmadd_ps(e3, w3, g);
                    _mm512_storeu_ps(pdwi.add(i * d + l), g);
                    a0 = _mm512_fmadd_ps(e0, vwi, a0);
                    a1 = _mm512_fmadd_ps(e1, vwi, a1);
                    a2 = _mm512_fmadd_ps(e2, vwi, a2);
                    a3 = _mm512_fmadd_ps(e3, vwi, a3);
                }
                _mm512_storeu_ps(pdwo.add(r0 + l), a0);
                _mm512_storeu_ps(pdwo.add(r1 + l), a1);
                _mm512_storeu_ps(pdwo.add(r2 + l), a2);
                _mm512_storeu_ps(pdwo.add(r3 + l), a3);
                l += 16;
            }
            while l < d {
                let mut a0 = *pdwo.add(r0 + l);
                let mut a1 = *pdwo.add(r1 + l);
                let mut a2 = *pdwo.add(r2 + l);
                let mut a3 = *pdwo.add(r3 + l);
                for i in 0..b {
                    let e = perr.add(i * s + j0);
                    let x = *pwi.add(i * d + l);
                    let mut g = if first { 0.0 } else { *pdwi.add(i * d + l) };
                    g += *e * *pwo.add(r0 + l)
                        + *e.add(1) * *pwo.add(r1 + l)
                        + *e.add(2) * *pwo.add(r2 + l)
                        + *e.add(3) * *pwo.add(r3 + l);
                    *pdwi.add(i * d + l) = g;
                    a0 += *e * x;
                    a1 += *e.add(1) * x;
                    a2 += *e.add(2) * x;
                    a3 += *e.add(3) * x;
                }
                *pdwo.add(r0 + l) = a0;
                *pdwo.add(r1 + l) = a1;
                *pdwo.add(r2 + l) = a2;
                *pdwo.add(r3 + l) = a3;
                l += 1;
            }
            j0 += 4;
        } else if s - j0 >= 2 {
            let r0 = slots[j0] as usize * d;
            let r1 = slots[j0 + 1] as usize * d;
            let mut l = 0usize;
            while l + 16 <= d {
                let w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                let w1 = _mm512_loadu_ps(pwo.add(r1 + l));
                let mut a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                let mut a1 = _mm512_loadu_ps(pdwo.add(r1 + l));
                for i in 0..b {
                    let e = perr.add(i * s + j0);
                    let vwi = _mm512_loadu_ps(pwi.add(i * d + l));
                    let e0 = _mm512_set1_ps(*e);
                    let e1 = _mm512_set1_ps(*e.add(1));
                    let mut g = if first {
                        _mm512_setzero_ps()
                    } else {
                        _mm512_loadu_ps(pdwi.add(i * d + l))
                    };
                    g = _mm512_fmadd_ps(e0, w0, g);
                    g = _mm512_fmadd_ps(e1, w1, g);
                    _mm512_storeu_ps(pdwi.add(i * d + l), g);
                    a0 = _mm512_fmadd_ps(e0, vwi, a0);
                    a1 = _mm512_fmadd_ps(e1, vwi, a1);
                }
                _mm512_storeu_ps(pdwo.add(r0 + l), a0);
                _mm512_storeu_ps(pdwo.add(r1 + l), a1);
                l += 16;
            }
            while l < d {
                let mut a0 = *pdwo.add(r0 + l);
                let mut a1 = *pdwo.add(r1 + l);
                for i in 0..b {
                    let e = perr.add(i * s + j0);
                    let x = *pwi.add(i * d + l);
                    let mut g = if first { 0.0 } else { *pdwi.add(i * d + l) };
                    g += *e * *pwo.add(r0 + l) + *e.add(1) * *pwo.add(r1 + l);
                    *pdwi.add(i * d + l) = g;
                    a0 += *e * x;
                    a1 += *e.add(1) * x;
                }
                *pdwo.add(r0 + l) = a0;
                *pdwo.add(r1 + l) = a1;
                l += 1;
            }
            j0 += 2;
        } else {
            let r0 = slots[j0] as usize * d;
            let mut l = 0usize;
            while l + 16 <= d {
                let w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                let mut a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                for i in 0..b {
                    let e0 = _mm512_set1_ps(*perr.add(i * s + j0));
                    let vwi = _mm512_loadu_ps(pwi.add(i * d + l));
                    let mut g = if first {
                        _mm512_setzero_ps()
                    } else {
                        _mm512_loadu_ps(pdwi.add(i * d + l))
                    };
                    g = _mm512_fmadd_ps(e0, w0, g);
                    _mm512_storeu_ps(pdwi.add(i * d + l), g);
                    a0 = _mm512_fmadd_ps(e0, vwi, a0);
                }
                _mm512_storeu_ps(pdwo.add(r0 + l), a0);
                l += 16;
            }
            while l < d {
                let mut a0 = *pdwo.add(r0 + l);
                for i in 0..b {
                    let e = *perr.add(i * s + j0);
                    let x = *pwi.add(i * d + l);
                    let mut g = if first { 0.0 } else { *pdwi.add(i * d + l) };
                    g += e * *pwo.add(r0 + l);
                    *pdwi.add(i * d + l) = g;
                    a0 += e * x;
                }
                *pdwo.add(r0 + l) = a0;
                l += 1;
            }
            j0 += 1;
        }
    }
}

/// Fused kernel over a RUN of consecutive windows sharing one negative
/// set, 16-lane twin of `avx2::sgns_fused_run` (see that kernel for the
/// bitwise-equality argument and the driver contract;
/// `scalar::sgns_fused_run` is the ground truth).  Shared negative lanes
/// are loaded once per D-block and carried in zmm registers across the
/// run's window loop; only the per-window positive lane reloads.
#[target_feature(enable = "avx512f", enable = "avx512bw")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn sgns_fused_run(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    offs: &[u32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    let r_n = offs.len() - 1;
    if r_n == 1 {
        // Singleton run: the per-window kernel IS the semantics
        // (including its duplicate-slot sequential fallback).
        return sgns_fused(s, d, lr, wi, wo, &slots[..s], err, dwi, dwo);
    }

    // Phase 1: logits tiles, dot4-blocked, global-row-major err.
    {
        let pwi = wi.as_ptr();
        let pwo = wo.as_ptr();
        for w in 0..r_n {
            let sl = &slots[w * s..(w + 1) * s];
            for gi in offs[w] as usize..offs[w + 1] as usize {
                let ar = pwi.add(gi * d);
                let mut j = 0usize;
                while j + 4 <= s {
                    let (d0, d1, d2, d3) = dot4(
                        ar,
                        pwo.add(sl[j] as usize * d),
                        pwo.add(sl[j + 1] as usize * d),
                        pwo.add(sl[j + 2] as usize * d),
                        pwo.add(sl[j + 3] as usize * d),
                        d,
                    );
                    err[gi * s + j] = d0;
                    err[gi * s + j + 1] = d1;
                    err[gi * s + j + 2] = d2;
                    err[gi * s + j + 3] = d3;
                    j += 4;
                }
                while j < s {
                    err[gi * s + j] = dot(
                        std::slice::from_raw_parts(ar, d),
                        std::slice::from_raw_parts(pwo.add(sl[j] as usize * d), d),
                    );
                    j += 1;
                }
            }
        }
    }

    // Phase 2: error transform, PER-WINDOW slice — a whole-run call
    // would shift each window's vector-bulk/scalar-tail boundary and
    // break bitwise parity with the sequential calls.
    for w in 0..r_n {
        let (lo, hi) = (offs[w] as usize, offs[w + 1] as usize);
        sgns_err(&mut err[lo * s..hi * s], s, lr);
    }

    // Phase 3: register-tiled gradient sweep with cross-window carry.
    let pwi = wi.as_ptr();
    let pwo = wo.as_ptr();
    let pdwi = dwi.as_mut_ptr();
    let pdwo = dwo.as_mut_ptr();
    let perr = err.as_ptr();
    let negs = &slots[..s]; // window 0's slots; lanes ≥ 1 shared run-wide
    let mut j0 = 0usize;
    while j0 < s {
        let first = j0 == 0;
        let lane0_shared = j0 != 0;
        if s - j0 >= 4 {
            let r1 = negs[j0 + 1] as usize * d;
            let r2 = negs[j0 + 2] as usize * d;
            let r3 = negs[j0 + 3] as usize * d;
            let mut l = 0usize;
            while l + 16 <= d {
                let w1 = _mm512_loadu_ps(pwo.add(r1 + l));
                let w2 = _mm512_loadu_ps(pwo.add(r2 + l));
                let w3 = _mm512_loadu_ps(pwo.add(r3 + l));
                let mut a1 = _mm512_loadu_ps(pdwo.add(r1 + l));
                let mut a2 = _mm512_loadu_ps(pdwo.add(r2 + l));
                let mut a3 = _mm512_loadu_ps(pdwo.add(r3 + l));
                let mut w0 = _mm512_setzero_ps();
                let mut a0 = _mm512_setzero_ps();
                if lane0_shared {
                    let r0 = negs[j0] as usize * d;
                    w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                    a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                }
                for w in 0..r_n {
                    let r0 = slots[w * s + j0] as usize * d;
                    if !lane0_shared {
                        w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                        a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                    }
                    for gi in offs[w] as usize..offs[w + 1] as usize {
                        let e = perr.add(gi * s + j0);
                        let vwi = _mm512_loadu_ps(pwi.add(gi * d + l));
                        let e0 = _mm512_set1_ps(*e);
                        let e1 = _mm512_set1_ps(*e.add(1));
                        let e2 = _mm512_set1_ps(*e.add(2));
                        let e3 = _mm512_set1_ps(*e.add(3));
                        let mut g = if first {
                            _mm512_setzero_ps()
                        } else {
                            _mm512_loadu_ps(pdwi.add(gi * d + l))
                        };
                        g = _mm512_fmadd_ps(e0, w0, g);
                        g = _mm512_fmadd_ps(e1, w1, g);
                        g = _mm512_fmadd_ps(e2, w2, g);
                        g = _mm512_fmadd_ps(e3, w3, g);
                        _mm512_storeu_ps(pdwi.add(gi * d + l), g);
                        a0 = _mm512_fmadd_ps(e0, vwi, a0);
                        a1 = _mm512_fmadd_ps(e1, vwi, a1);
                        a2 = _mm512_fmadd_ps(e2, vwi, a2);
                        a3 = _mm512_fmadd_ps(e3, vwi, a3);
                    }
                    if !lane0_shared {
                        _mm512_storeu_ps(pdwo.add(r0 + l), a0);
                    }
                }
                if lane0_shared {
                    _mm512_storeu_ps(pdwo.add(negs[j0] as usize * d + l), a0);
                }
                _mm512_storeu_ps(pdwo.add(r1 + l), a1);
                _mm512_storeu_ps(pdwo.add(r2 + l), a2);
                _mm512_storeu_ps(pdwo.add(r3 + l), a3);
                l += 16;
            }
            while l < d {
                let mut a1 = *pdwo.add(r1 + l);
                let mut a2 = *pdwo.add(r2 + l);
                let mut a3 = *pdwo.add(r3 + l);
                let mut a0 = 0.0f32;
                if lane0_shared {
                    a0 = *pdwo.add(negs[j0] as usize * d + l);
                }
                for w in 0..r_n {
                    let r0 = slots[w * s + j0] as usize * d;
                    if !lane0_shared {
                        a0 = *pdwo.add(r0 + l);
                    }
                    for gi in offs[w] as usize..offs[w + 1] as usize {
                        let e = perr.add(gi * s + j0);
                        let x = *pwi.add(gi * d + l);
                        let mut g = if first { 0.0 } else { *pdwi.add(gi * d + l) };
                        g += *e * *pwo.add(r0 + l)
                            + *e.add(1) * *pwo.add(r1 + l)
                            + *e.add(2) * *pwo.add(r2 + l)
                            + *e.add(3) * *pwo.add(r3 + l);
                        *pdwi.add(gi * d + l) = g;
                        a0 += *e * x;
                        a1 += *e.add(1) * x;
                        a2 += *e.add(2) * x;
                        a3 += *e.add(3) * x;
                    }
                    if !lane0_shared {
                        *pdwo.add(r0 + l) = a0;
                    }
                }
                if lane0_shared {
                    *pdwo.add(negs[j0] as usize * d + l) = a0;
                }
                *pdwo.add(r1 + l) = a1;
                *pdwo.add(r2 + l) = a2;
                *pdwo.add(r3 + l) = a3;
                l += 1;
            }
            j0 += 4;
        } else if s - j0 >= 2 {
            let r1 = negs[j0 + 1] as usize * d;
            let mut l = 0usize;
            while l + 16 <= d {
                let w1 = _mm512_loadu_ps(pwo.add(r1 + l));
                let mut a1 = _mm512_loadu_ps(pdwo.add(r1 + l));
                let mut w0 = _mm512_setzero_ps();
                let mut a0 = _mm512_setzero_ps();
                if lane0_shared {
                    let r0 = negs[j0] as usize * d;
                    w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                    a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                }
                for w in 0..r_n {
                    let r0 = slots[w * s + j0] as usize * d;
                    if !lane0_shared {
                        w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                        a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                    }
                    for gi in offs[w] as usize..offs[w + 1] as usize {
                        let e = perr.add(gi * s + j0);
                        let vwi = _mm512_loadu_ps(pwi.add(gi * d + l));
                        let e0 = _mm512_set1_ps(*e);
                        let e1 = _mm512_set1_ps(*e.add(1));
                        let mut g = if first {
                            _mm512_setzero_ps()
                        } else {
                            _mm512_loadu_ps(pdwi.add(gi * d + l))
                        };
                        g = _mm512_fmadd_ps(e0, w0, g);
                        g = _mm512_fmadd_ps(e1, w1, g);
                        _mm512_storeu_ps(pdwi.add(gi * d + l), g);
                        a0 = _mm512_fmadd_ps(e0, vwi, a0);
                        a1 = _mm512_fmadd_ps(e1, vwi, a1);
                    }
                    if !lane0_shared {
                        _mm512_storeu_ps(pdwo.add(r0 + l), a0);
                    }
                }
                if lane0_shared {
                    _mm512_storeu_ps(pdwo.add(negs[j0] as usize * d + l), a0);
                }
                _mm512_storeu_ps(pdwo.add(r1 + l), a1);
                l += 16;
            }
            while l < d {
                let mut a1 = *pdwo.add(r1 + l);
                let mut a0 = 0.0f32;
                if lane0_shared {
                    a0 = *pdwo.add(negs[j0] as usize * d + l);
                }
                for w in 0..r_n {
                    let r0 = slots[w * s + j0] as usize * d;
                    if !lane0_shared {
                        a0 = *pdwo.add(r0 + l);
                    }
                    for gi in offs[w] as usize..offs[w + 1] as usize {
                        let e = perr.add(gi * s + j0);
                        let x = *pwi.add(gi * d + l);
                        let mut g = if first { 0.0 } else { *pdwi.add(gi * d + l) };
                        g += *e * *pwo.add(r0 + l) + *e.add(1) * *pwo.add(r1 + l);
                        *pdwi.add(gi * d + l) = g;
                        a0 += *e * x;
                        a1 += *e.add(1) * x;
                    }
                    if !lane0_shared {
                        *pdwo.add(r0 + l) = a0;
                    }
                }
                if lane0_shared {
                    *pdwo.add(negs[j0] as usize * d + l) = a0;
                }
                *pdwo.add(r1 + l) = a1;
                l += 1;
            }
            j0 += 2;
        } else {
            let mut l = 0usize;
            while l + 16 <= d {
                let mut w0 = _mm512_setzero_ps();
                let mut a0 = _mm512_setzero_ps();
                if lane0_shared {
                    let r0 = negs[j0] as usize * d;
                    w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                    a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                }
                for w in 0..r_n {
                    let r0 = slots[w * s + j0] as usize * d;
                    if !lane0_shared {
                        w0 = _mm512_loadu_ps(pwo.add(r0 + l));
                        a0 = _mm512_loadu_ps(pdwo.add(r0 + l));
                    }
                    for gi in offs[w] as usize..offs[w + 1] as usize {
                        let e0 = _mm512_set1_ps(*perr.add(gi * s + j0));
                        let vwi = _mm512_loadu_ps(pwi.add(gi * d + l));
                        let mut g = if first {
                            _mm512_setzero_ps()
                        } else {
                            _mm512_loadu_ps(pdwi.add(gi * d + l))
                        };
                        g = _mm512_fmadd_ps(e0, w0, g);
                        _mm512_storeu_ps(pdwi.add(gi * d + l), g);
                        a0 = _mm512_fmadd_ps(e0, vwi, a0);
                    }
                    if !lane0_shared {
                        _mm512_storeu_ps(pdwo.add(r0 + l), a0);
                    }
                }
                if lane0_shared {
                    _mm512_storeu_ps(pdwo.add(negs[j0] as usize * d + l), a0);
                }
                l += 16;
            }
            while l < d {
                let mut a0 = 0.0f32;
                if lane0_shared {
                    a0 = *pdwo.add(negs[j0] as usize * d + l);
                }
                for w in 0..r_n {
                    let r0 = slots[w * s + j0] as usize * d;
                    if !lane0_shared {
                        a0 = *pdwo.add(r0 + l);
                    }
                    for gi in offs[w] as usize..offs[w + 1] as usize {
                        let e = *perr.add(gi * s + j0);
                        let x = *pwi.add(gi * d + l);
                        let mut g = if first { 0.0 } else { *pdwi.add(gi * d + l) };
                        g += e * *pwo.add(r0 + l);
                        *pdwi.add(gi * d + l) = g;
                        a0 += e * x;
                    }
                    if !lane0_shared {
                        *pdwo.add(r0 + l) = a0;
                    }
                }
                if lane0_shared {
                    *pdwo.add(negs[j0] as usize * d + l) = a0;
                }
                l += 1;
            }
            j0 += 1;
        }
    }
}

/// Fused `logits <- (label − σ(logits)) · lr`: the bulk is computed with
/// label 0 (`-σ·lr`), then the positive column (j = 0 of each `s`-wide
/// row) gets its `+lr` label term added back.  16-lane twin of
/// `avx2::sgns_err` with the identical branch-stable scalar tail.
#[target_feature(enable = "avx512f", enable = "avx512bw")]
pub unsafe fn sgns_err(logits: &mut [f32], s: usize, lr: f32) {
    let n = logits.len();
    let p = logits.as_mut_ptr();
    let one = _mm512_set1_ps(1.0);
    let neg_lr = _mm512_set1_ps(-lr);
    let zero = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let x = _mm512_loadu_ps(p.add(i));
        let e = exp512(_mm512_sub_ps(zero, x));
        let sig = _mm512_div_ps(one, _mm512_add_ps(one, e));
        _mm512_storeu_ps(p.add(i), _mm512_mul_ps(neg_lr, sig));
        i += 16;
    }
    while i < n {
        let x = *p.add(i);
        let sig = if x >= 0.0 {
            1.0 / (1.0 + (-x).exp())
        } else {
            let e = x.exp();
            e / (1.0 + e)
        };
        *p.add(i) = -lr * sig;
        i += 1;
    }
    let mut r = 0usize;
    while r < n {
        *p.add(r) += lr;
        r += s;
    }
}
