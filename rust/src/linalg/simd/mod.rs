//! Explicit-SIMD kernel layer with runtime CPU dispatch.
//!
//! The paper's speed argument is that level-3 organisation turns SGNS into
//! FMA-bound GEMMs (Sec. III-B); its successor work retargets the same
//! kernels at wider vector units explicitly.  The portable kernels in
//! `linalg::vecops` / `linalg::gemm` *hope* LLVM autovectorises; this
//! module removes the hope: every hot-path primitive has an AVX2+FMA
//! implementation and a 16-lane AVX-512 one (`std::arch` intrinsics)
//! next to the portable-scalar one, selected once per process.
//!
//! Dispatch:
//!
//! * [`SimdLevel::ALL`] is the level registry (widest first); parsing,
//!   `Display`, availability and the `PINNED` encoding all derive from
//!   it, so adding a tier is one enum variant plus one row per match;
//! * [`level()`] resolves `Auto` to the widest AUTO-ELIGIBLE level the
//!   CPU has (AVX2+FMA today — AVX-512 is opt-in, see
//!   [`SimdLevel::auto_eligible`]); detection is cached in `OnceLock`s;
//! * [`configure`] pins the level explicitly — the `--simd
//!   {auto,avx512,avx2,scalar}` config knob routes here, so ablations can
//!   compare dispatch paths on the same binary.  `--simd scalar` executes
//!   the exact same code as the pre-SIMD crate, bit for bit.
//!
//! The dispatched surface is the complete per-window hot path: `dot`,
//! `axpy`, the three GEMM microkernels at the paper's (B≈16, S≈6, D≈300)
//! shapes, the fused `err = (label − σ(logits))·lr` elementwise kernel
//! between GEMM 1 and GEMMs 2/3 — and [`sgns_fused`], the single-pass
//! window kernel that replaces that whole four-kernel chain with one
//! register-tiled sweep (`--kernel {auto,fused,gemm3}` selects between
//! them in the GEMM backend; `gemm3` keeps the chain bit-for-bit for
//! ablation), plus [`sgns_fused_run`], the FULL-W2V-style extension that
//! carries the shared negative rows and accumulators across a RUN of
//! consecutive windows (`--reuse {off,window,sentence}`).

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx512;
pub(crate) mod scalar;

/// The `--simd` config knob: requested dispatch policy.
///
/// `Auto` follows detection; every other mode pins exactly one
/// [`SimdLevel`].  Parsing, `Display` and the error text derive from the
/// level registry ([`SimdLevel::ALL`]), so the mode surface tracks the
/// level surface automatically.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the widest auto-eligible level the CPU has (AVX2+FMA today;
    /// AVX-512 must be requested explicitly — downclock caveats in
    /// EXPERIMENTS.md §AVX-512).
    #[default]
    Auto,
    /// Require the 16-lane AVX-512 kernels (error on CPUs without
    /// avx512f+avx512bw).
    Avx512,
    /// Require the AVX2+FMA kernels (error on CPUs without them).
    Avx2,
    /// Force the portable kernels (bit-identical to the pre-SIMD crate).
    Scalar,
}

impl SimdMode {
    /// The level this mode pins; `None` for `Auto`.
    #[inline]
    pub fn pinned_level(self) -> Option<SimdLevel> {
        match self {
            SimdMode::Auto => None,
            SimdMode::Avx512 => Some(SimdLevel::Avx512),
            SimdMode::Avx2 => Some(SimdLevel::Avx2),
            SimdMode::Scalar => Some(SimdLevel::Scalar),
        }
    }

    /// The mode that pins `level` (inverse of [`Self::pinned_level`]).
    pub fn pinning(level: SimdLevel) -> SimdMode {
        match level {
            SimdLevel::Avx512 => SimdMode::Avx512,
            SimdLevel::Avx2 => SimdMode::Avx2,
            SimdLevel::Scalar => SimdMode::Scalar,
        }
    }
}

impl FromStr for SimdMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        let lower = s.to_ascii_lowercase();
        if lower == "auto" {
            return Ok(SimdMode::Auto);
        }
        for l in SimdLevel::ALL {
            if lower == l.name() {
                return Ok(SimdMode::pinning(l));
            }
        }
        let names: Vec<&str> = std::iter::once("auto")
            .chain(SimdLevel::ALL.iter().map(|l| l.name()))
            .collect();
        anyhow::bail!("unknown simd mode '{lower}' ({})", names.join("|"))
    }
}

impl fmt::Display for SimdMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.pinned_level() {
            None => f.write_str("auto"),
            Some(l) => f.write_str(l.name()),
        }
    }
}

/// The resolved dispatch level actually executing, widest first.
///
/// Discriminants match the [`Self::ALL`] registry positions — the
/// `PINNED` encoding (`code()`/`from_code()`) relies on that, so keep the
/// declaration order and the registry order identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SimdLevel {
    /// 16-lane AVX-512 kernels (avx512f + avx512bw).
    Avx512,
    /// 8-lane AVX2+FMA kernels.
    Avx2,
    /// Portable kernels (the pre-SIMD crate, bit for bit).
    Scalar,
}

impl SimdLevel {
    /// Every dispatchable level, widest first — THE registry that
    /// parsing, `Display`, availability, the `PINNED` encoding and the
    /// bench level sweeps derive from.  Adding a tier is one enum
    /// variant plus one row in each match below; no string tables or
    /// encodings elsewhere need touching.
    pub const ALL: [SimdLevel; 3] =
        [SimdLevel::Avx512, SimdLevel::Avx2, SimdLevel::Scalar];

    /// Canonical knob spelling (`--simd <name>`, `PW2V_SIMD=<name>`).
    pub const fn name(self) -> &'static str {
        match self {
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Scalar => "scalar",
        }
    }

    /// The CPUID features the level needs (for diagnostics).
    const fn requirement(self) -> &'static str {
        match self {
            SimdLevel::Avx512 => "avx512f+avx512bw",
            SimdLevel::Avx2 => "avx2+fma",
            SimdLevel::Scalar => "nothing",
        }
    }

    /// Whether this CPU can run the level (cached CPUID detection).
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Avx512 => avx512_available(),
            SimdLevel::Avx2 => avx2_available(),
            SimdLevel::Scalar => true,
        }
    }

    /// Whether `--simd auto` may resolve to this level.  AVX-512 is
    /// deliberately opt-in: on many cores 512-bit vectors downclock the
    /// whole socket, so the 16-lane tier must be requested explicitly
    /// after measuring (EXPERIMENTS.md §AVX-512).
    const fn auto_eligible(self) -> bool {
        !matches!(self, SimdLevel::Avx512)
    }

    /// `PINNED` encoding: 0 is "unpinned", each level is its registry
    /// position + 1.
    fn code(self) -> u8 {
        self as u8 + 1
    }

    fn from_code(code: u8) -> Option<SimdLevel> {
        SimdLevel::ALL.get(code.wrapping_sub(1) as usize).copied()
    }
}

impl fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// 0 = unpinned (follow detection); `level.code()` pins that level.
static PINNED: AtomicU8 = AtomicU8::new(0);

/// CPUID detection, done once per process.
fn avx2_available() -> bool {
    static AVAILABLE: OnceLock<bool> = OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// CPUID detection for the 16-lane tier, done once per process:
/// `avx512f` (512-bit f32 FMA foundation) plus `avx512bw` (byte/word
/// integer ops, needed by the int8 dot).
fn avx512_available() -> bool {
    static AVAILABLE: OnceLock<bool> = OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512bw")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// The level `Auto` resolves to: the widest auto-eligible level this CPU
/// has (always terminates at Scalar, which is unconditionally available).
fn detected() -> SimdLevel {
    for l in SimdLevel::ALL {
        if l.auto_eligible() && l.available() {
            return l;
        }
    }
    SimdLevel::Scalar
}

/// Apply a [`SimdMode`]; returns the level that will run.  Pinning modes
/// pin their level; `Auto` UNPINS (back to detection), so a
/// scalar-pinned run never leaks into a later `--simd auto` run in the
/// same process.  A pinned level errors when the CPU lacks its features
/// instead of mis-executing — `--simd avx512` on a non-AVX-512 box is a
/// clean startup error, never an illegal instruction.
///
/// The dispatch level is deliberately PROCESS-GLOBAL (the issue's
/// "selected once at startup"): all levels compute the same answers, so
/// concurrent trainers with different `--simd` settings stay correct,
/// but they would contaminate each other's *timings* — run dispatch
/// ablations sequentially, as the benches do.
pub fn configure(mode: SimdMode) -> anyhow::Result<SimdLevel> {
    let (pin, level) = match mode.pinned_level() {
        None => (0, detected()),
        Some(l) => {
            anyhow::ensure!(
                l.available(),
                "--simd {l} requested but the CPU lacks {}",
                l.requirement()
            );
            (l.code(), l)
        }
    };
    PINNED.store(pin, Ordering::Relaxed);
    Ok(level)
}

/// The dispatch level in effect (pinned, else detected).
#[inline]
pub fn level() -> SimdLevel {
    match SimdLevel::from_code(PINNED.load(Ordering::Relaxed)) {
        Some(l) => l,
        None => detected(),
    }
}

/// Dispatched dot product `<a, b>`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: level() returns a vector tier only when its CPUID
        // features were detected (or explicitly pinned via configure,
        // which re-checks availability).
        match level() {
            SimdLevel::Avx512 => return unsafe { avx512::dot(a, b) },
            SimdLevel::Avx2 => return unsafe { avx2::dot(a, b) },
            SimdLevel::Scalar => {}
        }
    }
    scalar::dot(a, b)
}

/// Dispatched integer dot `<a, b>` over int8 quantized codes (the serve
/// engine's int8 row store).  Pure i32 accumulation of i8·i8 products —
/// EXACTLY equal across dispatch levels, unlike the f32 kernels'
/// bounded reassociation drift.  The i32-overflow length bound
/// (len ≤ 2¹⁷, so 2¹⁷·127² < 2³¹) is validated ONCE, with a typed
/// error, where int8 stores are built (`serve::store::MAX_DIM` at
/// `RowStore` construction and `QuantStore::build`); the kernel keeps a
/// `debug_assert!` only, so a hot serve request can never panic
/// mid-scan in release builds.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len(), "dot_i8 length mismatch");
    debug_assert!(
        a.len() <= 1 << 17,
        "dot_i8 length exceeds overflow-safe bound"
    );
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate as in `dot`.
        match level() {
            SimdLevel::Avx512 => return unsafe { avx512::dot_i8(a, b) },
            SimdLevel::Avx2 => return unsafe { avx2::dot_i8(a, b) },
            SimdLevel::Scalar => {}
        }
    }
    scalar::dot_i8(a, b)
}

/// Dispatched `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate as in `dot`.
        match level() {
            SimdLevel::Avx512 => return unsafe { avx512::axpy(alpha, x, y) },
            SimdLevel::Avx2 => return unsafe { avx2::axpy(alpha, x, y) },
            SimdLevel::Scalar => {}
        }
    }
    scalar::axpy(alpha, x, y)
}

/// Dispatched `c[m,n] = alpha * a[m,k] · b[n,k]ᵀ + beta * c` (GEMM 1:
/// logits).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    // Release-mode asserts: the vector kernels index through raw
    // pointers, so undersized slices must panic here, not corrupt memory
    // there.
    assert!(a.len() >= m * k && b.len() >= n * k && c.len() >= m * n);
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate; slice bounds asserted above.
        match level() {
            SimdLevel::Avx512 => {
                return unsafe { avx512::gemm_nt(m, n, k, alpha, a, b, beta, c) }
            }
            SimdLevel::Avx2 => {
                return unsafe { avx2::gemm_nt(m, n, k, alpha, a, b, beta, c) }
            }
            SimdLevel::Scalar => {}
        }
    }
    scalar::gemm_nt(m, n, k, alpha, a, b, beta, c)
}

/// Dispatched `c[m,n] = alpha * a[m,k] · b[k,n] + beta * c` (GEMM 2: dWi).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate; slice bounds asserted above.
        match level() {
            SimdLevel::Avx512 => {
                return unsafe { avx512::gemm_nn(m, n, k, alpha, a, b, beta, c) }
            }
            SimdLevel::Avx2 => {
                return unsafe { avx2::gemm_nn(m, n, k, alpha, a, b, beta, c) }
            }
            SimdLevel::Scalar => {}
        }
    }
    scalar::gemm_nn(m, n, k, alpha, a, b, beta, c)
}

/// Dispatched `c[m,n] = alpha * a[k,m]ᵀ · b[k,n] + beta * c` (GEMM 3: dWo).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(a.len() >= k * m && b.len() >= k * n && c.len() >= m * n);
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate; slice bounds asserted above.
        match level() {
            SimdLevel::Avx512 => {
                return unsafe { avx512::gemm_tn(m, n, k, alpha, a, b, beta, c) }
            }
            SimdLevel::Avx2 => {
                return unsafe { avx2::gemm_tn(m, n, k, alpha, a, b, beta, c) }
            }
            SimdLevel::Scalar => {}
        }
    }
    scalar::gemm_tn(m, n, k, alpha, a, b, beta, c)
}

/// Dispatched fused elementwise kernel between GEMM 1 and GEMMs 2/3:
/// `logits[r, j] <- (label(j) − σ(logits[r, j])) · lr` in place, where
/// `label(j)` is 1 for the positive column (j = 0 of each `s`-wide row)
/// and 0 for the shared negatives.
#[inline]
pub fn sgns_err(logits: &mut [f32], s: usize, lr: f32) {
    assert!(s > 0 && logits.len() % s == 0, "sgns_err geometry");
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate.
        match level() {
            SimdLevel::Avx512 => return unsafe { avx512::sgns_err(logits, s, lr) },
            SimdLevel::Avx2 => return unsafe { avx2::sgns_err(logits, s, lr) },
            SimdLevel::Scalar => {}
        }
    }
    scalar::sgns_err(logits, s, lr)
}

/// Dispatched FUSED single-pass SGNS window kernel — the perf-PR
/// tentpole that collapses `gemm_nt → sgns_err → gemm_nn → gemm_tn` into
/// one call (see `scalar::sgns_fused` for the reference semantics and
/// `avx2::sgns_fused` / `avx512::sgns_fused` for the register-tiling):
///
/// * `wi` holds `b = wi.len()/d` gathered input rows;
/// * `slots` selects the `s` output rows inside `wo`/`dwo` (the
///   superbatch dedup block; identity `0..s` for the window-at-a-time
///   path), `slots[0]` being the positive target;
/// * `err` is caller scratch of at least `b·s` (the L1-resident logits
///   tile — never round-trips between kernel calls);
/// * `dwi` is OVERWRITTEN with the input-row gradients;
/// * `dwo` rows named by `slots` are ACCUMULATED into (callers zero or
///   carry them across a superbatch).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn sgns_fused(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    // Release-mode asserts: the vector kernels index through raw
    // pointers, so bad geometry must panic here, not corrupt memory
    // there.
    assert!(d > 0 && s > 0 && slots.len() == s, "sgns_fused geometry");
    assert!(
        wi.len() % d == 0 && dwi.len() == wi.len(),
        "sgns_fused wi/dwi geometry"
    );
    let b = wi.len() / d;
    assert!(err.len() >= b * s, "sgns_fused err scratch undersized");
    let max_row = slots.iter().map(|&x| x as usize).max().unwrap_or(0);
    assert!(
        (max_row + 1) * d <= wo.len() && (max_row + 1) * d <= dwo.len(),
        "sgns_fused slot out of range"
    );
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate; slice bounds asserted above.
        match level() {
            SimdLevel::Avx512 => {
                return unsafe {
                    avx512::sgns_fused(s, d, lr, wi, wo, slots, err, dwi, dwo)
                }
            }
            SimdLevel::Avx2 => {
                return unsafe {
                    avx2::sgns_fused(s, d, lr, wi, wo, slots, err, dwi, dwo)
                }
            }
            SimdLevel::Scalar => {}
        }
    }
    scalar::sgns_fused(s, d, lr, wi, wo, slots, err, dwi, dwo)
}

/// Dispatched fused kernel over a RUN of consecutive windows that share
/// one negative-slot set — the FULL-W2V-style cross-window reuse behind
/// `--reuse sentence` (the driver groups a sentence's windows into runs):
///
/// * `offs` delimits each window's rows inside `wi`/`dwi` (CSR-style
///   row offsets; `offs.len() - 1` windows, strictly increasing);
/// * `slots` holds `s` output slots per window, window-major; every
///   window's `slots[1..]` (the shared negatives) must be identical
///   across the run, and for runs longer than one window each window's
///   slots must be pairwise distinct — the driver routes duplicate-slot
///   windows into singleton runs, where the per-window kernel's
///   sequential fallback applies;
/// * `err` is caller scratch of at least `rows·s` (global-row-major:
///   run row `g` occupies `err[g·s .. (g+1)·s]`);
/// * semantics are EXACTLY `offs.len() - 1` consecutive [`sgns_fused`]
///   calls at the same dispatch level (pinned bitwise in
///   `tests/props.rs`): the vector paths keep the shared negative `wo`
///   rows and their `dwo` accumulators in registers across the whole run
///   instead of re-reading them per window — bit-identical because an
///   f32 store/reload round-trip is exact and the per-location operation
///   order is unchanged.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn sgns_fused_run(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    offs: &[u32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    assert!(d > 0 && s > 0 && offs.len() >= 2, "sgns_fused_run geometry");
    let r_n = offs.len() - 1;
    assert_eq!(slots.len(), r_n * s, "sgns_fused_run slots geometry");
    assert!(
        offs[0] == 0 && offs.windows(2).all(|p| p[0] < p[1]),
        "sgns_fused_run offsets not strictly increasing from 0"
    );
    let rows = offs[r_n] as usize;
    assert!(
        wi.len() == rows * d && dwi.len() == wi.len(),
        "sgns_fused_run wi/dwi geometry"
    );
    assert!(err.len() >= rows * s, "sgns_fused_run err scratch undersized");
    let max_row = slots.iter().map(|&x| x as usize).max().unwrap_or(0);
    assert!(
        (max_row + 1) * d <= wo.len() && (max_row + 1) * d <= dwo.len(),
        "sgns_fused_run slot out of range"
    );
    // Driver contract, checked in debug builds: negatives shared across
    // the run, and multi-window runs duplicate-free per window.
    debug_assert!(
        (1..r_n).all(|w| slots[w * s + 1..(w + 1) * s] == slots[1..s]),
        "sgns_fused_run: negatives differ across the run"
    );
    debug_assert!(
        r_n == 1
            || (0..r_n).all(|w| {
                let sl = &slots[w * s..(w + 1) * s];
                sl.iter().enumerate().all(|(j, x)| !sl[..j].contains(x))
            }),
        "sgns_fused_run: duplicate slot inside a multi-window run"
    );
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: detection gate; slice bounds asserted above.
        match level() {
            SimdLevel::Avx512 => {
                return unsafe {
                    avx512::sgns_fused_run(
                        s, d, lr, wi, offs, wo, slots, err, dwi, dwo,
                    )
                }
            }
            SimdLevel::Avx2 => {
                return unsafe {
                    avx2::sgns_fused_run(
                        s, d, lr, wi, offs, wo, slots, err, dwi, dwo,
                    )
                }
            }
            SimdLevel::Scalar => {}
        }
    }
    scalar::sgns_fused_run(s, d, lr, wi, offs, wo, slots, err, dwi, dwo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sigmoid::sigmoid_exact;
    use crate::util::rng::Xoshiro256ss;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256ss::new(seed);
        (0..n).map(|_| r.next_f32() - 0.5).collect()
    }

    #[test]
    fn mode_parsing_and_display() {
        assert_eq!("auto".parse::<SimdMode>().unwrap(), SimdMode::Auto);
        assert_eq!("AVX2".parse::<SimdMode>().unwrap(), SimdMode::Avx2);
        assert_eq!("scalar".parse::<SimdMode>().unwrap(), SimdMode::Scalar);
        assert!("sse9".parse::<SimdMode>().is_err());
        assert_eq!(SimdMode::Avx2.to_string(), "avx2");
        assert_eq!(SimdLevel::Scalar.to_string(), "scalar");
        // The 16-lane tier is a first-class mode: it parses (the old
        // closed-enum contract asserted this FAILED), displays, and its
        // name appears in the error text for unknown modes.
        assert_eq!("avx512".parse::<SimdMode>().unwrap(), SimdMode::Avx512);
        assert_eq!("AVX512".parse::<SimdMode>().unwrap(), SimdMode::Avx512);
        assert_eq!(SimdMode::Avx512.to_string(), "avx512");
        let err = "sse9".parse::<SimdMode>().unwrap_err().to_string();
        assert!(err.contains("auto|avx512|avx2|scalar"), "{err}");
    }

    /// The registry IS the single source of truth: every mode except
    /// `Auto` round-trips through a registry level, codes round-trip
    /// through `from_code`, and 0 means unpinned.
    #[test]
    fn level_registry_is_consistent() {
        assert_eq!(SimdLevel::from_code(0), None);
        for (i, l) in SimdLevel::ALL.into_iter().enumerate() {
            assert_eq!(l.code() as usize, i + 1, "{l}: code is position + 1");
            assert_eq!(SimdLevel::from_code(l.code()), Some(l));
            assert_eq!(l.name().parse::<SimdMode>().unwrap().pinned_level(), Some(l));
            assert_eq!(SimdMode::pinning(l).to_string(), l.name());
        }
        assert_eq!(
            SimdLevel::from_code(SimdLevel::ALL.len() as u8 + 1),
            None,
            "codes past the registry are unpinned, never UB"
        );
        assert!(SimdLevel::Scalar.available(), "scalar is always runnable");
        assert!(
            !SimdLevel::Avx512.auto_eligible(),
            "avx512 stays opt-in under --simd auto"
        );
    }

    /// `configure`'s RETURN VALUE reports the resolved level (asserting
    /// on the process-global `level()` here would race with other test
    /// threads calling `train`, which also configures).  The pinned
    /// dispatch level's bit-identity is asserted in `tests/props.rs`,
    /// whose process has a single configure caller.
    #[test]
    fn configure_resolves_levels() {
        assert_eq!(configure(SimdMode::Scalar).unwrap(), SimdLevel::Scalar);
        let auto = configure(SimdMode::Auto).unwrap();
        match configure(SimdMode::Avx2) {
            Ok(l) => {
                assert_eq!(l, SimdLevel::Avx2);
                assert_eq!(auto, SimdLevel::Avx2);
            }
            Err(_) => assert_eq!(auto, SimdLevel::Scalar),
        }
        // avx512: configure either pins the 16-lane tier (CPU has it) or
        // errors with the requirement named — never panics, never pins a
        // level the CPU cannot run.  Auto NEVER resolves to it.
        match configure(SimdMode::Avx512) {
            Ok(l) => assert_eq!(l, SimdLevel::Avx512),
            Err(e) => assert!(
                e.to_string().contains("avx512f+avx512bw"),
                "rejection must name the missing features: {e}"
            ),
        }
        assert_ne!(configure(SimdMode::Auto).unwrap(), SimdLevel::Avx512);
        // Leave the process unpinned for everyone else.
        configure(SimdMode::Auto).unwrap();
    }

    /// The scalar dispatch targets ARE the portable kernels (delegation,
    /// bit for bit) — the contract behind "`--simd scalar` reproduces the
    /// pre-SIMD crate exactly".
    #[test]
    fn scalar_module_is_the_portable_kernels() {
        let a = randv(300, 1);
        let b = randv(300, 2);
        assert_eq!(
            scalar::dot(&a, &b).to_bits(),
            crate::linalg::vecops::dot(&a, &b).to_bits()
        );
        let mut y1 = randv(300, 3);
        let mut y2 = y1.clone();
        scalar::axpy(0.37, &a, &mut y1);
        crate::linalg::vecops::axpy(0.37, &a, &mut y2);
        assert_eq!(
            y1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            y2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // The fused err kernel matches the pre-SIMD inline loop exactly.
        let logits = randv(96, 4);
        let mut got = logits.clone();
        scalar::sgns_err(&mut got, 6, 0.025);
        for (idx, (g, x)) in got.iter().zip(&logits).enumerate() {
            let label = if idx % 6 == 0 { 1.0 } else { 0.0 };
            let want = (label - sigmoid_exact(*x)) * 0.025;
            assert_eq!(g.to_bits(), want.to_bits(), "idx {idx}");
        }
    }

    /// Whatever level is currently dispatched, the fused window kernel
    /// must agree with the per-pair definition — including slot
    /// indirection and duplicate slots (the sequential-fallback path).
    #[test]
    fn sgns_fused_matches_definition() {
        for (b, s, d, slots) in [
            (16usize, 6usize, 300usize, vec![3u32, 7, 0, 5, 2, 6]),
            (1, 5, 33, vec![1, 4, 2, 0, 3]),
            (4, 3, 8, vec![2, 0, 1]),
            // Duplicate slot: two identical negative draws in one window.
            (5, 6, 31, vec![0, 4, 4, 2, 1, 3]),
        ] {
            let u = 8usize; // rows in the wo/dwo blocks
            let mut rng = Xoshiro256ss::new(0xF05E + b as u64);
            let wi = randv(b * d, rng.next_u64());
            let wo = randv(u * d, rng.next_u64());
            let lr = 0.025f32;
            let mut err = vec![0.0f32; b * s];
            let mut dwi = randv(b * d, 1); // garbage: must be overwritten
            let mut dwo = randv(u * d, 2);
            let dwo0 = dwo.clone(); // accumulation baseline
            sgns_fused(s, d, lr, &wi, &wo, &slots, &mut err, &mut dwi, &mut dwo);

            let mut want_dwi = vec![0.0f32; b * d];
            let mut want_dwo = dwo0;
            for i in 0..b {
                for (j, &slot) in slots.iter().enumerate() {
                    let r = slot as usize * d;
                    let x: f32 = (0..d)
                        .map(|l| wi[i * d + l] * wo[r + l])
                        .sum();
                    let label = if j == 0 { 1.0 } else { 0.0 };
                    let e = (label - sigmoid_exact(x)) * lr;
                    for l in 0..d {
                        want_dwi[i * d + l] += e * wo[r + l];
                        want_dwo[r + l] += e * wi[i * d + l];
                    }
                }
            }
            for (idx, (g, w)) in dwi.iter().zip(&want_dwi).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "dwi (b={b},s={s},d={d}) idx {idx}: {g} vs {w}"
                );
            }
            for (idx, (g, w)) in dwo.iter().zip(&want_dwo).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "dwo (b={b},s={s},d={d}) idx {idx}: {g} vs {w}"
                );
            }
        }
    }

    /// Whatever level is currently dispatched, the RUN kernel must equal
    /// repeated per-window [`sgns_fused`] calls BIT FOR BIT — this is
    /// the run kernel's defining contract (the level×shape matrix lives
    /// in `tests/props.rs`; this is the in-crate smoke).
    #[test]
    fn sgns_fused_run_is_bitwise_repeated_windows() {
        let (s, d, u) = (6usize, 37usize, 11usize);
        let bs = [3usize, 1, 4]; // rows per window
        let rows: usize = bs.iter().sum();
        let mut rng = Xoshiro256ss::new(0x4E57);
        let wi = randv(rows * d, rng.next_u64());
        let wo = randv(u * d, rng.next_u64());
        let lr = 0.025f32;
        // Shared negatives, per-window positives (dup-free per window).
        let negs = [7u32, 2, 9, 4, 0];
        let mut slots = Vec::new();
        let mut offs = vec![0u32];
        for (w, &b) in bs.iter().enumerate() {
            slots.push(w as u32 + 1); // positive: 1, 2, 3 (≠ negs? 2 IS a neg)
            slots.extend_from_slice(&negs);
            offs.push(offs.last().unwrap() + b as u32);
        }
        // Window 1's positive (2) duplicates a shared negative, which a
        // multi-window run forbids — fix it to a clean id.
        slots[s] = 10;

        let mut want_dwi = vec![0.0f32; rows * d];
        let mut want_dwo = randv(u * d, 3);
        let mut got_dwi = vec![0.0f32; rows * d];
        let mut got_dwo = want_dwo.clone();
        let mut err = vec![0.0f32; rows * s];
        for (w, _) in bs.iter().enumerate() {
            let (lo, hi) = (offs[w] as usize, offs[w + 1] as usize);
            sgns_fused(
                s,
                d,
                lr,
                &wi[lo * d..hi * d],
                &wo,
                &slots[w * s..(w + 1) * s],
                &mut err[lo * s..hi * s],
                &mut want_dwi[lo * d..hi * d],
                &mut want_dwo,
            );
        }
        let mut err2 = vec![0.0f32; rows * s];
        sgns_fused_run(
            s, d, lr, &wi, &offs, &wo, &slots, &mut err2, &mut got_dwi,
            &mut got_dwo,
        );
        assert_eq!(
            got_dwi.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want_dwi.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "dwi must be bitwise the repeated per-window kernel"
        );
        assert_eq!(
            got_dwo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want_dwo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "dwo must be bitwise the repeated per-window kernel"
        );
    }

    /// The int8 dot is integer arithmetic: whatever level dispatches,
    /// the answer must EQUAL the scalar reference — not approximate it.
    #[test]
    fn dot_i8_levels_agree_exactly() {
        let mut rng = Xoshiro256ss::new(0x18_D07);
        for n in [0usize, 1, 7, 15, 16, 17, 31, 48, 127, 300, 1024] {
            let a: Vec<i8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8 as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8 as i8).collect();
            let want = scalar::dot_i8(&a, &b);
            assert_eq!(dot_i8(&a, &b), want, "n={n}");
            // And scalar matches the obvious definition.
            let naive: i32 = a.iter().zip(&b).map(|(x, y)| *x as i32 * *y as i32).sum();
            assert_eq!(want, naive, "n={n}");
        }
        // Extremes: every code at ±127 at the store layer's length cap
        // (`serve::store::MAX_DIM`; the kernel itself only debug-asserts).
        let a = vec![127i8; 1 << 17];
        let b = vec![-127i8; 1 << 17];
        assert_eq!(dot_i8(&a, &b), -(127i32 * 127) * (1 << 17));
    }

    /// Whatever level is currently dispatched, the fused err kernel must
    /// agree with the exact definition.
    #[test]
    fn sgns_err_matches_definition() {
        let (b, s) = (16usize, 6usize);
        let logits = randv(b * s, 9);
        let lr = 0.025f32;
        let mut got = logits.clone();
        sgns_err(&mut got, s, lr);
        for i in 0..b {
            for j in 0..s {
                let label = if j == 0 { 1.0 } else { 0.0 };
                let want = (label - sigmoid_exact(logits[i * s + j])) * lr;
                let g = got[i * s + j];
                assert!(
                    (g - want).abs() < 1e-6,
                    "({i},{j}): {g} vs {want}"
                );
            }
        }
    }
}
