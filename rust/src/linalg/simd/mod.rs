//! Explicit-SIMD kernel layer with runtime CPU dispatch.
//!
//! The paper's speed argument is that level-3 organisation turns SGNS into
//! FMA-bound GEMMs (Sec. III-B); its successor work retargets the same
//! kernels at wider vector units explicitly.  The portable kernels in
//! `linalg::vecops` / `linalg::gemm` *hope* LLVM autovectorises; this
//! module removes the hope: every hot-path primitive has an AVX2+FMA
//! implementation (`std::arch` intrinsics) next to the portable-scalar
//! one, selected once per process.
//!
//! Dispatch:
//!
//! * [`level()`] resolves to [`SimdLevel::Avx2`] iff the CPU reports
//!   `avx2` **and** `fma` (detection result cached in a `OnceLock`);
//! * [`configure`] pins the level explicitly — the `--simd
//!   {auto,avx2,scalar}` config knob routes here, so ablations can compare
//!   dispatch paths on the same binary.  `--simd scalar` executes the
//!   exact same code as the pre-SIMD crate, bit for bit.
//!
//! The dispatched surface is the complete per-window hot path: `dot`,
//! `axpy`, the three GEMM microkernels at the paper's (B≈16, S≈6, D≈300)
//! shapes, the fused `err = (label − σ(logits))·lr` elementwise kernel
//! between GEMM 1 and GEMMs 2/3 — and [`sgns_fused`], the single-pass
//! window kernel that replaces that whole four-kernel chain with one
//! register-tiled sweep (`--kernel {auto,fused,gemm3}` selects between
//! them in the GEMM backend; `gemm3` keeps the chain bit-for-bit for
//! ablation).

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
pub(crate) mod scalar;

/// The `--simd` config knob: requested dispatch policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdMode {
    /// Use AVX2+FMA when the CPU has it, scalar otherwise.
    #[default]
    Auto,
    /// Require the AVX2+FMA kernels (error on CPUs without them).
    Avx2,
    /// Force the portable kernels (bit-identical to the pre-SIMD crate).
    Scalar,
}

impl FromStr for SimdMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(SimdMode::Auto),
            "avx2" => Ok(SimdMode::Avx2),
            "scalar" => Ok(SimdMode::Scalar),
            other => anyhow::bail!("unknown simd mode '{other}' (auto|avx2|scalar)"),
        }
    }
}

impl fmt::Display for SimdMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            SimdMode::Auto => "auto",
            SimdMode::Avx2 => "avx2",
            SimdMode::Scalar => "scalar",
        })
    }
}

/// The resolved dispatch level actually executing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    Avx2,
    Scalar,
}

impl fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Scalar => "scalar",
        })
    }
}

/// 0 = unpinned (follow detection), 1 = avx2, 2 = scalar.
static PINNED: AtomicU8 = AtomicU8::new(0);

/// CPUID detection, done once per process.
fn avx2_available() -> bool {
    static AVAILABLE: OnceLock<bool> = OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Apply a [`SimdMode`]; returns the level that will run.  `Avx2` /
/// `Scalar` pin the level; `Auto` UNPINS (back to detection), so a
/// scalar-pinned run never leaks into a later `--simd auto` run in the
/// same process.  `Avx2` errors on CPUs without avx2+fma instead of
/// mis-executing.
///
/// The dispatch level is deliberately PROCESS-GLOBAL (the issue's
/// "selected once at startup"): both levels compute the same answers, so
/// concurrent trainers with different `--simd` settings stay correct,
/// but they would contaminate each other's *timings* — run dispatch
/// ablations sequentially, as the benches do.
pub fn configure(mode: SimdMode) -> anyhow::Result<SimdLevel> {
    let (pin, level) = match mode {
        SimdMode::Auto => (
            0,
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            },
        ),
        SimdMode::Avx2 => {
            anyhow::ensure!(
                avx2_available(),
                "--simd avx2 requested but the CPU lacks avx2+fma"
            );
            (1, SimdLevel::Avx2)
        }
        SimdMode::Scalar => (2, SimdLevel::Scalar),
    };
    PINNED.store(pin, Ordering::Relaxed);
    Ok(level)
}

/// The dispatch level in effect (pinned, else detected).
#[inline]
pub fn level() -> SimdLevel {
    match PINNED.load(Ordering::Relaxed) {
        1 => SimdLevel::Avx2,
        2 => SimdLevel::Scalar,
        _ => {
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
    }
}

/// Dispatched dot product `<a, b>`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: level() is Avx2 only when avx2+fma were detected.
            return unsafe { avx2::dot(a, b) };
        }
    }
    scalar::dot(a, b)
}

/// Dispatched integer dot `<a, b>` over int8 quantized codes (the serve
/// engine's int8 row store).  Pure i32 accumulation of i8·i8 products —
/// EXACTLY equal across dispatch levels, unlike the f32 kernels'
/// bounded reassociation drift.  Length is capped at 2¹⁷ so the
/// accumulator cannot overflow even with every code at ±127
/// (2¹⁷ · 127² < 2³¹); serve dims sit orders of magnitude below that.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len(), "dot_i8 length mismatch");
    assert!(a.len() <= 1 << 17, "dot_i8 length exceeds overflow-safe bound");
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: level() is Avx2 only when avx2+fma were detected.
            return unsafe { avx2::dot_i8(a, b) };
        }
    }
    scalar::dot_i8(a, b)
}

/// Dispatched `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: detection gate as in `dot`.
            return unsafe { avx2::axpy(alpha, x, y) };
        }
    }
    scalar::axpy(alpha, x, y)
}

/// Dispatched `c[m,n] = alpha * a[m,k] · b[n,k]ᵀ + beta * c` (GEMM 1:
/// logits).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    // Release-mode asserts: the AVX2 kernels index through raw pointers,
    // so undersized slices must panic here, not corrupt memory there.
    assert!(a.len() >= m * k && b.len() >= n * k && c.len() >= m * n);
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: detection gate; slice bounds asserted above.
            return unsafe { avx2::gemm_nt(m, n, k, alpha, a, b, beta, c) };
        }
    }
    scalar::gemm_nt(m, n, k, alpha, a, b, beta, c)
}

/// Dispatched `c[m,n] = alpha * a[m,k] · b[k,n] + beta * c` (GEMM 2: dWi).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: detection gate; slice bounds asserted above.
            return unsafe { avx2::gemm_nn(m, n, k, alpha, a, b, beta, c) };
        }
    }
    scalar::gemm_nn(m, n, k, alpha, a, b, beta, c)
}

/// Dispatched `c[m,n] = alpha * a[k,m]ᵀ · b[k,n] + beta * c` (GEMM 3: dWo).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(a.len() >= k * m && b.len() >= k * n && c.len() >= m * n);
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: detection gate; slice bounds asserted above.
            return unsafe { avx2::gemm_tn(m, n, k, alpha, a, b, beta, c) };
        }
    }
    scalar::gemm_tn(m, n, k, alpha, a, b, beta, c)
}

/// Dispatched fused elementwise kernel between GEMM 1 and GEMMs 2/3:
/// `logits[r, j] <- (label(j) − σ(logits[r, j])) · lr` in place, where
/// `label(j)` is 1 for the positive column (j = 0 of each `s`-wide row)
/// and 0 for the shared negatives.
#[inline]
pub fn sgns_err(logits: &mut [f32], s: usize, lr: f32) {
    assert!(s > 0 && logits.len() % s == 0, "sgns_err geometry");
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: detection gate.
            return unsafe { avx2::sgns_err(logits, s, lr) };
        }
    }
    scalar::sgns_err(logits, s, lr)
}

/// Dispatched FUSED single-pass SGNS window kernel — the perf-PR
/// tentpole that collapses `gemm_nt → sgns_err → gemm_nn → gemm_tn` into
/// one call (see `scalar::sgns_fused` for the reference semantics and
/// `avx2::sgns_fused` for the register-tiling):
///
/// * `wi` holds `b = wi.len()/d` gathered input rows;
/// * `slots` selects the `s` output rows inside `wo`/`dwo` (the
///   superbatch dedup block; identity `0..s` for the window-at-a-time
///   path), `slots[0]` being the positive target;
/// * `err` is caller scratch of at least `b·s` (the L1-resident logits
///   tile — never round-trips between kernel calls);
/// * `dwi` is OVERWRITTEN with the input-row gradients;
/// * `dwo` rows named by `slots` are ACCUMULATED into (callers zero or
///   carry them across a superbatch).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn sgns_fused(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    // Release-mode asserts: the AVX2 kernel indexes through raw pointers,
    // so bad geometry must panic here, not corrupt memory there.
    assert!(d > 0 && s > 0 && slots.len() == s, "sgns_fused geometry");
    assert!(
        wi.len() % d == 0 && dwi.len() == wi.len(),
        "sgns_fused wi/dwi geometry"
    );
    let b = wi.len() / d;
    assert!(err.len() >= b * s, "sgns_fused err scratch undersized");
    let max_row = slots.iter().map(|&x| x as usize).max().unwrap_or(0);
    assert!(
        (max_row + 1) * d <= wo.len() && (max_row + 1) * d <= dwo.len(),
        "sgns_fused slot out of range"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if level() == SimdLevel::Avx2 {
            // SAFETY: detection gate; slice bounds asserted above.
            return unsafe {
                avx2::sgns_fused(s, d, lr, wi, wo, slots, err, dwi, dwo)
            };
        }
    }
    scalar::sgns_fused(s, d, lr, wi, wo, slots, err, dwi, dwo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sigmoid::sigmoid_exact;
    use crate::util::rng::Xoshiro256ss;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256ss::new(seed);
        (0..n).map(|_| r.next_f32() - 0.5).collect()
    }

    #[test]
    fn mode_parsing_and_display() {
        assert_eq!("auto".parse::<SimdMode>().unwrap(), SimdMode::Auto);
        assert_eq!("AVX2".parse::<SimdMode>().unwrap(), SimdMode::Avx2);
        assert_eq!("scalar".parse::<SimdMode>().unwrap(), SimdMode::Scalar);
        assert!("sse9".parse::<SimdMode>().is_err());
        assert_eq!(SimdMode::Avx2.to_string(), "avx2");
        assert_eq!(SimdLevel::Scalar.to_string(), "scalar");
    }

    /// `configure`'s RETURN VALUE reports the resolved level (asserting
    /// on the process-global `level()` here would race with other test
    /// threads calling `train`, which also configures).  The pinned
    /// dispatch level's bit-identity is asserted in `tests/props.rs`,
    /// whose process has a single configure caller.
    #[test]
    fn configure_resolves_levels() {
        assert_eq!(configure(SimdMode::Scalar).unwrap(), SimdLevel::Scalar);
        let auto = configure(SimdMode::Auto).unwrap();
        match configure(SimdMode::Avx2) {
            Ok(l) => {
                assert_eq!(l, SimdLevel::Avx2);
                assert_eq!(auto, SimdLevel::Avx2);
            }
            Err(_) => assert_eq!(auto, SimdLevel::Scalar),
        }
        // Leave the process unpinned for everyone else.
        configure(SimdMode::Auto).unwrap();
    }

    /// The scalar dispatch targets ARE the portable kernels (delegation,
    /// bit for bit) — the contract behind "`--simd scalar` reproduces the
    /// pre-SIMD crate exactly".
    #[test]
    fn scalar_module_is_the_portable_kernels() {
        let a = randv(300, 1);
        let b = randv(300, 2);
        assert_eq!(
            scalar::dot(&a, &b).to_bits(),
            crate::linalg::vecops::dot(&a, &b).to_bits()
        );
        let mut y1 = randv(300, 3);
        let mut y2 = y1.clone();
        scalar::axpy(0.37, &a, &mut y1);
        crate::linalg::vecops::axpy(0.37, &a, &mut y2);
        assert_eq!(
            y1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            y2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // The fused err kernel matches the pre-SIMD inline loop exactly.
        let logits = randv(96, 4);
        let mut got = logits.clone();
        scalar::sgns_err(&mut got, 6, 0.025);
        for (idx, (g, x)) in got.iter().zip(&logits).enumerate() {
            let label = if idx % 6 == 0 { 1.0 } else { 0.0 };
            let want = (label - sigmoid_exact(*x)) * 0.025;
            assert_eq!(g.to_bits(), want.to_bits(), "idx {idx}");
        }
    }

    /// Whatever level is currently dispatched, the fused window kernel
    /// must agree with the per-pair definition — including slot
    /// indirection and duplicate slots (the sequential-fallback path).
    #[test]
    fn sgns_fused_matches_definition() {
        for (b, s, d, slots) in [
            (16usize, 6usize, 300usize, vec![3u32, 7, 0, 5, 2, 6]),
            (1, 5, 33, vec![1, 4, 2, 0, 3]),
            (4, 3, 8, vec![2, 0, 1]),
            // Duplicate slot: two identical negative draws in one window.
            (5, 6, 31, vec![0, 4, 4, 2, 1, 3]),
        ] {
            let u = 8usize; // rows in the wo/dwo blocks
            let mut rng = Xoshiro256ss::new(0xF05E + b as u64);
            let wi = randv(b * d, rng.next_u64());
            let wo = randv(u * d, rng.next_u64());
            let lr = 0.025f32;
            let mut err = vec![0.0f32; b * s];
            let mut dwi = randv(b * d, 1); // garbage: must be overwritten
            let mut dwo = randv(u * d, 2);
            let dwo0 = dwo.clone(); // accumulation baseline
            sgns_fused(s, d, lr, &wi, &wo, &slots, &mut err, &mut dwi, &mut dwo);

            let mut want_dwi = vec![0.0f32; b * d];
            let mut want_dwo = dwo0;
            for i in 0..b {
                for (j, &slot) in slots.iter().enumerate() {
                    let r = slot as usize * d;
                    let x: f32 = (0..d)
                        .map(|l| wi[i * d + l] * wo[r + l])
                        .sum();
                    let label = if j == 0 { 1.0 } else { 0.0 };
                    let e = (label - sigmoid_exact(x)) * lr;
                    for l in 0..d {
                        want_dwi[i * d + l] += e * wo[r + l];
                        want_dwo[r + l] += e * wi[i * d + l];
                    }
                }
            }
            for (idx, (g, w)) in dwi.iter().zip(&want_dwi).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "dwi (b={b},s={s},d={d}) idx {idx}: {g} vs {w}"
                );
            }
            for (idx, (g, w)) in dwo.iter().zip(&want_dwo).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "dwo (b={b},s={s},d={d}) idx {idx}: {g} vs {w}"
                );
            }
        }
    }

    /// The int8 dot is integer arithmetic: whatever level dispatches,
    /// the answer must EQUAL the scalar reference — not approximate it.
    #[test]
    fn dot_i8_levels_agree_exactly() {
        let mut rng = Xoshiro256ss::new(0x18_D07);
        for n in [0usize, 1, 7, 15, 16, 17, 31, 48, 127, 300, 1024] {
            let a: Vec<i8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8 as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8 as i8).collect();
            let want = scalar::dot_i8(&a, &b);
            assert_eq!(dot_i8(&a, &b), want, "n={n}");
            // And scalar matches the obvious definition.
            let naive: i32 = a.iter().zip(&b).map(|(x, y)| *x as i32 * *y as i32).sum();
            assert_eq!(want, naive, "n={n}");
        }
        // Extremes: every code at ±127 at the dispatcher's length cap.
        let a = vec![127i8; 1 << 17];
        let b = vec![-127i8; 1 << 17];
        assert_eq!(dot_i8(&a, &b), -(127i32 * 127) * (1 << 17));
    }

    /// Whatever level is currently dispatched, the fused err kernel must
    /// agree with the exact definition.
    #[test]
    fn sgns_err_matches_definition() {
        let (b, s) = (16usize, 6usize);
        let logits = randv(b * s, 9);
        let lr = 0.025f32;
        let mut got = logits.clone();
        sgns_err(&mut got, s, lr);
        for i in 0..b {
            for j in 0..s {
                let label = if j == 0 { 1.0 } else { 0.0 };
                let want = (label - sigmoid_exact(logits[i * s + j])) * lr;
                let g = got[i * s + j];
                assert!(
                    (g - want).abs() < 1e-6,
                    "({i},{j}): {g} vs {want}"
                );
            }
        }
    }
}
