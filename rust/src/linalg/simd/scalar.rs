//! Portable-scalar dispatch targets: thin delegations to the original
//! autovectorised kernels in `linalg::vecops` / `linalg::gemm`, plus the
//! reference forms of the fused SGNS error kernel and the fused
//! single-pass SGNS window kernel.
//!
//! These are deliberately the SAME functions the crate used before the
//! explicit-SIMD layer existed, so `--simd scalar` reproduces pre-SIMD
//! results bit for bit (asserted in `simd::tests` and `tests/props.rs`).

use crate::linalg::sigmoid::sigmoid_exact;

pub use crate::linalg::gemm::{gemm_nn, gemm_nt, gemm_tn};
pub use crate::linalg::vecops::{axpy, dot};

/// Integer dot `<a, b>` over int8 quantized codes, i32 accumulation.
/// Pure integer arithmetic — no rounding, no reassociation drift — so
/// every dispatch level returns the identical value (asserted in
/// `simd::tests::dot_i8_levels_agree_exactly`).
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let mut s = 0i32;
    for (x, y) in a.iter().zip(b) {
        s += *x as i32 * *y as i32;
    }
    s
}

/// `logits[r, j] <- (label(j) − σ(logits[r, j])) · lr`, exact sigmoid.
/// Column 0 of each `s`-wide row is the positive target (label 1).
pub fn sgns_err(logits: &mut [f32], s: usize, lr: f32) {
    for (idx, x) in logits.iter_mut().enumerate() {
        let label = if idx % s == 0 { 1.0 } else { 0.0 };
        *x = (label - sigmoid_exact(*x)) * lr;
    }
}

/// Fused single-pass SGNS window kernel, portable reference form.
///
/// For the `b = wi.len() / d` input rows against the `s` output rows
/// selected by `slots` (row indices into `wo`/`dwo`; `slots[0]` is the
/// positive target), one call computes what the gemm3 chain spreads over
/// `gemm_nt → sgns_err → gemm_nn → gemm_tn`:
///
/// ```text
/// err[i,j]       = (label(j) − σ(<wi_i, wo[slots_j]>)) · lr
/// dwi[i]         = Σ_j err[i,j] · wo[slots_j]     (overwritten)
/// dwo[slots_j]  += Σ_i err[i,j] · wi_i            (accumulated)
/// ```
///
/// `err` is caller scratch of at least `b·s` — the logits tile lives in
/// L1 for the duration of one window instead of round-tripping between
/// separate kernel calls.  Duplicate slots are legal (two identical
/// negative draws in one window): the sequential axpy accumulation below
/// is the reference semantics the AVX2 fast path must preserve.
#[allow(clippy::too_many_arguments)]
pub fn sgns_fused(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    let b = wi.len() / d;
    // Pass 1: logits tile.
    for i in 0..b {
        let wi_row = &wi[i * d..(i + 1) * d];
        for (j, &slot) in slots.iter().enumerate() {
            let r = slot as usize * d;
            err[i * s + j] = dot(wi_row, &wo[r..r + d]);
        }
    }
    sgns_err(&mut err[..b * s], s, lr);
    // Pass 2: both gradient accumulations from the same err tile.
    for i in 0..b {
        let wi_row = &wi[i * d..(i + 1) * d];
        dwi[i * d..(i + 1) * d].fill(0.0);
        for (j, &slot) in slots.iter().enumerate() {
            let e = err[i * s + j];
            let r = slot as usize * d;
            axpy(e, &wo[r..r + d], &mut dwi[i * d..(i + 1) * d]);
            axpy(e, wi_row, &mut dwo[r..r + d]);
        }
    }
}

/// Fused kernel over a run of consecutive windows sharing one negative
/// set, portable reference form — and THE bitwise ground truth for the
/// vector run kernels: a run is DEFINED as `offs.len() - 1` consecutive
/// [`sgns_fused`] calls over per-window slices.  `offs` holds CSR-style
/// row offsets into `wi`/`dwi` (window `w` owns rows
/// `offs[w]..offs[w+1]`), `slots` is `s` entries per window
/// (window-major), and `err` is global-row-major scratch of at least
/// `rows·s`.  The register-resident reuse in the vector twins must
/// reproduce this loop bit for bit — an f32 store/reload round-trip is
/// exact, so keeping a row live across windows changes nothing as long
/// as the per-location operation order is preserved.
#[allow(clippy::too_many_arguments)]
pub fn sgns_fused_run(
    s: usize,
    d: usize,
    lr: f32,
    wi: &[f32],
    offs: &[u32],
    wo: &[f32],
    slots: &[u32],
    err: &mut [f32],
    dwi: &mut [f32],
    dwo: &mut [f32],
) {
    for w in 0..offs.len() - 1 {
        let (lo, hi) = (offs[w] as usize, offs[w + 1] as usize);
        sgns_fused(
            s,
            d,
            lr,
            &wi[lo * d..hi * d],
            wo,
            &slots[w * s..(w + 1) * s],
            &mut err[lo * s..hi * s],
            &mut dwi[lo * d..hi * d],
            dwo,
        );
    }
}
