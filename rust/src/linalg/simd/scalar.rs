//! Portable-scalar dispatch targets: thin delegations to the original
//! autovectorised kernels in `linalg::vecops` / `linalg::gemm`, plus the
//! reference form of the fused SGNS error kernel.
//!
//! These are deliberately the SAME functions the crate used before the
//! explicit-SIMD layer existed, so `--simd scalar` reproduces pre-SIMD
//! results bit for bit (asserted in `simd::tests` and `tests/props.rs`).

use crate::linalg::sigmoid::sigmoid_exact;

pub use crate::linalg::gemm::{gemm_nn, gemm_nt, gemm_tn};
pub use crate::linalg::vecops::{axpy, dot};

/// `logits[r, j] <- (label(j) − σ(logits[r, j])) · lr`, exact sigmoid.
/// Column 0 of each `s`-wide row is the positive target (label 1).
pub fn sgns_err(logits: &mut [f32], s: usize, lr: f32) {
    for (idx, x) in logits.iter_mut().enumerate() {
        let label = if idx % s == 0 { 1.0 } else { 0.0 };
        *x = (label - sigmoid_exact(*x)) * lr;
    }
}
