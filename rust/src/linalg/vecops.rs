//! Level-1 BLAS primitives: the operations Algorithm 1 is made of.
//!
//! These are written with fixed-width chunking so LLVM autovectorises them
//! (verified in the perf pass — see EXPERIMENTS.md §Perf); they are the
//! fair "original word2vec" baseline, not a strawman.

/// Dot product `<a, b>`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let (ac, ar) = a.split_at(a.len() - a.len() % 8);
    let (bc, br) = b.split_at(ac.len());
    for (ca, cb) in ac.chunks_exact(8).zip(bc.chunks_exact(8)) {
        for i in 0..8 {
            acc[i] += ca[i] * cb[i];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for (x, y) in ar.iter().zip(br) {
        s += x * y;
    }
    s
}

/// `y += alpha * x` (the model-update primitive of Algorithm 1).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n8 = x.len() - x.len() % 8;
    let (xc, xr) = x.split_at(n8);
    let (yc, yr) = y.split_at_mut(n8);
    for (cx, cy) in xc.chunks_exact(8).zip(yc.chunks_exact_mut(8)) {
        for i in 0..8 {
            cy[i] += alpha * cx[i];
        }
    }
    for (x, y) in xr.iter().zip(yr) {
        *y += alpha * x;
    }
}

/// `y = a*x + b*y` elementwise (used by AdaGrad/RMSProp accumulators).
#[inline]
pub fn scale_add(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi = a * xi + b * *yi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        // Cover remainder handling: lengths around the chunk width.
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 300] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.91).cos()).collect();
            let got = dot(&a, &b);
            let want = naive_dot(&a, &b);
            assert!((got - want).abs() < 1e-4, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [1usize, 7, 8, 13, 300] {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut y: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5).collect();
            let mut want = y.clone();
            axpy(0.25, &x, &mut y);
            for (w, xi) in want.iter_mut().zip(&x) {
                *w += 0.25 * xi;
            }
            assert_eq!(y, want, "n={n}");
        }
    }

    #[test]
    fn scale_add_basic() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        scale_add(2.0, &x, 0.5, &mut y);
        assert_eq!(y, [7.0, 14.0]);
    }
}
