//! Logistic function: exact, and the original word2vec's precomputed
//! `EXP_TABLE` (1000 entries over [-6, 6], saturating outside), used by
//! the scalar baseline.  The table matches the C code's resolution and
//! saturation behaviour, but the in-range lookup deliberately diverges:
//! it rounds to the nearest bin where the C original truncates (see
//! [`SigmoidTable::get`] for the bias this removes).

/// Exact numerically-stable sigmoid.
#[inline]
pub fn sigmoid_exact(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// word2vec's EXP_TABLE: `table[i] = sigma((i/SIZE*2 - 1) * MAX_EXP)`.
pub struct SigmoidTable {
    table: Vec<f32>,
    max_exp: f32,
}

impl SigmoidTable {
    pub const DEFAULT_SIZE: usize = 1000;
    pub const DEFAULT_MAX_EXP: f32 = 6.0;

    pub fn new(size: usize, max_exp: f32) -> Self {
        let mut table = Vec::with_capacity(size);
        for i in 0..size {
            // exp table as in word2vec: exp((i / size * 2 - 1) * MAX_EXP)
            let e = ((i as f32 / size as f32 * 2.0 - 1.0) * max_exp).exp();
            table.push(e / (e + 1.0));
        }
        Self { table, max_exp }
    }

    pub fn default_table() -> Self {
        Self::new(Self::DEFAULT_SIZE, Self::DEFAULT_MAX_EXP)
    }

    /// Lookup with the original's saturation: returns 1 for x >= MAX_EXP,
    /// 0 for x <= -MAX_EXP.  (The C code *skips* the update in the
    /// saturated region for the positive/negative label logic; callers
    /// replicate that where needed.)
    ///
    /// Unlike the C original, the in-range lookup rounds to the NEAREST
    /// bin instead of truncating.  Truncation always selects the bin
    /// below `x`, a systematic downward shift of up to one full bin
    /// (≈0.003 in σ at the default resolution) that biases every gradient
    /// in the same direction; rounding halves the worst-case error and
    /// centres it at zero (asserted by `rounding_beats_truncation`).
    #[inline]
    pub fn get(&self, x: f32) -> f32 {
        if x >= self.max_exp {
            1.0
        } else if x <= -self.max_exp {
            0.0
        } else {
            let t = (x + self.max_exp)
                * (self.table.len() as f32 / self.max_exp / 2.0);
            let idx = (t + 0.5) as usize;
            self.table[idx.min(self.table.len() - 1)]
        }
    }

    /// The saturation bound MAX_EXP.
    #[inline]
    pub fn max(&self) -> f32 {
        self.max_exp
    }

    /// Whether the original code would skip this activation entirely
    /// (|x| > MAX_EXP ⇒ gradient treated as 0 or ±1 clamp).
    #[inline]
    pub fn saturated(&self, x: f32) -> bool {
        x.abs() >= self.max_exp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_matches_definition() {
        for &x in &[-30.0f32, -6.0, -1.0, 0.0, 0.5, 6.0, 30.0] {
            let want = 1.0 / (1.0 + (-x as f64).exp());
            assert!((sigmoid_exact(x) as f64 - want).abs() < 1e-6, "x={x}");
        }
    }

    #[test]
    fn table_close_to_exact_in_range() {
        let t = SigmoidTable::default_table();
        for i in -59..=59 {
            let x = i as f32 * 0.1;
            let err = (t.get(x) - sigmoid_exact(x)).abs();
            assert!(err < 0.01, "x={x} err={err}");
        }
    }

    #[test]
    fn table_saturates() {
        let t = SigmoidTable::default_table();
        assert_eq!(t.get(6.0), 1.0);
        assert_eq!(t.get(100.0), 1.0);
        assert_eq!(t.get(-6.0), 0.0);
        assert_eq!(t.get(-100.0), 0.0);
        assert!(t.saturated(6.5));
        assert!(!t.saturated(5.9));
    }

    /// Round-to-nearest lookup: error vs the exact sigmoid is bounded by
    /// half a bin's worth of σ-variation and is UNBIASED, where the C
    /// original's truncating lookup erred low on essentially every point.
    #[test]
    fn rounding_beats_truncation() {
        let t = SigmoidTable::default_table();
        // Bin width in x is 2*MAX_EXP/SIZE = 0.012; max |σ'| = 1/4, so the
        // nearest-bin error is ≤ 0.012/2 * 0.25 + interpolation slack.
        let mut sum_err = 0.0f64;
        let mut max_err = 0.0f32;
        let mut n = 0u32;
        let mut x = -5.9f32;
        while x < 5.9 {
            let err = t.get(x) - sigmoid_exact(x);
            sum_err += err as f64;
            max_err = max_err.max(err.abs());
            n += 1;
            x += 0.000_7; // incommensurate with the bin width
        }
        let bias = sum_err / n as f64;
        assert!(max_err < 2.0e-3, "max err {max_err}");
        assert!(bias.abs() < 2.0e-4, "lookup bias {bias}");
    }

    #[test]
    fn table_monotone() {
        let t = SigmoidTable::default_table();
        let mut prev = -1.0f32;
        for i in -600..=600 {
            let v = t.get(i as f32 * 0.01);
            assert!(v >= prev - 1e-6);
            prev = v;
        }
    }
}
