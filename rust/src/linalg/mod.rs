//! Level-1/2/3 BLAS substrate (MKL substitute; DESIGN.md §3).
//!
//! The paper's entire argument is a contrast between BLAS levels:
//! level-1 dot/axpy (original word2vec), level-2 matrix–vector (BIDMach's
//! organisation), and level-3 GEMM (the paper's scheme).  Each trainer
//! back-end in `crate::train` uses exactly the primitives of its level, so
//! the measured contrast mirrors the paper's.
//!
//! The GEMM trainer's hot path goes through [`simd`], which dispatches at
//! runtime between explicit AVX2+FMA kernels and these portable ones
//! (`--simd {auto,avx2,scalar}`); the portable kernels remain the
//! reference semantics and the fair scalar baseline.

pub mod gemm;
pub mod sigmoid;
pub mod simd;
pub mod vecops;

pub use gemm::{gemm_nn, gemm_nt, gemm_tn};
pub use sigmoid::{sigmoid_exact, SigmoidTable};
pub use simd::{SimdLevel, SimdMode};
pub use vecops::{axpy, dot, scale_add};
