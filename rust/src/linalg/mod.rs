//! Level-1/2/3 BLAS substrate (MKL substitute; DESIGN.md §3).
//!
//! The paper's entire argument is a contrast between BLAS levels:
//! level-1 dot/axpy (original word2vec), level-2 matrix–vector (BIDMach's
//! organisation), and level-3 GEMM (the paper's scheme).  Each trainer
//! back-end in `crate::train` uses exactly the primitives of its level, so
//! the measured contrast mirrors the paper's.

pub mod gemm;
pub mod sigmoid;
pub mod vecops;

pub use gemm::{gemm_nn, gemm_nt, gemm_tn};
pub use sigmoid::{sigmoid_exact, SigmoidTable};
pub use vecops::{axpy, dot, scale_add};
