//! Level-3 BLAS: small-matrix f32 GEMM kernels (MKL-SGEMM substitute).
//!
//! The paper's scheme needs exactly three GEMM shapes per window
//! (Fig. 2 right; B≈16, S=1+K≈6, D≈300):
//!
//!   1. `logits[B,S] = Wi[B,D] · Wo[S,D]ᵀ`   — [`gemm_nt`]
//!   2. `dWi[B,D]    = Err[B,S] · Wo[S,D]`   — [`gemm_nn`]
//!   3. `dWo[S,D]    = Err[B,S]ᵀ · Wi[B,D]`  — [`gemm_tn`]
//!
//! All three are organised so the *innermost* loop runs contiguously over
//! the long `D` axis (the embedding dimension) and autovectorises; the
//! small `B`/`S` axes are the outer loops.  This is the same reuse
//! structure MKL gives the paper: `Wo` is loaded once per window and used
//! across the whole input batch — the locality win over level-1 updates.

use super::vecops::{axpy, dot};

/// `c[m,n] = alpha * a[m,k] · b[n,k]ᵀ + beta * c`  (rows-dot-rows).
pub fn gemm_nt(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &b[j * k..(j + 1) * k];
            let d = dot(ar, br);
            crow[j] = alpha * d + beta * crow[j];
        }
    }
}

/// `c[m,n] = alpha * a[m,k] · b[k,n] + beta * c`.
///
/// Single-pass register accumulation: each output row is produced in ONE
/// sweep over the contiguous `n` axis, accumulating all `k` contributions
/// in registers (the axpy-per-`l` formulation re-reads and re-writes the
/// output row `k` times and measured ~6× slower at the paper's shapes —
/// see EXPERIMENTS.md §Perf).
pub fn gemm_nn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let coeff = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        accumulate_rows(n, k, alpha, coeff, 1, b, beta, crow);
    }
}

/// `c[m,n] = alpha * a[k,m]ᵀ · b[k,n] + beta * c`.
///
/// Same single-pass structure as [`gemm_nn`]; the coefficient for output
/// row `j` is the strided column `a[:, j]`.
pub fn gemm_tn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for j in 0..m {
        let crow = &mut c[j * n..(j + 1) * n];
        accumulate_rows(n, k, alpha, &a[j..], m, b, beta, crow);
    }
}

/// `crow = alpha * Σ_l coeff[l*stride] · b[l, :] + beta * crow`, one sweep
/// over `n` with the `k` partial products held in registers.  `k` is
/// blocked by 4 so the compiler keeps 4 row pointers + 4 coefficients
/// live and fuses the multiply-adds.
#[inline]
fn accumulate_rows(
    n: usize,
    k: usize,
    alpha: f32,
    coeff: &[f32],
    stride: usize,
    b: &[f32],
    beta: f32,
    crow: &mut [f32],
) {
    if beta == 0.0 {
        crow.fill(0.0);
    } else if beta != 1.0 {
        for x in crow.iter_mut() {
            *x *= beta;
        }
    }
    let mut l = 0;
    // Blocks of 4 source rows.
    while l + 4 <= k {
        let (c0, c1, c2, c3) = (
            alpha * coeff[l * stride],
            alpha * coeff[(l + 1) * stride],
            alpha * coeff[(l + 2) * stride],
            alpha * coeff[(l + 3) * stride],
        );
        let b0 = &b[l * n..(l + 1) * n];
        let b1 = &b[(l + 1) * n..(l + 2) * n];
        let b2 = &b[(l + 2) * n..(l + 3) * n];
        let b3 = &b[(l + 3) * n..(l + 4) * n];
        for j in 0..n {
            crow[j] += c0 * b0[j] + c1 * b1[j] + c2 * b2[j] + c3 * b3[j];
        }
        l += 4;
    }
    // Remainder pair (k = 4q+2/4q+3 is the common SGNS case: S = 6).
    if l + 2 <= k {
        let (c0, c1) = (alpha * coeff[l * stride], alpha * coeff[(l + 1) * stride]);
        let b0 = &b[l * n..(l + 1) * n];
        let b1 = &b[(l + 1) * n..(l + 2) * n];
        for j in 0..n {
            crow[j] += c0 * b0[j] + c1 * b1[j];
        }
        l += 2;
    }
    if l < k {
        let cl = alpha * coeff[l * stride];
        if cl != 0.0 {
            axpy(cl, &b[l * n..(l + 1) * n], crow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256ss;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256ss::new(seed);
        (0..n).map(|_| r.next_f32() - 0.5).collect()
    }

    fn naive_nt(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for l in 0..k {
                    c[i * n + j] += a[i * k + l] * b[j * k + l];
                }
            }
        }
        c
    }

    fn naive_nn(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for l in 0..k {
                    c[i * n + j] += a[i * k + l] * b[l * n + j];
                }
            }
        }
        c
    }

    fn naive_tn(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for l in 0..k {
                    c[i * n + j] += a[l * m + i] * b[l * n + j];
                }
            }
        }
        c
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-3, "idx {i}: {x} vs {y}");
        }
    }

    // Shapes including the paper's (16, 6, 300) and awkward remainders.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (16, 6, 300),
        (6, 16, 300),
        (7, 9, 13),
        (16, 6, 7),
        (1, 6, 300),
    ];

    #[test]
    fn nt_matches_naive() {
        for &(m, n, k) in SHAPES {
            let a = randv(m * k, 1);
            let b = randv(n * k, 2);
            let mut c = vec![0.0; m * n];
            gemm_nt(m, n, k, 1.0, &a, &b, 0.0, &mut c);
            close(&c, &naive_nt(m, n, k, &a, &b));
        }
    }

    #[test]
    fn nn_matches_naive() {
        for &(m, n, k) in SHAPES {
            let a = randv(m * k, 3);
            let b = randv(k * n, 4);
            let mut c = vec![0.0; m * n];
            gemm_nn(m, n, k, 1.0, &a, &b, 0.0, &mut c);
            close(&c, &naive_nn(m, n, k, &a, &b));
        }
    }

    #[test]
    fn tn_matches_naive() {
        for &(m, n, k) in SHAPES {
            let a = randv(k * m, 5);
            let b = randv(k * n, 6);
            let mut c = vec![0.0; m * n];
            gemm_tn(m, n, k, 1.0, &a, &b, 0.0, &mut c);
            close(&c, &naive_tn(m, n, k, &a, &b));
        }
    }

    #[test]
    fn alpha_beta_semantics() {
        let (m, n, k) = (4, 3, 8);
        let a = randv(m * k, 7);
        let b = randv(n * k, 8);
        let c0 = randv(m * n, 9);

        let mut c = c0.clone();
        gemm_nt(m, n, k, 2.0, &a, &b, 0.5, &mut c);
        let plain = naive_nt(m, n, k, &a, &b);
        for i in 0..m * n {
            let want = 2.0 * plain[i] + 0.5 * c0[i];
            assert!((c[i] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn sgns_gemm_chain_consistency() {
        // The three GEMMs chained as the trainer uses them must equal the
        // direct per-pair computation (mirrors the python oracle).
        let (bsz, s, d) = (8, 6, 32);
        let wi = randv(bsz * d, 10);
        let wo = randv(s * d, 11);
        let lr = 0.025f32;

        let mut logits = vec![0.0; bsz * s];
        gemm_nt(bsz, s, d, 1.0, &wi, &wo, 0.0, &mut logits);
        let mut err = vec![0.0; bsz * s];
        for i in 0..bsz {
            for j in 0..s {
                let label = if j == 0 { 1.0 } else { 0.0 };
                let sig = 1.0 / (1.0 + (-logits[i * s + j]).exp());
                err[i * s + j] = (label - sig) * lr;
            }
        }
        let mut dwi = vec![0.0; bsz * d];
        gemm_nn(bsz, d, s, 1.0, &err, &wo, 0.0, &mut dwi);
        let mut dwo = vec![0.0; s * d];
        gemm_tn(s, d, bsz, 1.0, &err, &wi, 0.0, &mut dwo);

        // Naive per-pair accumulation (Algorithm 1 with end-of-batch updates).
        let mut ndwi = vec![0.0f32; bsz * d];
        let mut ndwo = vec![0.0f32; s * d];
        for i in 0..bsz {
            for j in 0..s {
                let mut inn = 0.0;
                for l in 0..d {
                    inn += wi[i * d + l] * wo[j * d + l];
                }
                let label = if j == 0 { 1.0 } else { 0.0 };
                let g = (label - 1.0 / (1.0 + (-inn).exp())) * lr;
                for l in 0..d {
                    ndwi[i * d + l] += g * wo[j * d + l];
                    ndwo[j * d + l] += g * wi[i * d + l];
                }
            }
        }
        close(&dwi, &ndwi);
        close(&dwo, &ndwo);
    }
}
