//! Learning-rate schedules (paper Sec. III-E).
//!
//! * [`LrState`] — the original word2vec linear decay, plus the paper's
//!   distributed scaling trick: raise the starting rate and sharpen the
//!   decay as the node count N grows (their low-overhead alternative to
//!   per-parameter methods).
//! * [`AdaGrad`] / [`RmsProp`] — the per-parameter schedules the paper
//!   evaluated and REJECTED for doubling model memory and going
//!   memory-bandwidth-bound; implemented for the ablation bench
//!   (`benches/ablations.rs`) so the rejection is measured, not asserted.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::Embedding;

/// Global progress-driven learning rate, shared across worker threads.
pub struct LrState {
    start: f32,
    min: f32,
    /// Sharpness multiplier on the progress term (1.0 = original).
    decay_mult: f32,
    /// Total words the run will process (epochs × corpus words).
    /// Atomic because a STREAMING run's horizon grows while workers
    /// read it ([`extend_total`](Self::extend_total)); batch runs store
    /// it once and the schedule is bit-for-bit the plain-field version.
    total: AtomicU64,
    words_done: AtomicU64,
}

impl LrState {
    /// The original schedule: `lr = start * max(1 - p, min_frac)` with
    /// `p = words_done / total`.
    pub fn linear(start: f32, min_frac: f32, total: u64) -> Self {
        Self {
            start,
            min: start * min_frac,
            decay_mult: 1.0,
            total: AtomicU64::new(total.max(1)),
            words_done: AtomicU64::new(0),
        }
    }

    /// The paper's distributed trick, following Splash's m-weighted
    /// scheme: the starting rate scales LINEARLY with the node count
    /// (each synchronous round averages N contributions, so the combined
    /// step needs N× weight), and because each node's schedule spans only
    /// corpus/N words, the rate also decays N× faster in global-word
    /// terms — the paper's "reduce the learning rate more aggressively as
    /// number of nodes increases".  Validated end-to-end by the Table IV
    /// bench: N-node accuracy tracks single-node.
    pub fn dist_scaled(start: f32, min_frac: f32, total: u64, nodes: usize) -> Self {
        let n = nodes.max(1) as f32;
        let start = start * n;
        Self {
            start,
            min: start * min_frac,
            decay_mult: 1.0,
            total: AtomicU64::new(total.max(1)),
            words_done: AtomicU64::new(0),
        }
    }

    /// Record progress and return the current rate.
    pub fn advance(&self, words: u64) -> f32 {
        let done = self.words_done.fetch_add(words, Ordering::Relaxed) + words;
        self.at(done)
    }

    /// Rate at an absolute progress point.
    pub fn at(&self, words_done: u64) -> f32 {
        let p = words_done as f32 / self.total.load(Ordering::Relaxed) as f32;
        (self.start * (1.0 - p * self.decay_mult)).max(self.min)
    }

    /// Total words the schedule currently spans.
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Grow the schedule horizon by `more` words (streaming ingest: the
    /// corpus grew, so the linear decay now spans the longer run).  The
    /// already-consumed progress is unchanged — the rate simply decays
    /// more slowly from here on, which is the standard online treatment
    /// of an open-ended corpus.
    pub fn extend_total(&self, more: u64) {
        self.total.fetch_add(more, Ordering::Relaxed);
    }

    /// Pin the horizon to an absolute value (stream checkpoint resume).
    pub fn restore_total(&self, total: u64) {
        self.total.store(total.max(1), Ordering::Relaxed);
    }

    pub fn current(&self) -> f32 {
        self.at(self.words_done.load(Ordering::Relaxed))
    }

    /// Words recorded so far (checkpoint header payload).
    pub fn words_done(&self) -> u64 {
        self.words_done.load(Ordering::Relaxed)
    }

    /// Reset progress to an absolute point (checkpoint resume): the next
    /// [`advance`](Self::advance) continues the schedule exactly where a
    /// checkpointed run left it.
    pub fn restore(&self, words: u64) {
        self.words_done.store(words, Ordering::Relaxed);
    }

    pub fn start(&self) -> f32 {
        self.start
    }
}

/// AdaGrad over the two model matrices.  `adjust` rescales a raw gradient
/// for one row element; accumulators are updated racily (Hogwild), which
/// matches how such schemes are bolted onto word2vec in practice.
pub struct AdaGrad {
    acc_in: Embedding,
    acc_out: Embedding,
    eps: f32,
}

// SAFETY: racy accumulator updates are part of the Hogwild contract, as
// with the model matrices themselves (see model::hogwild docs).
unsafe impl Sync for AdaGrad {}

impl AdaGrad {
    pub fn new(vocab: usize, dim: usize) -> Self {
        Self {
            acc_in: Embedding::zeros(vocab, dim),
            acc_out: Embedding::zeros(vocab, dim),
            eps: 1e-6,
        }
    }

    /// Rescale a gradient delta for `M_in[row]` in place.
    pub fn adjust_in(&self, row: u32, delta: &mut [f32]) {
        // SAFETY: Hogwild contract.
        let acc = unsafe { racy_row(&self.acc_in, row) };
        for (d, a) in delta.iter_mut().zip(acc.iter_mut()) {
            *a += *d * *d;
            *d /= a.sqrt() + self.eps;
        }
    }

    pub fn adjust_out(&self, row: u32, delta: &mut [f32]) {
        // SAFETY: Hogwild contract.
        let acc = unsafe { racy_row(&self.acc_out, row) };
        for (d, a) in delta.iter_mut().zip(acc.iter_mut()) {
            *a += *d * *d;
            *d /= a.sqrt() + self.eps;
        }
    }

    /// Extra model memory this schedule costs (the paper's objection).
    pub fn memory_bytes(&self) -> usize {
        (self.acc_in.vocab() * self.acc_in.stride()
            + self.acc_out.vocab() * self.acc_out.stride())
            * std::mem::size_of::<f32>()
    }
}

/// RMSProp accumulator (decaying mean square), same interface as AdaGrad.
pub struct RmsProp {
    acc_in: Embedding,
    acc_out: Embedding,
    rho: f32,
    eps: f32,
}

// SAFETY: see AdaGrad.
unsafe impl Sync for RmsProp {}

impl RmsProp {
    pub fn new(vocab: usize, dim: usize, rho: f32) -> Self {
        Self {
            acc_in: Embedding::zeros(vocab, dim),
            acc_out: Embedding::zeros(vocab, dim),
            rho,
            eps: 1e-6,
        }
    }

    pub fn adjust_in(&self, row: u32, delta: &mut [f32]) {
        // SAFETY: Hogwild contract.
        let acc = unsafe { racy_row(&self.acc_in, row) };
        for (d, a) in delta.iter_mut().zip(acc.iter_mut()) {
            *a = self.rho * *a + (1.0 - self.rho) * *d * *d;
            *d /= a.sqrt() + self.eps;
        }
    }

    pub fn adjust_out(&self, row: u32, delta: &mut [f32]) {
        // SAFETY: Hogwild contract.
        let acc = unsafe { racy_row(&self.acc_out, row) };
        for (d, a) in delta.iter_mut().zip(acc.iter_mut()) {
            *a = self.rho * *a + (1.0 - self.rho) * *d * *d;
            *d /= a.sqrt() + self.eps;
        }
    }

    pub fn memory_bytes(&self) -> usize {
        (self.acc_in.vocab() * self.acc_in.stride()
            + self.acc_out.vocab() * self.acc_out.stride())
            * std::mem::size_of::<f32>()
    }
}

/// Racy mutable row view (same pattern as `SharedModel`).
///
/// # Safety
/// Hogwild contract: allocation outlives workers; races are admitted.
unsafe fn racy_row(e: &Embedding, row: u32) -> &mut [f32] {
    let o = row as usize * e.stride();
    std::slice::from_raw_parts_mut((e.data().as_ptr() as *mut f32).add(o), e.dim())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_decays_to_floor() {
        let lr = LrState::linear(0.025, 1e-4, 1000);
        assert!((lr.at(0) - 0.025).abs() < 1e-7);
        assert!(lr.at(500) < 0.025 * 0.51);
        assert!((lr.at(1000) - 0.025 * 1e-4).abs() < 1e-7);
        assert!((lr.at(10_000) - 0.025 * 1e-4).abs() < 1e-7); // clamped
    }

    #[test]
    fn advance_is_cumulative() {
        let lr = LrState::linear(0.1, 0.0, 100);
        lr.advance(50);
        assert!((lr.current() - 0.05).abs() < 1e-6);
        lr.advance(25);
        assert!((lr.current() - 0.025).abs() < 1e-6);
    }

    #[test]
    fn extend_total_flattens_future_decay_only() {
        let lr = LrState::linear(0.1, 0.0, 100);
        lr.advance(50);
        let before = lr.current();
        lr.extend_total(100); // horizon now 200; progress unchanged
        assert_eq!(lr.total(), 200);
        assert!(lr.current() > before, "same words over a longer run");
        assert!((lr.current() - 0.1 * 0.75).abs() < 1e-6);
        let pinned = LrState::linear(0.1, 0.0, 123);
        pinned.restore_total(200);
        pinned.restore(lr.words_done());
        assert!((pinned.current() - lr.current()).abs() < 1e-9);
    }

    #[test]
    fn restore_resumes_schedule() {
        let a = LrState::linear(0.1, 0.0, 100);
        a.advance(30);
        a.advance(20);
        let b = LrState::linear(0.1, 0.0, 100);
        b.restore(a.words_done());
        assert_eq!(b.words_done(), 50);
        assert!((a.current() - b.current()).abs() < 1e-9);
        assert!((a.advance(10) - b.advance(10)).abs() < 1e-9);
    }

    #[test]
    fn dist_scaling_is_m_weighted() {
        let lr1 = LrState::dist_scaled(0.025, 0.0, 1000, 1);
        let lr16 = LrState::dist_scaled(0.025, 0.0, 1000, 16);
        // Linear (m-weighted) start scaling.
        assert!((lr16.start() - 16.0 * lr1.start()).abs() < 1e-6);
        // Absolute decay per word is 16× steeper.
        let slope1 = lr1.start() - lr1.at(500);
        let slope16 = lr16.start() - lr16.at(500);
        assert!((slope16 - 16.0 * slope1).abs() < 1e-4);
    }

    #[test]
    fn adagrad_shrinks_repeated_updates() {
        let ag = AdaGrad::new(4, 8);
        let mut d1 = vec![0.1f32; 8];
        ag.adjust_in(0, &mut d1);
        let mut d2 = vec![0.1f32; 8];
        ag.adjust_in(0, &mut d2);
        // Second update on the same row must be smaller.
        assert!(d2[0].abs() < d1[0].abs());
        // Different row unaffected.
        let mut d3 = vec![0.1f32; 8];
        ag.adjust_in(1, &mut d3);
        assert!((d3[0] - d1[0]).abs() < 1e-6);
    }

    #[test]
    fn rmsprop_adapts_but_forgets() {
        let rp = RmsProp::new(2, 4, 0.9);
        let mut sizes = Vec::new();
        for _ in 0..50 {
            let mut d = vec![0.1f32; 4];
            rp.adjust_out(0, &mut d);
            sizes.push(d[0]);
        }
        // Converges to a fixed point instead of shrinking to zero
        // (unlike AdaGrad): last two adjustments nearly equal.
        let n = sizes.len();
        assert!((sizes[n - 1] - sizes[n - 2]).abs() < 1e-3);
        assert!(sizes[n - 1] > 0.05); // not vanishing
    }

    #[test]
    fn per_parameter_memory_cost_is_model_sized() {
        // The paper's objection: AdaGrad needs a second Ω worth of memory.
        let ag = AdaGrad::new(1000, 300);
        let model_bytes = 2 * 1000 * 304 * 4; // stride-padded
        assert_eq!(ag.memory_bytes(), model_bytes);
    }
}
