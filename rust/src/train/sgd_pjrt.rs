//! The paper's GEMM scheme executed through the AOT stack: the JAX/Pallas
//! superbatch step (`python/compile/`), lowered to HLO text at build time
//! and run here via the PJRT CPU client.  This is the three-layer
//! composition path: rust gathers/scatters against the Hogwild model and
//! the fused three-GEMM kernel runs inside XLA.
//!
//! Geometry is fixed per artifact `(W, B, S, D)`; windows with fewer than
//! `B` inputs are zero-padded (zero rows produce exactly zero deltas for
//! the rows they touch — see the kernel docs — and padded `dwi` rows are
//! simply not scattered).  Trailing partial superbatches pad whole windows
//! the same way.

use std::sync::Arc;

use super::Backend;
use crate::model::ModelRef;
use crate::runtime::StepExecutable;
use crate::sampling::batch::Window;

pub struct PjrtBackend {
    exe: Arc<StepExecutable>,
    /// Staging buffers, reused across calls.
    wi: Vec<f32>,
    wo: Vec<f32>,
}

impl PjrtBackend {
    pub fn new(exe: Arc<StepExecutable>) -> Self {
        let (wi_len, wo_len) = (exe.wi_len(), exe.wo_len());
        Self {
            exe,
            wi: vec![0.0; wi_len],
            wo: vec![0.0; wo_len],
        }
    }

    /// Max windows per call.
    pub fn superbatch(&self) -> usize {
        self.exe.w
    }

    fn run_chunk(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        let (w_cap, b_cap, s, d) =
            (self.exe.w, self.exe.b, self.exe.s, self.exe.d);
        anyhow::ensure!(windows.len() <= w_cap, "chunk exceeds artifact W");

        // Gather with zero padding.
        self.wi.fill(0.0);
        self.wo.fill(0.0);
        for (wdx, win) in windows.iter().enumerate() {
            anyhow::ensure!(
                win.inputs.len() <= b_cap && win.outputs.len() == s,
                "window geometry mismatch (b={} cap={b_cap}, s={} want {s})",
                win.inputs.len(),
                win.outputs.len()
            );
            for (i, &inp) in win.inputs.iter().enumerate() {
                // SAFETY: Hogwild contract (model::hogwild docs).
                let row = unsafe { model.row_in(inp) };
                let o = (wdx * b_cap + i) * d;
                self.wi[o..o + d].copy_from_slice(row);
            }
            for (j, &out) in win.outputs.iter().enumerate() {
                // SAFETY: Hogwild contract.
                let row = unsafe { model.row_out(out) };
                let o = (wdx * s + j) * d;
                self.wo[o..o + d].copy_from_slice(row);
            }
        }

        let (dwi, dwo) = self.exe.run(&self.wi, &self.wo, lr)?;

        // Scatter-add only the real rows.
        for (wdx, win) in windows.iter().enumerate() {
            for (i, &inp) in win.inputs.iter().enumerate() {
                let o = (wdx * b_cap + i) * d;
                model.add_in(inp, &dwi[o..o + d]);
            }
            for (j, &out) in win.outputs.iter().enumerate() {
                let o = (wdx * s + j) * d;
                model.add_out(out, &dwo[o..o + d]);
            }
        }
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn process(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        for chunk in windows.chunks(self.exe.w) {
            self.run_chunk(model, chunk, lr)?;
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SharedModel;
    use crate::runtime::{Manifest, Runtime};
    use crate::train::sgd_gemm::GemmBackend;

    fn test_exe() -> Option<Arc<StepExecutable>> {
        let dir =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(&dir).unwrap();
        let v = m.by_name("test_w4_b8_s6_d32").unwrap().clone();
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                // Artifacts exist but this build has no PJRT (stub
                // runtime, `pjrt` feature off): skip, don't fail.
                eprintln!("skipping: {e}");
                return None;
            }
        };
        Some(Arc::new(rt.compile_variant(&m, &v).unwrap()))
    }

    fn window(inputs: &[u32], target: u32, negs: &[u32]) -> Window {
        let mut outputs = vec![target];
        outputs.extend_from_slice(negs);
        Window {
            inputs: inputs.to_vec(),
            outputs,
        }
    }

    /// The AOT path must produce the same model as the native GEMM path —
    /// the cross-layer equivalence test for the whole stack.
    #[test]
    fn pjrt_matches_native_gemm() {
        let Some(exe) = test_exe() else { return };
        let dim = 32;
        let model_p = SharedModel::init(50, dim, 21);
        let model_g = SharedModel::init(50, dim, 21);
        // 6 windows (more than W=4 to exercise chunking), ragged batches.
        let windows = vec![
            window(&[1, 2, 3], 10, &[20, 21, 22, 23, 24]),
            window(&[4], 11, &[25, 26, 27, 28, 29]),
            window(&[5, 6, 7, 8, 9, 12, 13, 14], 15, &[30, 31, 32, 33, 34]),
            window(&[16, 17], 18, &[35, 36, 37, 38, 39]),
            window(&[19, 40], 41, &[42, 43, 44, 45, 46]),
            window(&[47], 48, &[1, 2, 3, 4, 5]),
        ];
        let mut p = PjrtBackend::new(exe);
        let mut g = GemmBackend::new(dim, 8, 6);
        p.process(model_p.store(), &windows, 0.05).unwrap();
        g.process(model_g.store(), &windows, 0.05).unwrap();

        for r in 0..50u32 {
            for (a, b) in model_p.m_in().row(r).iter().zip(model_g.m_in().row(r)) {
                assert!((a - b).abs() < 1e-4, "m_in row {r}");
            }
            for (a, b) in model_p.m_out().row(r).iter().zip(model_g.m_out().row(r)) {
                assert!((a - b).abs() < 1e-4, "m_out row {r}");
            }
        }
    }

    #[test]
    fn rejects_wrong_geometry() {
        let Some(exe) = test_exe() else { return };
        let model = SharedModel::init(50, 32, 1);
        let mut p = PjrtBackend::new(exe);
        // s=3 != artifact s=6
        let w = window(&[1], 2, &[3, 4]);
        assert!(p.process(model.store(), &[w], 0.05).is_err());
        // b=9 > artifact cap 8
        let w = window(&[1, 2, 3, 4, 5, 6, 7, 8, 9], 10, &[3, 4, 5, 6, 7]);
        assert!(p.process(model.store(), &[w], 0.05).is_err());
    }
}
