//! BIDMach-style trainer — the comparator scheme of paper Sec. III-D
//! (Canny et al., "Machine learning at the limit").
//!
//! BIDMach shares negative samples but organises the computation as TWO
//! separate passes of matrix–VECTOR products (level-2 BLAS):
//!
//! 1. positives: for each target, dot products of the context words
//!    against the single target vector, updating the model after the
//!    vector op;
//! 2. negatives: for each shared negative sample, dot products of the
//!    context words against that sample vector, again updating per
//!    vector op.
//!
//! Because computation is never batched into a GEMM, register/cache
//! blocking across the batch is impossible — the deficiency the paper
//! calls out and measures (Table III: BIDMach ≈1.6× vs ours ≈3X-4X over
//! the original).

use super::Backend;
use crate::linalg::sigmoid::sigmoid_exact;
use crate::linalg::vecops::{axpy, dot};
use crate::model::ModelRef;
use crate::sampling::batch::Window;

pub struct BidmachBackend {
    /// err per input word for the current vector pass.
    err: Vec<f32>,
    /// Output-row delta accumulated from PRE-update input rows (the
    /// standard SGD semantics for one vector op; computing it from
    /// already-updated inputs compounds the step and diverges).
    wo_delta: Vec<f32>,
}

impl BidmachBackend {
    pub fn new(batch_cap: usize) -> Self {
        Self {
            err: vec![0.0; batch_cap],
            wo_delta: Vec::new(),
        }
    }

    /// One matrix–vector pass: all inputs against a single output vector,
    /// then immediate model updates for that vector (level-2 organisation).
    #[inline]
    fn vector_pass(
        &mut self,
        model: ModelRef<'_>,
        inputs: &[u32],
        out_word: u32,
        label: f32,
        lr: f32,
    ) {
        // SAFETY: Hogwild contract (model::hogwild docs).
        let wo = unsafe { model.row_out(out_word) };
        if self.wo_delta.len() != wo.len() {
            self.wo_delta.resize(wo.len(), 0.0);
        }
        self.wo_delta.fill(0.0);
        // matvec: err[i] = (label - sigma(<wi_i, wo>)) * lr
        for (i, &inp) in inputs.iter().enumerate() {
            // SAFETY: Hogwild contract.
            let wi = unsafe { model.row_in(inp) };
            self.err[i] = (label - sigmoid_exact(dot(wi, wo))) * lr;
        }
        // Both gradients from the pre-update rows of this vector op;
        // model updated immediately afterwards (level-2 granularity).
        for (i, &inp) in inputs.iter().enumerate() {
            // SAFETY: Hogwild contract.
            let wi = unsafe { model.row_in(inp) };
            axpy(self.err[i], wi, &mut self.wo_delta);
            axpy(self.err[i], wo, wi);
        }
        axpy(1.0, &self.wo_delta, wo);
    }
}

impl Backend for BidmachBackend {
    fn process(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        for w in windows {
            anyhow::ensure!(
                w.inputs.len() <= self.err.len(),
                "window exceeds batch capacity"
            );
            // Pass 1: positive target.
            self.vector_pass(model, &w.inputs, w.target(), 1.0, lr);
            // Pass 2: each shared negative, one vector op at a time.
            for &neg in w.negatives() {
                self.vector_pass(model, &w.inputs, neg, 0.0, lr);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "bidmach"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SharedModel;

    fn window(inputs: &[u32], target: u32, negs: &[u32]) -> Window {
        let mut outputs = vec![target];
        outputs.extend_from_slice(negs);
        Window {
            inputs: inputs.to_vec(),
            outputs,
        }
    }

    #[test]
    fn positive_similarity_grows_negatives_shrink() {
        let model = SharedModel::init(20, 16, 3);
        let mut b = BidmachBackend::new(16);
        let w = window(&[1, 2, 3], 10, &[11, 12]);
        let sim = |a: u32, b_: u32| dot(model.m_in().row(a), model.m_out().row(b_));
        for _ in 0..300 {
            b.process(model.store(), std::slice::from_ref(&w), 0.05).unwrap();
        }
        assert!(sim(1, 10) > 0.5, "positive sim {}", sim(1, 10));
        assert!(sim(1, 11) < 0.1, "negative sim {}", sim(1, 11));
        assert!(sim(2, 12) < 0.1);
    }

    #[test]
    fn only_window_rows_touched() {
        let model = SharedModel::init(30, 8, 4);
        let before_out: Vec<Vec<f32>> =
            (0..30u32).map(|w| model.m_out().row(w).to_vec()).collect();
        let mut b = BidmachBackend::new(16);
        b.process(model.store(), &[window(&[1, 2], 5, &[7, 8])], 0.1)
            .unwrap();
        for w in 0..30u32 {
            let touched = [5u32, 7, 8].contains(&w);
            let changed = model.m_out().row(w) != &before_out[w as usize][..];
            assert_eq!(changed, touched, "row {w}");
        }
    }

    #[test]
    fn capacity_enforced() {
        let model = SharedModel::init(10, 4, 5);
        let mut b = BidmachBackend::new(2);
        let w = window(&[1, 2, 3], 5, &[6]);
        assert!(b.process(model.store(), &[w], 0.1).is_err());
    }
}
