//! Trainer back-ends and orchestration.
//!
//! Four interchangeable back-ends implement the SAME skip-gram
//! negative-sampling updates with different computational organisation —
//! the axis of the paper's evaluation:
//!
//! | backend   | BLAS level | negatives    | updates            | paper role |
//! |-----------|------------|--------------|--------------------|------------|
//! | `scalar`  | 1 (dot/axpy)| per pair    | after every pair   | Mikolov original (Alg. 1) |
//! | `bidmach` | 2 (matvec) | shared/window| after every vector op | Canny et al. comparator (Sec. III-D) |
//! | `gemm`    | 3 (GEMM)   | shared/window| end of window block | **the paper's scheme** (Sec. III-B/C) |
//! | `pjrt`    | 3 (GEMM)   | shared/window| end of superbatch   | same scheme through the AOT JAX/Pallas artifact |
//!
//! All run Hogwild across worker threads over corpus shards.

pub mod lr;
pub mod route;
pub mod sgd_bidmach;
pub mod sgd_gemm;
pub mod sgd_pjrt;
pub mod sgd_scalar;
pub mod trainer;

pub use lr::LrState;
pub use route::RouteMode;
pub use trainer::{train, TrainOutcome};

use crate::model::{ModelRef, SharedModel};
use crate::sampling::batch::{SuperbatchArena, Window};

/// A trainer back-end: processes a block of windows against the shared
/// model.  One instance per worker thread (holds scratch + private RNG);
/// the model is shared Hogwild-style.
///
/// Back-ends see the model through the [`ModelRef`] row handle, so the
/// same code drives the flat layout (`--numa off`) and the NUMA-sharded
/// layout (`--numa {auto,<nodes>}`) — the store decides where rows
/// live, the back-end never does (and the enum dispatch keeps the flat
/// path's row pointer math inlined).
pub trait Backend {
    /// Process `windows` at learning rate `lr`, mutating `model`.
    fn process(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()>;

    /// Process a flat superbatch arena (the trainer's hot path).
    ///
    /// The default materialises `Vec<Window>`s and forwards to
    /// [`process`](Self::process) — correct for every back-end, with the
    /// same allocation profile the pre-arena trainer had.  Back-ends with
    /// a native flat path (the GEMM backend) override this to run
    /// allocation-free.
    fn process_arena(
        &mut self,
        model: ModelRef<'_>,
        arena: &SuperbatchArena,
        lr: f32,
    ) -> anyhow::Result<()> {
        let windows = arena.to_windows();
        self.process(model, &windows, lr)
    }

    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;
}

/// The negative-sampling objective of Eq. (3) summed over a window set —
/// the loss-curve metric the examples/benches log (higher is better; the
/// quantity SGNS maximises).
pub fn ns_objective(model: &SharedModel, windows: &[Window]) -> f64 {
    let mut total = 0.0f64;
    for w in windows {
        for &inp in &w.inputs {
            let wi = model.m_in().row(inp);
            for (j, &out) in w.outputs.iter().enumerate() {
                let x = crate::linalg::dot(wi, model.m_out().row(out)) as f64;
                let signed = if j == 0 { x } else { -x };
                // log sigma(z) = -softplus(-z)
                total -= (1.0 + (-signed).exp()).ln();
            }
        }
    }
    total
}

#[cfg(test)]
mod obj_tests {
    use super::*;

    #[test]
    fn objective_increases_under_training() {
        let model = SharedModel::init(30, 16, 5);
        let windows: Vec<Window> = (0..8u32)
            .map(|t| Window {
                inputs: vec![(t + 1) % 30, (t + 2) % 30],
                outputs: vec![t, 20, 21, 22, 23, 24],
            })
            .collect();
        let before = ns_objective(&model, &windows);
        let mut b = super::sgd_gemm::GemmBackend::new(16, 8, 6);
        for _ in 0..50 {
            b.process(model.store(), &windows, 0.05).unwrap();
        }
        let after = ns_objective(&model, &windows);
        assert!(after > before, "{before} -> {after}");
    }
}
