//! Ownership-routed superbatch scheduling — steering each generated
//! window to the worker whose NUMA node owns the window's OUTPUT rows.
//!
//! `--numa` sharding (PR 4) bounds the expected remote Hogwild row share
//! at `(n−1)/n` because workers still consume an arbitrary window
//! stream: a window's target/negative rows live on a random node
//! relative to whoever generated it.  The paper's shared-memory scaling
//! (Sec. IV) comes precisely from keeping hot rows resident near the
//! threads that update them, and word ids are Zipf-distributed — a small
//! **routed head** of output ids covers most of the traffic.  This
//! module routes exactly that head:
//!
//! * [`RowRouter`] — arithmetic home-node lookup (the same
//!   [`ShardMap`] partition `NumaModel` places rows with) plus the
//!   Zipf-aware head cutoff: only targets with `id < K` are routed; the
//!   cold tail stays on the generating worker, so rare rows never pay
//!   for cross-worker queues.
//! * [`Exchange`] — per-worker-pair bounded SPSC mailboxes moving whole
//!   window BLOCKS (mini [`SuperbatchArena`]s) with a free-ring
//!   recycling path back to the producer, so the steady-state routed
//!   loop allocates nothing (`tests/alloc_steadystate.rs`, routed leg).
//!   Std-only — the same no-new-crates discipline as
//!   `runtime::topology`'s raw `sched_setaffinity(2)` and
//!   `corpus::encoded`'s raw `mmap(2)`.
//! * [`Outbox`]/[`RouteSink`] — the producer side: windows are
//!   classified at GENERATION time (before arena placement, so dedup
//!   slots stay node-local) and land either in the worker's own arena or
//!   in a pending block bound for the owner's worker.
//!
//! **Backpressure is the load balancer.** Under a contiguous shard map
//! the Zipf head lives almost entirely on node 0, so strict ownership
//! routing would pile most of the window mass onto node-0 workers.  The
//! mailboxes are bounded and producers NEVER block: when a destination's
//! rings are full (its workers can't absorb windows any faster), the
//! producer falls back to processing the window locally — routing is
//! opportunistic locality, not a partition, and correctness never
//! depends on where a window is processed (the model is shared;
//! `tests/routing_parity.rs` bounds the drift).  `--route off` bypasses
//! this module entirely (bit-for-bit the PR-4 path).

use std::cell::UnsafeCell;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use crate::corpus::vocab::Vocab;
use crate::model::ShardMap;
use crate::sampling::batch::{SuperbatchArena, WindowSink};

/// The `--route` config knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteMode {
    /// No routing — bit-for-bit the pre-routing trainer path.
    #[default]
    Off,
    /// Route windows whose target is in the Zipf-derived default head
    /// (the smallest id prefix covering [`OWNER_COVERAGE`] of corpus
    /// mass) to the worker on the target row's home node.
    Owner,
    /// Like `Owner` with an explicit head cutoff: route only targets
    /// with `id < K` (ids are frequency-sorted, so this is the hottest-K
    /// prefix) — the ablation/test knob.
    Head(usize),
}

impl RouteMode {
    /// The routed-head cutoff this mode resolves to for `vocab` —
    /// `None` = routing off.
    pub fn head_k(&self, vocab: &Vocab) -> Option<usize> {
        match *self {
            RouteMode::Off => None,
            RouteMode::Owner => Some(owner_head_k(vocab)),
            RouteMode::Head(k) => Some(k.min(vocab.len().max(1))),
        }
    }
}

impl FromStr for RouteMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "off" | "none" => Ok(RouteMode::Off),
            "owner" => Ok(RouteMode::Owner),
            other => {
                let k: usize = other
                    .strip_prefix("head=")
                    .and_then(|k| k.parse().ok())
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown route mode '{s}' (off|owner|head=<K>)"
                        )
                    })?;
                // Ids are u32; a head past that can never match a row.
                anyhow::ensure!(
                    (1..=u32::MAX as usize).contains(&k),
                    "--route head=<K> must be in 1..=2^32-1 (got {k})"
                );
                Ok(RouteMode::Head(k))
            }
        }
    }
}

impl fmt::Display for RouteMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteMode::Off => f.write_str("off"),
            RouteMode::Owner => f.write_str("owner"),
            RouteMode::Head(k) => write!(f, "head={k}"),
        }
    }
}

/// Corpus-mass fraction the `--route owner` default head covers.  Under
/// Zipf(1) frequencies the head length is sublinear in vocabulary size
/// (`H(K)/H(V)` coverage — EXPERIMENTS.md §Routing tabulates it), so 90%
/// of routable window mass costs a fraction of the id space.
pub const OWNER_COVERAGE: f64 = 0.90;

/// Smallest K such that ids `0..K` cover [`OWNER_COVERAGE`] of the
/// retained corpus mass.  Relies on the vocabulary's frequency-sorted id
/// invariant (id 0 = most frequent), which `corpus::vocab` guarantees.
pub fn owner_head_k(vocab: &Vocab) -> usize {
    let total = vocab.total_words();
    if total == 0 || vocab.is_empty() {
        return vocab.len().max(1);
    }
    let want = (total as f64 * OWNER_COVERAGE).ceil() as u64;
    let mut cum = 0u64;
    for id in 0..vocab.len() as u32 {
        cum += vocab.count(id);
        if cum >= want {
            return id as usize + 1;
        }
    }
    vocab.len()
}

/// Home-node lookup + routed-head cutoff: the read-only routing table
/// every worker shares.  Built over the SAME contiguous [`ShardMap`]
/// partition `NumaModel` shards rows with, so "home node" is literally
/// where the row's pages live under `--numa` (and the single node of the
/// flat model otherwise — routing then degenerates to per-row worker
/// ownership WITHIN the node, which still keeps a hot row's `dWo`
/// scatters on one core's cache).
pub struct RowRouter {
    map: ShardMap,
    head_k: u32,
}

impl RowRouter {
    pub fn new(map: ShardMap, head_k: usize) -> Self {
        let head_k = head_k.min(map.vocab()).min(u32::MAX as usize) as u32;
        Self { map, head_k }
    }

    pub fn nodes(&self) -> usize {
        self.map.nodes()
    }

    pub fn head_k(&self) -> usize {
        self.head_k as usize
    }

    /// Home node of a row (shard-map arithmetic lookup).
    #[inline]
    pub fn home_node(&self, row: u32) -> usize {
        self.map.locate(row).0
    }

    /// `Some(home node)` iff this target is in the routed head; `None`
    /// for the cold tail (stays on the generating worker).
    #[inline]
    pub fn route(&self, target: u32) -> Option<usize> {
        if target < self.head_k {
            Some(self.home_node(target))
        } else {
            None
        }
    }
}

/// Worker ↔ node assignment (worker `i` is pinned to node `i % nodes`,
/// the trainer's round-robin rule) plus the destination-worker pick for
/// a routed target: among the owning node's workers, the target id
/// selects one DETERMINISTICALLY, so a given hot row always lands in the
/// same worker's superbatches — maximising its dedup hit rate there.
#[derive(Clone, Copy, Debug)]
pub struct RoutePlan {
    workers: usize,
    nodes: usize,
}

impl RoutePlan {
    pub fn new(workers: usize, nodes: usize) -> Self {
        assert!(workers >= 1 && nodes >= 1);
        Self { workers, nodes }
    }

    #[inline]
    pub fn node_of_worker(&self, worker: usize) -> usize {
        worker % self.nodes
    }

    /// Number of workers pinned to `node` (0 when `nodes > workers`
    /// leaves the node workerless).
    #[inline]
    pub fn workers_on(&self, node: usize) -> usize {
        if node >= self.workers {
            0
        } else {
            (self.workers - 1 - node) / self.nodes + 1
        }
    }

    /// Destination worker for a routed target homed on `node`; `None`
    /// when no worker is pinned there (the window stays local).
    #[inline]
    pub fn consumer_for(&self, node: usize, target: u32) -> Option<usize> {
        let cnt = self.workers_on(node);
        if cnt == 0 {
            None
        } else {
            Some(node + (target as usize % cnt) * self.nodes)
        }
    }
}

/// Bounded single-producer/single-consumer ring.  Lock-free with two
/// atomic cursors: `head` is written only by the producer, `tail` only
/// by the consumer; each side Acquire-loads the other's cursor before
/// touching a slot, which is what makes the `UnsafeCell` access sound.
/// The SPSC discipline itself is enforced by [`Exchange`]'s (producer,
/// consumer) indexing — each ring has exactly one pushing worker and one
/// popping worker.
struct Spsc<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
    /// Next slot the producer writes.
    head: AtomicUsize,
    /// Next slot the consumer reads.
    tail: AtomicUsize,
    /// Producer-side "no more pushes" flag (Release-stored after the
    /// final push, so a consumer that Acquire-observes it and then
    /// drains sees everything).
    closed: AtomicBool,
}

// SAFETY: slot access is ordered by the head/tail Acquire/Release
// protocol above — a slot is touched by at most one thread at a time.
unsafe impl<T: Send> Sync for Spsc<T> {}

impl<T> Spsc<T> {
    /// Ring holding up to `cap` items (one slot is kept empty to tell
    /// full from empty, hence `cap + 1` physical slots).
    fn with_capacity(cap: usize) -> Self {
        assert!(cap >= 1);
        Self {
            slots: (0..cap + 1).map(|_| UnsafeCell::new(None)).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Producer: push without blocking; hands the value back when full.
    fn try_push(&self, v: T) -> Result<(), T> {
        let head = self.head.load(Ordering::Relaxed);
        let next = (head + 1) % self.slots.len();
        if next == self.tail.load(Ordering::Acquire) {
            return Err(v); // full
        }
        // SAFETY: single producer; the Acquire load above proves the
        // consumer has vacated slot `head` (tail moved past it), and the
        // consumer cannot see it again until the Release store below.
        unsafe { *self.slots[head].get() = Some(v) };
        self.head.store(next, Ordering::Release);
        Ok(())
    }

    /// Consumer: pop without blocking.
    fn try_pop(&self) -> Option<T> {
        let tail = self.tail.load(Ordering::Relaxed);
        if tail == self.head.load(Ordering::Acquire) {
            return None; // empty
        }
        // SAFETY: single consumer; the Acquire load above synchronises
        // with the producer's Release store, so the slot's write is
        // visible and the producer will not touch it again until the
        // Release store below recycles it.
        let v = unsafe { (*self.slots[tail].get()).take() };
        debug_assert!(v.is_some(), "non-empty ring held an empty slot");
        self.tail.store((tail + 1) % self.slots.len(), Ordering::Release);
        v
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
    }

    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }
}

/// One producer→consumer channel: the data ring carries filled window
/// blocks forward, the free ring recycles empty blocks back.  Blocks
/// are seeded LAZILY (`Exchange::take_free` allocates up to the
/// `blocks` quota on first demand), so a pair that never exchanges a
/// window — the common case, since hot-row ownership concentrates on a
/// few consumers — costs two empty ring headers, not block buffers:
/// the mailbox matrix is O(workers²) PAIRS but only O(active pairs)
/// MEMORY.  At most `blocks` blocks ever circulate per pair and the
/// data ring holds `blocks` slots, so a producer holding a block can
/// ALWAYS push it — the invariant `Exchange::send` relies on.
struct Mailbox {
    data: Spsc<Box<SuperbatchArena>>,
    free: Spsc<Box<SuperbatchArena>>,
    /// Blocks allocated for this pair so far (≤ quota).  Only the
    /// pair's producer touches it — Relaxed is enough.
    seeded: AtomicUsize,
}

impl Mailbox {
    fn new(blocks: usize) -> Self {
        Self {
            data: Spsc::with_capacity(blocks),
            free: Spsc::with_capacity(blocks),
            seeded: AtomicUsize::new(0),
        }
    }
}

/// Blocks seeded per worker pair.  Two blocks per direction keep the
/// producer filling one while the consumer drains the other; the total
/// in-flight bound stays small (`max_inflight`), which is what the
/// routed arena slack is sized from.
pub const ROUTE_BLOCKS: usize = 2;

/// The full worker-pair mailbox matrix plus the exchange geometry.
///
/// Indexing discipline (what makes the inner SPSC rings sound): for the
/// `(p, c)` pair, only worker `p` calls the producer operations
/// ([`Outbox`] wraps them) and only worker `c` calls
/// [`drain_into`](Self::drain_into) / [`producers_done`](Self::producers_done).
pub struct Exchange {
    /// `boxes[p][c]`: channel from producer worker `p` to consumer `c`.
    /// The `p == c` diagonal is never pushed to (local windows go
    /// straight into the worker's own arena); keeping it makes indexing
    /// uniform and costs only two tiny idle rings per worker.
    boxes: Vec<Vec<Mailbox>>,
    blocks: usize,
    block_windows: usize,
    /// Block geometry for lazy seeding ([`Mailbox`] docs).
    b_cap: usize,
    s: usize,
}

impl Exchange {
    pub fn new(
        workers: usize,
        blocks: usize,
        block_windows: usize,
        b_cap: usize,
        s: usize,
    ) -> Self {
        assert!(workers >= 1 && blocks >= 1 && block_windows >= 1);
        let boxes = (0..workers)
            .map(|_| (0..workers).map(|_| Mailbox::new(blocks)).collect())
            .collect();
        Self {
            boxes,
            blocks,
            block_windows,
            b_cap,
            s,
        }
    }

    pub fn workers(&self) -> usize {
        self.boxes.len()
    }

    /// Windows per mailbox block (the outbox flushes a block before it
    /// would exceed this).
    pub fn block_windows(&self) -> usize {
        self.block_windows
    }

    /// Upper bound on windows simultaneously in flight toward ONE
    /// consumer — the `inflight` term of
    /// [`SuperbatchArena::with_route_slack`]: every other worker can hold
    /// at most `blocks` full blocks in its ring to us.
    pub fn max_inflight(&self) -> usize {
        (self.workers() - 1) * self.blocks * self.block_windows
    }

    /// Producer `p`: an empty block for consumer `c` — recycled from
    /// the free ring, or lazily allocated while the pair is under its
    /// block quota.  `None` = the consumer is saturated (backpressure).
    fn take_free(&self, p: usize, c: usize) -> Option<Box<SuperbatchArena>> {
        let mb = &self.boxes[p][c];
        if let Some(block) = mb.free.try_pop() {
            return Some(block);
        }
        // Only this pair's producer reads/writes `seeded`, so the
        // load-then-add below is not a race.
        if mb.seeded.load(Ordering::Relaxed) < self.blocks {
            mb.seeded.fetch_add(1, Ordering::Relaxed);
            return Some(Box::new(SuperbatchArena::with_capacity(
                self.block_windows,
                self.b_cap,
                self.s,
            )));
        }
        None
    }

    /// Producer `p`: hand a filled block to consumer `c`.  Never fails:
    /// the block count in circulation equals the data ring's capacity.
    fn send(&self, p: usize, c: usize, block: Box<SuperbatchArena>) {
        assert!(
            self.boxes[p][c].data.try_push(block).is_ok(),
            "data ring sized for every block in circulation"
        );
    }

    /// Producer `p` finished generating: no more pushes to anyone.
    /// Idempotent — the drop guard re-closes on every exit path.
    pub fn close_producer(&self, p: usize) {
        for mb in &self.boxes[p] {
            mb.data.close();
        }
    }

    /// RAII close: peers' tail loops spin until EVERY producer has
    /// closed, so a worker that exits early — `?` error or panic — must
    /// still close its rings or the whole training scope hangs.  Workers
    /// arm this guard before their first fallible operation; the normal
    /// path also closes explicitly (before its own tail drain), which is
    /// fine because closing is idempotent.
    pub fn producer_guard(&self, p: usize) -> ProducerGuard<'_> {
        ProducerGuard { exch: self, p }
    }

    /// Consumer `c`: adopt queued blocks into `arena` (which must have
    /// route slack for [`max_inflight`](Self::max_inflight) windows) and
    /// recycle the empties.  Returns the number of windows adopted.
    ///
    /// Pops at most `blocks` blocks per producer PER CALL: a block
    /// recycled mid-drain can be refilled and re-pushed by a live
    /// producer, so an unbounded `while try_pop` could adopt more than
    /// `max_inflight` windows in one call and overflow the arena's route
    /// slack (reallocating on the hot path).  The cap restores the
    /// per-call bound exactly; later arrivals wait for the next drain.
    /// After a producer has closed, nothing refills, so one bounded
    /// drain still empties its ring completely.
    pub fn drain_into(&self, c: usize, arena: &mut SuperbatchArena) -> usize {
        let mut adopted = 0usize;
        for (p, row) in self.boxes.iter().enumerate() {
            if p == c {
                continue;
            }
            let mb = &row[c];
            for _ in 0..self.blocks {
                let Some(mut block) = mb.data.try_pop() else {
                    break;
                };
                adopted += block.len();
                arena.append_from(&block);
                block.clear();
                assert!(
                    mb.free.try_push(block).is_ok(),
                    "free ring sized for every block in circulation"
                );
            }
        }
        adopted
    }

    /// Consumer `c`: have ALL peers closed their rings toward us?  Once
    /// true, one more [`drain_into`](Self::drain_into) observes every
    /// window ever pushed (close is Release-stored after the final push).
    pub fn producers_done(&self, c: usize) -> bool {
        self.boxes
            .iter()
            .enumerate()
            .all(|(p, row)| p == c || row[c].data.is_closed())
    }
}

/// Closes a producer's outgoing rings when dropped (normal return,
/// `?` error, or unwind) — see [`Exchange::producer_guard`].
pub struct ProducerGuard<'x> {
    exch: &'x Exchange,
    p: usize,
}

impl Drop for ProducerGuard<'_> {
    fn drop(&mut self) {
        self.exch.close_producer(self.p);
    }
}

/// Producer-side routing state for one worker: the pending
/// partially-filled block per destination, plus the routed/fallback
/// accounting the benches and tests read.
pub struct Outbox<'x> {
    exch: &'x Exchange,
    router: &'x RowRouter,
    plan: RoutePlan,
    me: usize,
    pending: Vec<Option<Box<SuperbatchArena>>>,
    /// Windows steered into a mailbox block.
    pub routed_windows: u64,
    /// Routed-head windows processed locally because the destination's
    /// rings were saturated — the backpressure valve (see module docs).
    pub fallback_windows: u64,
    /// Cold-tail / own-target windows that were never routing candidates.
    pub local_windows: u64,
}

impl<'x> Outbox<'x> {
    pub fn new(exch: &'x Exchange, router: &'x RowRouter, me: usize) -> Self {
        let workers = exch.workers();
        assert!(me < workers);
        Self {
            exch,
            router,
            plan: RoutePlan::new(workers, router.nodes()),
            me,
            pending: (0..workers).map(|_| None).collect(),
            routed_windows: 0,
            fallback_windows: 0,
            local_windows: 0,
        }
    }

    /// Decide the destination of a window with this target and make its
    /// block current: `Some(consumer)` with `pending[consumer]` ready to
    /// take the window, or `None` for the worker's own arena (cold tail,
    /// own target, workerless node, or backpressure fallback).
    fn prepare(&mut self, target: u32) -> Option<usize> {
        let routed = self
            .router
            .route(target)
            .and_then(|node| self.plan.consumer_for(node, target))
            .filter(|&c| c != self.me);
        let c = match routed {
            Some(c) => c,
            None => {
                self.local_windows += 1;
                return None;
            }
        };
        // Hand off a block that could not take one more window, then
        // grab a recycled one; no recycled block = the consumer is
        // saturated, so this window processes locally instead.
        if self.pending[c]
            .as_ref()
            .is_some_and(|b| b.len() >= self.exch.block_windows())
        {
            let block = self.pending[c].take().expect("checked above");
            self.exch.send(self.me, c, block);
        }
        if self.pending[c].is_none() {
            self.pending[c] = self.exch.take_free(self.me, c);
        }
        if self.pending[c].is_some() {
            self.routed_windows += 1;
            Some(c)
        } else {
            self.fallback_windows += 1;
            None
        }
    }

    /// The block `prepare` made current (panics if not prepared).
    fn block(&mut self, c: usize) -> &mut SuperbatchArena {
        self.pending[c].as_mut().expect("prepare() returned this slot")
    }

    /// Hand off every pending (possibly partial) block — the producer
    /// half of the exchange step, run before each local superbatch and
    /// once after the worker's final sentence.
    pub fn flush(&mut self) {
        for (c, slot) in self.pending.iter_mut().enumerate() {
            if slot.as_ref().is_some_and(|b| !b.is_empty()) {
                let block = slot.take().expect("checked above");
                self.exch.send(self.me, c, block);
            }
        }
    }
}

/// The [`WindowSink`] a routed worker fills through: local windows go to
/// the worker's own arena, routed-head windows into the outbox's pending
/// blocks.
pub struct RouteSink<'a, 'x> {
    local: &'a mut SuperbatchArena,
    outbox: &'a mut Outbox<'x>,
}

impl<'a, 'x> RouteSink<'a, 'x> {
    pub fn new(local: &'a mut SuperbatchArena, outbox: &'a mut Outbox<'x>) -> Self {
        Self { local, outbox }
    }
}

impl WindowSink for RouteSink<'_, '_> {
    #[inline]
    fn arena_for(&mut self, target: u32) -> &mut SuperbatchArena {
        // `prepare` decides WITHOUT holding a borrow (it returns an
        // index), so both arms can hand out a borrow tied to `self`.
        match self.outbox.prepare(target) {
            Some(c) => self.outbox.block(c),
            None => &mut *self.local,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn zipf_vocab(v: usize) -> Vocab {
        let counts: HashMap<String, u64> = (0..v)
            .map(|i| (format!("w{i:05}"), (1_000_000 / (i + 1)) as u64))
            .collect();
        Vocab::from_counts(counts, 1)
    }

    #[test]
    fn route_mode_parsing_and_display() {
        assert_eq!("off".parse::<RouteMode>().unwrap(), RouteMode::Off);
        assert_eq!("OWNER".parse::<RouteMode>().unwrap(), RouteMode::Owner);
        assert_eq!(
            "head=128".parse::<RouteMode>().unwrap(),
            RouteMode::Head(128)
        );
        assert!("head=0".parse::<RouteMode>().is_err());
        assert!("head=".parse::<RouteMode>().is_err());
        assert!("head=4294967296".parse::<RouteMode>().is_err());
        assert!("hot".parse::<RouteMode>().is_err());
        assert_eq!(RouteMode::Off.to_string(), "off");
        assert_eq!(RouteMode::Owner.to_string(), "owner");
        assert_eq!(RouteMode::Head(64).to_string(), "head=64");
        assert_eq!(RouteMode::default(), RouteMode::Off);
    }

    #[test]
    fn owner_head_covers_mass_and_is_sublinear() {
        let vocab = zipf_vocab(10_000);
        let k = owner_head_k(&vocab);
        assert!(k >= 1 && k <= vocab.len());
        // Head must actually cover the coverage target...
        let covered: u64 = (0..k as u32).map(|id| vocab.count(id)).sum();
        assert!(
            covered as f64 >= OWNER_COVERAGE * vocab.total_words() as f64,
            "head {k} covers only {covered}/{}",
            vocab.total_words()
        );
        // ...and under Zipf it is a small fraction of the id space.
        assert!(k < vocab.len() / 2, "head {k} of {} not sublinear", vocab.len());
        // head_k resolution per mode.
        assert_eq!(RouteMode::Off.head_k(&vocab), None);
        assert_eq!(RouteMode::Owner.head_k(&vocab), Some(k));
        assert_eq!(RouteMode::Head(17).head_k(&vocab), Some(17));
        assert_eq!(
            RouteMode::Head(usize::MAX).head_k(&vocab),
            Some(vocab.len())
        );
    }

    #[test]
    fn router_routes_head_by_home_node_only() {
        let map = ShardMap::contiguous(100, 4);
        let router = RowRouter::new(map.clone(), 40);
        assert_eq!(router.nodes(), 4);
        assert_eq!(router.head_k(), 40);
        for row in 0..100u32 {
            let expect_home = map.locate(row).0;
            assert_eq!(router.home_node(row), expect_home, "row {row}");
            match router.route(row) {
                Some(node) => {
                    assert!(row < 40, "cold row {row} routed");
                    assert_eq!(node, expect_home);
                }
                None => assert!(row >= 40, "hot row {row} not routed"),
            }
        }
        // head_k clamps to the vocabulary.
        assert_eq!(RowRouter::new(map, 1_000_000).head_k(), 100);
    }

    #[test]
    fn route_plan_consumer_invariants() {
        for (workers, nodes) in
            [(1usize, 1usize), (2, 2), (3, 2), (8, 3), (2, 5), (7, 7)]
        {
            let plan = RoutePlan::new(workers, nodes);
            let mut counted = 0;
            for node in 0..nodes {
                counted += plan.workers_on(node);
            }
            assert_eq!(counted, workers, "({workers},{nodes})");
            for node in 0..nodes {
                for target in 0..64u32 {
                    match plan.consumer_for(node, target) {
                        Some(c) => {
                            assert!(c < workers, "({workers},{nodes})");
                            assert_eq!(
                                plan.node_of_worker(c),
                                node,
                                "({workers},{nodes}) consumer off-node"
                            );
                        }
                        None => assert_eq!(
                            plan.workers_on(node),
                            0,
                            "({workers},{nodes}) node {node}"
                        ),
                    }
                }
            }
        }
        // Deterministic per target: the same id always picks the same
        // consumer (dedup affinity).
        let plan = RoutePlan::new(8, 2);
        for t in 0..100u32 {
            assert_eq!(plan.consumer_for(0, t), plan.consumer_for(0, t));
        }
    }

    #[test]
    fn spsc_orders_fills_and_closes() {
        let ring: Spsc<u32> = Spsc::with_capacity(3);
        assert!(ring.try_pop().is_none());
        ring.try_push(1).unwrap();
        ring.try_push(2).unwrap();
        ring.try_push(3).unwrap();
        // Full: the push hands the value back.
        assert_eq!(ring.try_push(4), Err(4));
        assert_eq!(ring.try_pop(), Some(1));
        ring.try_push(4).unwrap();
        assert_eq!(ring.try_pop(), Some(2));
        assert_eq!(ring.try_pop(), Some(3));
        assert_eq!(ring.try_pop(), Some(4));
        assert!(ring.try_pop().is_none());
        assert!(!ring.is_closed());
        ring.close();
        assert!(ring.is_closed());
    }

    #[test]
    fn spsc_survives_threaded_stream() {
        let ring: Spsc<u64> = Spsc::with_capacity(4);
        const N: u64 = 20_000;
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..N {
                    let mut v = i;
                    loop {
                        match ring.try_push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
                ring.close();
            });
            s.spawn(|| {
                let mut expect = 0u64;
                loop {
                    match ring.try_pop() {
                        Some(v) => {
                            assert_eq!(v, expect, "reordered or lost");
                            expect += 1;
                        }
                        None => {
                            if ring.is_closed() && ring.try_pop().is_none() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                assert_eq!(expect, N, "missing items");
            });
        });
    }

    /// Windows routed through the exchange arrive exactly once, in
    /// producer order, with the cold tail left local.
    #[test]
    fn outbox_exchange_roundtrip() {
        let router = RowRouter::new(ShardMap::contiguous(100, 2), 100);
        let exch = Exchange::new(2, ROUTE_BLOCKS, 4, 8, 6);
        assert_eq!(exch.max_inflight(), ROUTE_BLOCKS * 4);
        let mut local = SuperbatchArena::new(8, 6);
        let mut adopted = SuperbatchArena::new(8, 6);
        let mut outbox = Outbox::new(&exch, &router, 0);
        // Rows 0..50 home on node 0 (worker 0 = me → local), rows
        // 50..100 on node 1 (worker 1 → mailbox).
        let outputs_of = |t: u32| {
            let mut o = vec![t];
            o.extend_from_slice(&[1, 2, 3, 4, 5]);
            o
        };
        let mut sent_remote = Vec::new();
        for t in [10u32, 60, 61, 7, 62, 63, 64, 99] {
            let mut sink = RouteSink::new(&mut local, &mut outbox);
            let arena = sink.arena_for(t);
            arena.push_window(&[t], &outputs_of(t));
            if t >= 50 {
                sent_remote.push(t);
            }
        }
        outbox.flush();
        exch.close_producer(0);
        assert_eq!(outbox.local_windows, 2);
        assert_eq!(outbox.routed_windows as usize, sent_remote.len());
        assert_eq!(outbox.fallback_windows, 0);
        assert_eq!(local.len(), 2);
        let n = exch.drain_into(1, &mut adopted);
        assert_eq!(n, sent_remote.len());
        assert_eq!(adopted.len(), sent_remote.len());
        for (w, &t) in sent_remote.iter().enumerate() {
            assert_eq!(adopted.outputs_of(w)[0], t, "window {w}");
            assert_eq!(adopted.inputs_of(w), &[t][..], "window {w}");
        }
        assert!(exch.producers_done(1));
        // Nothing flowed toward worker 0.
        assert_eq!(exch.drain_into(0, &mut local), 0);
    }

    /// When the destination's rings are saturated (consumer never
    /// drains), the producer falls back to local processing instead of
    /// blocking — the backpressure valve.
    #[test]
    fn saturated_mailbox_falls_back_to_local() {
        let router = RowRouter::new(ShardMap::contiguous(100, 2), 100);
        let blocks = 1usize;
        let block_windows = 2usize;
        let exch = Exchange::new(2, blocks, block_windows, 8, 6);
        let mut local = SuperbatchArena::new(8, 6);
        let mut outbox = Outbox::new(&exch, &router, 0);
        let outputs = [60u32, 1, 2, 3, 4, 5];
        // Capacity toward worker 1: `blocks` blocks circulate per pair,
        // so at most `blocks * block_windows` routed windows fit before
        // the free ring runs dry; everything past that must fall back.
        let routable = blocks * block_windows;
        for _ in 0..routable + 3 {
            let mut sink = RouteSink::new(&mut local, &mut outbox);
            let arena = sink.arena_for(60);
            arena.push_window(&[9], &outputs);
        }
        assert_eq!(outbox.routed_windows as usize, routable);
        assert_eq!(outbox.fallback_windows, 3);
        assert_eq!(local.len(), 3, "fallback windows must land locally");
        // Consumer drains, recycling the block back to the free ring —
        // routing capacity returns.
        let mut adopted = SuperbatchArena::new(8, 6);
        outbox.flush();
        assert_eq!(exch.drain_into(1, &mut adopted), routable);
        let before = outbox.routed_windows;
        {
            let mut sink = RouteSink::new(&mut local, &mut outbox);
            sink.arena_for(60).push_window(&[9], &outputs);
        }
        assert_eq!(outbox.routed_windows, before + 1, "capacity not recycled");
    }

    /// A single-worker exchange (the dist replica case) classifies every
    /// window back to its own arena — routing collapses to the local
    /// path by construction.
    #[test]
    fn single_worker_routes_everything_local() {
        let router = RowRouter::new(ShardMap::contiguous(50, 1), 50);
        let exch = Exchange::new(1, 1, 1, 4, 6);
        assert_eq!(exch.max_inflight(), 0);
        let mut local = SuperbatchArena::new(4, 6);
        let mut outbox = Outbox::new(&exch, &router, 0);
        for t in 0..50u32 {
            let mut sink = RouteSink::new(&mut local, &mut outbox);
            sink.arena_for(t).push_window(&[t], &[t, 1, 2, 3, 4, 5]);
        }
        assert_eq!(local.len(), 50);
        assert_eq!(outbox.local_windows, 50);
        assert_eq!(outbox.routed_windows, 0);
        outbox.flush();
        assert!(exch.producers_done(0));
    }
}
