//! THE PAPER'S SCHEME (Sec. III-B/C): minibatched, shared-negative-sample
//! SGNS organised as three level-3 BLAS calls per window, with all model
//! updates deferred to the end of the window block.
//!
//! Per window (Fig. 2 right):
//!
//! ```text
//! gather:  Wi[B,D] <- M_in[inputs],  Wo[S,D] <- M_out[target + negatives]
//! GEMM 1:  logits = Wi · Woᵀ                  (level-3, reuses Wo across B)
//! elem:    err    = (label - σ(logits)) · lr
//! GEMM 2:  dWi    = err · Wo
//! GEMM 3:  dWo    = errᵀ · Wi
//! scatter: M_in[inputs] += dWi rows, M_out[outputs] += dWo rows (Hogwild)
//! ```
//!
//! The scatter phase performs ONE update per touched row per window — the
//! update-count reduction (Sec. III-C) that cuts coherence traffic versus
//! the scalar baseline's per-pair updates.
//!
//! Optionally wraps the scatter in AdaGrad/RMSProp per-parameter rescaling
//! for the Sec. III-E ablation.

use std::sync::Arc;

use super::lr::{AdaGrad, RmsProp};
use super::Backend;
use crate::linalg::gemm::{gemm_nn, gemm_nt, gemm_tn};
use crate::linalg::sigmoid::sigmoid_exact;
use crate::model::SharedModel;
use crate::sampling::batch::Window;

/// Per-parameter update rule applied at scatter time.
#[derive(Clone, Default)]
pub enum UpdateRule {
    #[default]
    Plain,
    Adagrad(Arc<AdaGrad>),
    Rmsprop(Arc<RmsProp>),
}

pub struct GemmBackend {
    dim: usize,
    /// Scratch (per worker thread): gathered blocks + intermediates.
    wi: Vec<f32>,
    wo: Vec<f32>,
    logits: Vec<f32>,
    dwi: Vec<f32>,
    dwo: Vec<f32>,
    rule: UpdateRule,
}

impl GemmBackend {
    pub fn new(dim: usize, batch_cap: usize, samples: usize) -> Self {
        Self {
            dim,
            wi: vec![0.0; batch_cap * dim],
            wo: vec![0.0; samples * dim],
            logits: vec![0.0; batch_cap * samples],
            dwi: vec![0.0; batch_cap * dim],
            dwo: vec![0.0; samples * dim],
            rule: UpdateRule::Plain,
        }
    }

    pub fn with_rule(mut self, rule: UpdateRule) -> Self {
        self.rule = rule;
        self
    }

    /// One window: gather → 3 GEMMs → scatter.
    fn window(&mut self, model: &SharedModel, w: &Window, lr: f32) {
        let d = self.dim;
        let b = w.inputs.len();
        let s = w.outputs.len();
        debug_assert!(b * d <= self.wi.len() && s * d <= self.wo.len());

        // Gather rows into contiguous blocks (the paper's "minibatching").
        for (i, &inp) in w.inputs.iter().enumerate() {
            // SAFETY: Hogwild contract (model::hogwild docs).
            let row = unsafe { model.row_in(inp) };
            self.wi[i * d..(i + 1) * d].copy_from_slice(row);
        }
        for (j, &out) in w.outputs.iter().enumerate() {
            // SAFETY: Hogwild contract.
            let row = unsafe { model.row_out(out) };
            self.wo[j * d..(j + 1) * d].copy_from_slice(row);
        }

        let (wi, wo) = (&self.wi[..b * d], &self.wo[..s * d]);

        // GEMM 1: logits = Wi · Woᵀ.
        gemm_nt(b, s, d, 1.0, wi, wo, 0.0, &mut self.logits[..b * s]);

        // err = (label - sigma(logits)) * lr, in place.
        for i in 0..b {
            for j in 0..s {
                let label = if j == 0 { 1.0 } else { 0.0 };
                let x = &mut self.logits[i * s + j];
                *x = (label - sigmoid_exact(*x)) * lr;
            }
        }
        let err = &self.logits[..b * s];

        // GEMM 2 + 3 from the PRE-update blocks.
        gemm_nn(b, d, s, 1.0, err, wo, 0.0, &mut self.dwi[..b * d]);
        gemm_tn(s, d, b, 1.0, err, wi, 0.0, &mut self.dwo[..s * d]);

        // Scatter-add (one Hogwild update per touched row).
        match &self.rule {
            UpdateRule::Plain => {
                for (i, &inp) in w.inputs.iter().enumerate() {
                    model.add_in(inp, &self.dwi[i * d..(i + 1) * d]);
                }
                for (j, &out) in w.outputs.iter().enumerate() {
                    model.add_out(out, &self.dwo[j * d..(j + 1) * d]);
                }
            }
            UpdateRule::Adagrad(ag) => {
                for (i, &inp) in w.inputs.iter().enumerate() {
                    ag.adjust_in(inp, &mut self.dwi[i * d..(i + 1) * d]);
                    model.add_in(inp, &self.dwi[i * d..(i + 1) * d]);
                }
                for (j, &out) in w.outputs.iter().enumerate() {
                    ag.adjust_out(out, &mut self.dwo[j * d..(j + 1) * d]);
                    model.add_out(out, &self.dwo[j * d..(j + 1) * d]);
                }
            }
            UpdateRule::Rmsprop(rp) => {
                for (i, &inp) in w.inputs.iter().enumerate() {
                    rp.adjust_in(inp, &mut self.dwi[i * d..(i + 1) * d]);
                    model.add_in(inp, &self.dwi[i * d..(i + 1) * d]);
                }
                for (j, &out) in w.outputs.iter().enumerate() {
                    rp.adjust_out(out, &mut self.dwo[j * d..(j + 1) * d]);
                    model.add_out(out, &self.dwo[j * d..(j + 1) * d]);
                }
            }
        }
    }
}

impl Backend for GemmBackend {
    fn process(
        &mut self,
        model: &SharedModel,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        for w in windows {
            anyhow::ensure!(
                w.inputs.len() * self.dim <= self.wi.len()
                    && w.outputs.len() * self.dim <= self.wo.len(),
                "window exceeds backend capacity"
            );
            self.window(model, w, lr);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "gemm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dot;

    fn window(inputs: &[u32], target: u32, negs: &[u32]) -> Window {
        let mut outputs = vec![target];
        outputs.extend_from_slice(negs);
        Window {
            inputs: inputs.to_vec(),
            outputs,
        }
    }

    /// The GEMM backend must produce EXACTLY the same deltas as a naive
    /// per-pair computation with end-of-window updates (the semantics the
    /// python oracle also checks for the kernel).
    #[test]
    fn matches_naive_end_of_window_semantics() {
        let dim = 24;
        let model_g = SharedModel::init(40, dim, 11);
        let model_n = SharedModel::init(40, dim, 11); // same seed => same init
        let w = window(&[1, 2, 3, 4], 10, &[20, 21, 22, 23, 24]);
        let lr = 0.07f32;

        let mut g = GemmBackend::new(dim, 16, 6);
        g.process(&model_g, std::slice::from_ref(&w), lr).unwrap();

        // Naive: compute ALL gradients from pre-update state, apply at end.
        let b = w.inputs.len();
        let s = w.outputs.len();
        let mut dwi = vec![0.0f32; b * dim];
        let mut dwo = vec![0.0f32; s * dim];
        for (i, &inp) in w.inputs.iter().enumerate() {
            for (j, &out) in w.outputs.iter().enumerate() {
                let wi = model_n.m_in().row(inp);
                let wo = model_n.m_out().row(out);
                let label = if j == 0 { 1.0 } else { 0.0 };
                let gld = (label - sigmoid_exact(dot(wi, wo))) * lr;
                for l in 0..dim {
                    dwi[i * dim + l] += gld * wo[l];
                    dwo[j * dim + l] += gld * wi[l];
                }
            }
        }
        for (i, &inp) in w.inputs.iter().enumerate() {
            model_n.add_in(inp, &dwi[i * dim..(i + 1) * dim]);
        }
        for (j, &out) in w.outputs.iter().enumerate() {
            model_n.add_out(out, &dwo[j * dim..(j + 1) * dim]);
        }

        for r in 0..40u32 {
            let (a, b_) = (model_g.m_in().row(r), model_n.m_in().row(r));
            for l in 0..dim {
                assert!((a[l] - b_[l]).abs() < 1e-5, "m_in row {r} dim {l}");
            }
            let (a, b_) = (model_g.m_out().row(r), model_n.m_out().row(r));
            for l in 0..dim {
                assert!((a[l] - b_[l]).abs() < 1e-5, "m_out row {r} dim {l}");
            }
        }
    }

    #[test]
    fn learns_positive_pairs() {
        let model = SharedModel::init(20, 16, 3);
        let mut g = GemmBackend::new(16, 16, 6);
        let w = window(&[1, 2, 3], 10, &[11, 12, 13, 14, 15]);
        let sim = |a: u32, b_: u32| dot(model.m_in().row(a), model.m_out().row(b_));
        for _ in 0..300 {
            g.process(&model, std::slice::from_ref(&w), 0.05).unwrap();
        }
        assert!(sim(1, 10) > 0.5);
        assert!(sim(1, 11) < 0.1);
    }

    #[test]
    fn duplicate_input_words_accumulate() {
        // The same word appearing twice in the batch gets both deltas
        // (scatter-ADD, not overwrite).
        let dim = 8;
        let model = SharedModel::init(10, dim, 9);
        let w_dup = window(&[1, 1], 5, &[6, 7]);
        let w_single = window(&[1], 5, &[6, 7]);

        let model_single = SharedModel::init(10, dim, 9);
        let mut g1 = GemmBackend::new(dim, 16, 6);
        let mut g2 = GemmBackend::new(dim, 16, 6);
        g1.process(&model, &[w_dup], 0.05).unwrap();
        g2.process(&model_single, &[w_single], 0.05).unwrap();
        // Dup delta on M_in[1] must be ~2x the single delta.
        let base = SharedModel::init(10, dim, 9);
        let d_dup: Vec<f32> = model
            .m_in()
            .row(1)
            .iter()
            .zip(base.m_in().row(1))
            .map(|(a, b)| a - b)
            .collect();
        let d_single: Vec<f32> = model_single
            .m_in()
            .row(1)
            .iter()
            .zip(base.m_in().row(1))
            .map(|(a, b)| a - b)
            .collect();
        for l in 0..dim {
            assert!((d_dup[l] - 2.0 * d_single[l]).abs() < 1e-6, "dim {l}");
        }
    }

    #[test]
    fn adagrad_rule_damps_over_time() {
        let dim = 8;
        let mut model = SharedModel::init(10, dim, 13);
        // Prewarm M_out (word2vec zero-init would make the first dwi zero
        // and hide the damping behaviour under test).
        for r in 0..10u32 {
            for (i, x) in model.m_out_mut().row_mut(r).iter_mut().enumerate() {
                *x = 0.05 * ((r as f32) - 4.0) * if i % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let ag = Arc::new(AdaGrad::new(10, dim));
        let mut g =
            GemmBackend::new(dim, 16, 6).with_rule(UpdateRule::Adagrad(ag));
        let w = window(&[1], 5, &[6, 7]);
        let mut deltas = Vec::new();
        let mut prev = model.m_in().row(1).to_vec();
        for _ in 0..5 {
            g.process(&model, std::slice::from_ref(&w), 0.05).unwrap();
            let cur = model.m_in().row(1).to_vec();
            let step: f32 = cur
                .iter()
                .zip(&prev)
                .map(|(a, b)| (a - b).abs())
                .sum();
            deltas.push(step);
            prev = cur;
        }
        // First adjusted step is the sign-normalised AdaGrad step; later
        // steps must shrink as the accumulator grows.
        assert!(deltas[0] > 0.0, "{deltas:?}");
        assert!(deltas[4] < deltas[0] * 0.9, "should shrink: {deltas:?}");
    }
}
