//! THE PAPER'S SCHEME (Sec. III-B/C): minibatched, shared-negative-sample
//! SGNS with all model updates deferred to the end of the window block,
//! in one of two kernel organisations (`--kernel {auto,fused,gemm3}`):
//!
//! **gemm3** — three level-3 BLAS calls per window (Fig. 2 right),
//! preserved bit-for-bit from the pre-fusion crate for ablations:
//!
//! ```text
//! gather:  Wi[B,D] <- M_in[inputs],  Wo[S,D] <- M_out[target + negatives]
//! GEMM 1:  logits = Wi · Woᵀ                  (level-3, reuses Wo across B)
//! elem:    err    = (label - σ(logits)) · lr   (fused SIMD kernel)
//! GEMM 2:  dWi    = err · Wo
//! GEMM 3:  dWo    = errᵀ · Wi
//! scatter: M_in[inputs] += dWi rows, M_out[outputs] += dWo rows (Hogwild)
//! ```
//!
//! **fused** (default) — ONE call to [`simd::sgns_fused`] per window: the
//! dot products, the `(label − σ)·lr` error, and both gradient
//! accumulations happen in the same register tiles, so the gathered
//! blocks are swept ~once instead of three-plus times and the
//! `logits`/`err` intermediates never round-trip between kernels.  On the
//! arena path the kernel additionally reads `Wo` rows and accumulates
//! `dWo` THROUGH the superbatch dedup slots, which deletes the per-window
//! `Wo` block assembly copy and the per-window `dWo` accumulation pass
//! that the gemm3 chain needs.  The fused kernel evaluates the exact
//! sigmoid; under `--sigmoid table` the backend keeps the gemm3 chain
//! (`--kernel fused --sigmoid table` is rejected at config validation).
//!
//! All kernels go through [`crate::linalg::simd`], so the backend runs the
//! AVX2+FMA path on capable CPUs and the portable path under
//! `--simd scalar` (bit-identical to the pre-SIMD crate).
//!
//! Two processing surfaces:
//!
//! * [`Backend::process`] — window-at-a-time over `&[Window]` (reference
//!   semantics: each window gathers fresh rows, scatters immediately);
//! * [`Backend::process_arena`] — the trainer's zero-allocation superbatch
//!   path over a flat [`SuperbatchArena`].  `Wo` rows are gathered ONCE
//!   per superbatch per distinct id (shared negatives repeat heavily under
//!   the Zipf unigram distribution), window blocks are assembled from that
//!   L1-hot copy, and `dWo` accumulates per distinct id with a single
//!   Hogwild update at the end — extending the paper's Sec. III-C
//!   update-count reduction from the window to the superbatch.
//!
//! Optionally wraps the scatter in AdaGrad/RMSProp per-parameter rescaling
//! for the Sec. III-E ablation.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::Arc;

use super::lr::{AdaGrad, RmsProp};
use super::Backend;
use crate::config::{KernelMode, SigmoidMode};
use crate::linalg::sigmoid::SigmoidTable;
use crate::linalg::simd;
use crate::model::ModelRef;
use crate::sampling::batch::{SuperbatchArena, Window};

/// FxHash-style multiply-mix hasher for the `u32` output-id dedup map:
/// SipHash (the `HashMap` default) is a measurable tax at millions of
/// lookups per second on exactly the hot path this backend optimises,
/// and hash-flooding resistance buys nothing against word ids.
#[derive(Default)]
struct FxU32Hasher(u64);

impl Hasher for FxU32Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.mix(n);
    }
}

impl FxU32Hasher {
    #[inline]
    fn mix(&mut self, n: u64) {
        self.0 = (self.0.rotate_left(5) ^ n).wrapping_mul(0x51_7c_c1_b7_27_22_0a_95);
    }
}

type FxU32Map<V> = HashMap<u32, V, BuildHasherDefault<FxU32Hasher>>;

/// Per-parameter update rule applied at scatter time.
#[derive(Clone, Default)]
pub enum UpdateRule {
    #[default]
    Plain,
    Adagrad(Arc<AdaGrad>),
    Rmsprop(Arc<RmsProp>),
}

pub struct GemmBackend {
    dim: usize,
    /// Scratch (per worker thread): gathered blocks + intermediates.
    wi: Vec<f32>,
    wo: Vec<f32>,
    logits: Vec<f32>,
    dwi: Vec<f32>,
    dwo: Vec<f32>,
    rule: UpdateRule,
    /// `Some` = EXP_TABLE sigmoid (config `sigmoid = table`); `None` =
    /// exact sigmoid through the fused SIMD kernel.
    sigmoid_table: Option<SigmoidTable>,
    /// Kernel organisation (`--kernel`); see [`Self::use_fused`].
    kernel: KernelMode,
    /// Identity slot map `0..s` for the fused window-at-a-time path
    /// (reused; steady-state allocation-free).
    win_slots: Vec<u32>,
    /// Superbatch dedup scratch (reused; steady-state allocation-free).
    uniq_ids: Vec<u32>,
    slot_of: FxU32Map<u32>,
    out_slots: Vec<u32>,
    wo_uniq: Vec<f32>,
    dwo_uniq: Vec<f32>,
}

impl GemmBackend {
    pub fn new(dim: usize, batch_cap: usize, samples: usize) -> Self {
        Self {
            dim,
            wi: vec![0.0; batch_cap * dim],
            wo: vec![0.0; samples * dim],
            logits: vec![0.0; batch_cap * samples],
            dwi: vec![0.0; batch_cap * dim],
            dwo: vec![0.0; samples * dim],
            rule: UpdateRule::Plain,
            sigmoid_table: None,
            kernel: KernelMode::Auto,
            win_slots: Vec::new(),
            uniq_ids: Vec::new(),
            slot_of: FxU32Map::default(),
            out_slots: Vec::new(),
            wo_uniq: Vec::new(),
            dwo_uniq: Vec::new(),
        }
    }

    pub fn with_rule(mut self, rule: UpdateRule) -> Self {
        self.rule = rule;
        self
    }

    /// Select the sigmoid the fused error kernel evaluates.
    pub fn with_sigmoid(mut self, mode: SigmoidMode) -> Self {
        self.sigmoid_table = match mode {
            SigmoidMode::Exact => None,
            SigmoidMode::Table => Some(SigmoidTable::default_table()),
        };
        self
    }

    /// Select the kernel organisation (`--kernel`).
    pub fn with_kernel(mut self, kernel: KernelMode) -> Self {
        self.kernel = kernel;
        self
    }

    /// The fused single-pass kernel runs unless the caller pinned `gemm3`
    /// or configured the EXP_TABLE sigmoid (the fused kernel evaluates
    /// the exact sigmoid only; the contradictory `--kernel fused
    /// --sigmoid table` is rejected by `TrainConfig::validate`).
    #[inline]
    fn use_fused(&self) -> bool {
        self.kernel != KernelMode::Gemm3 && self.sigmoid_table.is_none()
    }

    /// `logits[..b*s] <- (label - σ) · lr` under the configured sigmoid.
    #[inline]
    fn err_inplace(&mut self, b: usize, s: usize, lr: f32) {
        let logits = &mut self.logits[..b * s];
        match &self.sigmoid_table {
            None => simd::sgns_err(logits, s, lr),
            Some(t) => {
                for (idx, x) in logits.iter_mut().enumerate() {
                    let label = if idx % s == 0 { 1.0 } else { 0.0 };
                    *x = (label - t.get(*x)) * lr;
                }
            }
        }
    }

    /// One window: gather → fused kernel (or 3-GEMM chain) → scatter.
    fn window(&mut self, model: ModelRef<'_>, w: &Window, lr: f32) {
        let d = self.dim;
        let b = w.inputs.len();
        let s = w.outputs.len();
        debug_assert!(b * d <= self.wi.len() && s * d <= self.wo.len());

        // Gather rows into contiguous blocks (the paper's "minibatching").
        for (i, &inp) in w.inputs.iter().enumerate() {
            // SAFETY: Hogwild contract (model::hogwild docs).
            let row = unsafe { model.row_in(inp) };
            self.wi[i * d..(i + 1) * d].copy_from_slice(row);
        }
        for (j, &out) in w.outputs.iter().enumerate() {
            // SAFETY: Hogwild contract.
            let row = unsafe { model.row_out(out) };
            self.wo[j * d..(j + 1) * d].copy_from_slice(row);
        }

        if self.use_fused() {
            // One single-pass kernel call over the gathered blocks
            // (identity slots: the window block IS the wo/dwo storage).
            self.win_slots.clear();
            self.win_slots.extend(0..s as u32);
            self.dwo[..s * d].fill(0.0);
            simd::sgns_fused(
                s,
                d,
                lr,
                &self.wi[..b * d],
                &self.wo[..s * d],
                &self.win_slots[..s],
                &mut self.logits[..b * s],
                &mut self.dwi[..b * d],
                &mut self.dwo[..s * d],
            );
        } else {
            // GEMM 1: logits = Wi · Woᵀ.
            simd::gemm_nt(
                b,
                s,
                d,
                1.0,
                &self.wi[..b * d],
                &self.wo[..s * d],
                0.0,
                &mut self.logits[..b * s],
            );

            // err = (label - sigma(logits)) * lr, in place.
            self.err_inplace(b, s, lr);

            // GEMM 2 + 3 from the PRE-update blocks.
            simd::gemm_nn(
                b,
                d,
                s,
                1.0,
                &self.logits[..b * s],
                &self.wo[..s * d],
                0.0,
                &mut self.dwi[..b * d],
            );
            simd::gemm_tn(
                s,
                d,
                b,
                1.0,
                &self.logits[..b * s],
                &self.wi[..b * d],
                0.0,
                &mut self.dwo[..s * d],
            );
        }

        // Scatter-add (one Hogwild update per touched row).
        self.scatter_dwi(model, &w.inputs);
        for (j, &out) in w.outputs.iter().enumerate() {
            let delta = &mut self.dwo[j * d..(j + 1) * d];
            match &self.rule {
                UpdateRule::Plain => {}
                UpdateRule::Adagrad(ag) => ag.adjust_out(out, delta),
                UpdateRule::Rmsprop(rp) => rp.adjust_out(out, delta),
            }
            model.add_out(out, delta);
        }
    }

    /// Scatter `dwi` rows for `inputs`, applying the update rule.
    fn scatter_dwi(&mut self, model: ModelRef<'_>, inputs: &[u32]) {
        let d = self.dim;
        for (i, &inp) in inputs.iter().enumerate() {
            let delta = &mut self.dwi[i * d..(i + 1) * d];
            match &self.rule {
                UpdateRule::Plain => {}
                UpdateRule::Adagrad(ag) => ag.adjust_in(inp, delta),
                UpdateRule::Rmsprop(rp) => rp.adjust_in(inp, delta),
            }
            model.add_in(inp, delta);
        }
    }
}

impl Backend for GemmBackend {
    fn process(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        for w in windows {
            anyhow::ensure!(
                w.inputs.len() * self.dim <= self.wi.len()
                    && w.outputs.len() * self.dim <= self.wo.len(),
                "window exceeds backend capacity"
            );
            self.window(model, w, lr);
        }
        Ok(())
    }

    /// Flat superbatch path: zero allocations at steady state, one `Wo`
    /// gather and one `dWo` Hogwild update per DISTINCT output id per
    /// superbatch.
    fn process_arena(
        &mut self,
        model: ModelRef<'_>,
        arena: &SuperbatchArena,
        lr: f32,
    ) -> anyhow::Result<()> {
        let d = self.dim;
        let s = arena.s();
        anyhow::ensure!(
            s * d <= self.wo.len() && arena.b_cap() * d <= self.wi.len(),
            "arena geometry exceeds backend capacity"
        );

        // Deduplicate output ids across the whole superbatch.
        self.slot_of.clear();
        self.uniq_ids.clear();
        self.out_slots.clear();
        {
            let uniq = &mut self.uniq_ids;
            let slots = &mut self.out_slots;
            let map = &mut self.slot_of;
            for &id in arena.outputs_flat() {
                let slot = *map.entry(id).or_insert_with(|| {
                    let next = uniq.len() as u32;
                    uniq.push(id);
                    next
                });
                slots.push(slot);
            }
        }

        // Gather each distinct Wo row ONCE (pre-superbatch state — the
        // same deferred-read semantics as the PJRT artifact path).
        let u = self.uniq_ids.len();
        if self.wo_uniq.len() < u * d {
            self.wo_uniq.resize(u * d, 0.0);
            self.dwo_uniq.resize(u * d, 0.0);
        }
        for (slot, &id) in self.uniq_ids.iter().enumerate() {
            // SAFETY: Hogwild contract (model::hogwild docs).
            let row = unsafe { model.row_out(id) };
            self.wo_uniq[slot * d..(slot + 1) * d].copy_from_slice(row);
        }
        self.dwo_uniq[..u * d].fill(0.0);

        let fused = self.use_fused();
        for w in 0..arena.len() {
            let b = arena.inputs_of(w).len();
            debug_assert!(b >= 1 && b <= arena.b_cap());

            // Gather Wi fresh per window (context rows rarely repeat).
            for (i, &inp) in arena.inputs_of(w).iter().enumerate() {
                // SAFETY: Hogwild contract.
                let row = unsafe { model.row_in(inp) };
                self.wi[i * d..(i + 1) * d].copy_from_slice(row);
            }

            if fused {
                // One single-pass kernel call that reads Wo rows and
                // accumulates dWo THROUGH the dedup slots — no per-window
                // Wo block assembly, no per-window dWo accumulation pass.
                simd::sgns_fused(
                    s,
                    d,
                    lr,
                    &self.wi[..b * d],
                    &self.wo_uniq[..u * d],
                    &self.out_slots[w * s..(w + 1) * s],
                    &mut self.logits[..b * s],
                    &mut self.dwi[..b * d],
                    &mut self.dwo_uniq[..u * d],
                );
                self.scatter_dwi(model, arena.inputs_of(w));
                continue;
            }

            // Assemble the window's Wo block from the L1-hot dedup copy.
            let slots = &self.out_slots[w * s..(w + 1) * s];
            for (j, &slot) in slots.iter().enumerate() {
                let src = slot as usize * d;
                self.wo[j * d..(j + 1) * d]
                    .copy_from_slice(&self.wo_uniq[src..src + d]);
            }

            simd::gemm_nt(
                b,
                s,
                d,
                1.0,
                &self.wi[..b * d],
                &self.wo[..s * d],
                0.0,
                &mut self.logits[..b * s],
            );
            self.err_inplace(b, s, lr);
            simd::gemm_nn(
                b,
                d,
                s,
                1.0,
                &self.logits[..b * s],
                &self.wo[..s * d],
                0.0,
                &mut self.dwi[..b * d],
            );
            simd::gemm_tn(
                s,
                d,
                b,
                1.0,
                &self.logits[..b * s],
                &self.wi[..b * d],
                0.0,
                &mut self.dwo[..s * d],
            );

            // Wi scatters stay per window; dWo accumulates per slot.
            self.scatter_dwi(model, arena.inputs_of(w));
            let slots = &self.out_slots[w * s..(w + 1) * s];
            for (j, &slot) in slots.iter().enumerate() {
                let dst = slot as usize * d;
                simd::axpy(
                    1.0,
                    &self.dwo[j * d..(j + 1) * d],
                    &mut self.dwo_uniq[dst..dst + d],
                );
            }
        }

        // One Hogwild update per distinct output id per superbatch.
        for (slot, &id) in self.uniq_ids.iter().enumerate() {
            let delta = &mut self.dwo_uniq[slot * d..(slot + 1) * d];
            match &self.rule {
                UpdateRule::Plain => {}
                UpdateRule::Adagrad(ag) => ag.adjust_out(id, delta),
                UpdateRule::Rmsprop(rp) => rp.adjust_out(id, delta),
            }
            model.add_out(id, delta);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "gemm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SharedModel;
    use crate::linalg::sigmoid::sigmoid_exact;
    use crate::linalg::vecops::dot;
    use crate::sampling::batch::SuperbatchArena;

    fn window(inputs: &[u32], target: u32, negs: &[u32]) -> Window {
        let mut outputs = vec![target];
        outputs.extend_from_slice(negs);
        Window {
            inputs: inputs.to_vec(),
            outputs,
        }
    }

    fn arena_of(windows: &[Window], b_cap: usize, s: usize) -> SuperbatchArena {
        let mut a = SuperbatchArena::new(b_cap, s);
        for w in windows {
            a.push_window(&w.inputs, &w.outputs);
        }
        a
    }

    /// The GEMM backend must produce EXACTLY the same deltas as a naive
    /// per-pair computation with end-of-window updates (the semantics the
    /// python oracle also checks for the kernel).
    #[test]
    fn matches_naive_end_of_window_semantics() {
        let dim = 24;
        let model_g = SharedModel::init(40, dim, 11);
        let model_n = SharedModel::init(40, dim, 11); // same seed => same init
        let w = window(&[1, 2, 3, 4], 10, &[20, 21, 22, 23, 24]);
        let lr = 0.07f32;

        let mut g = GemmBackend::new(dim, 16, 6);
        g.process(model_g.store(), std::slice::from_ref(&w), lr).unwrap();

        // Naive: compute ALL gradients from pre-update state, apply at end.
        let b = w.inputs.len();
        let s = w.outputs.len();
        let mut dwi = vec![0.0f32; b * dim];
        let mut dwo = vec![0.0f32; s * dim];
        for (i, &inp) in w.inputs.iter().enumerate() {
            for (j, &out) in w.outputs.iter().enumerate() {
                let wi = model_n.m_in().row(inp);
                let wo = model_n.m_out().row(out);
                let label = if j == 0 { 1.0 } else { 0.0 };
                let gld = (label - sigmoid_exact(dot(wi, wo))) * lr;
                for l in 0..dim {
                    dwi[i * dim + l] += gld * wo[l];
                    dwo[j * dim + l] += gld * wi[l];
                }
            }
        }
        for (i, &inp) in w.inputs.iter().enumerate() {
            model_n.add_in(inp, &dwi[i * dim..(i + 1) * dim]);
        }
        for (j, &out) in w.outputs.iter().enumerate() {
            model_n.add_out(out, &dwo[j * dim..(j + 1) * dim]);
        }

        for r in 0..40u32 {
            let (a, b_) = (model_g.m_in().row(r), model_n.m_in().row(r));
            for l in 0..dim {
                assert!((a[l] - b_[l]).abs() < 1e-5, "m_in row {r} dim {l}");
            }
            let (a, b_) = (model_g.m_out().row(r), model_n.m_out().row(r));
            for l in 0..dim {
                assert!((a[l] - b_[l]).abs() < 1e-5, "m_out row {r} dim {l}");
            }
        }
    }

    /// For a SINGLE window the arena path must equal the window path
    /// (dedup + deferred dWo scatter collapse to the same computation).
    #[test]
    fn arena_single_window_matches_process() {
        let dim = 24;
        let model_w = SharedModel::init(40, dim, 31);
        let model_a = SharedModel::init(40, dim, 31);
        // Duplicate negative (21 twice) exercises the dedup accumulation.
        let w = window(&[1, 2, 3], 10, &[20, 21, 21, 22, 23]);
        let mut g1 = GemmBackend::new(dim, 16, 6);
        let mut g2 = GemmBackend::new(dim, 16, 6);
        g1.process(model_w.store(), std::slice::from_ref(&w), 0.05).unwrap();
        let arena = arena_of(std::slice::from_ref(&w), 16, 6);
        g2.process_arena(model_a.store(), &arena, 0.05).unwrap();
        for r in 0..40u32 {
            for (x, y) in model_w.m_in().row(r).iter().zip(model_a.m_in().row(r)) {
                assert!((x - y).abs() < 1e-6, "m_in row {r}");
            }
            for (x, y) in model_w.m_out().row(r).iter().zip(model_a.m_out().row(r)) {
                assert!((x - y).abs() < 1e-6, "m_out row {r}");
            }
        }
    }

    /// Multi-window arena: same gradients as the naive end-of-superbatch
    /// computation (all reads from pre-superbatch state for Wo, fresh Wi).
    #[test]
    fn arena_learns_and_dedups() {
        let dim = 16;
        let model = SharedModel::init(30, dim, 5);
        // Shared negatives repeat across windows: 6 windows, negatives all
        // drawn from {20..25}.
        let windows: Vec<Window> = (0..6u32)
            .map(|t| window(&[t + 1, t + 2], t + 10, &[20, 21, 22, 23, 24]))
            .collect();
        let arena = arena_of(&windows, 16, 6);
        let mut g = GemmBackend::new(dim, 16, 6);
        let before = crate::train::ns_objective(&model, &windows);
        for _ in 0..200 {
            g.process_arena(model.store(), &arena, 0.05).unwrap();
        }
        let after = crate::train::ns_objective(&model, &windows);
        assert!(after > before, "{before} -> {after}");
        let sim = |a: u32, b_: u32| dot(model.m_in().row(a), model.m_out().row(b_));
        assert!(sim(1, 10) > 0.5);
        assert!(sim(1, 20) < 0.1);
    }

    /// The EXP_TABLE sigmoid mode trains equivalently to exact at window
    /// scale (the table is a ≲2e-3 approximation).
    #[test]
    fn sigmoid_table_mode_close_to_exact() {
        let dim = 16;
        let m_exact = SharedModel::init(30, dim, 8);
        let m_table = SharedModel::init(30, dim, 8);
        let w = window(&[1, 2, 3], 10, &[20, 21, 22, 23, 24]);
        let mut ge = GemmBackend::new(dim, 16, 6).with_sigmoid(SigmoidMode::Exact);
        let mut gt = GemmBackend::new(dim, 16, 6).with_sigmoid(SigmoidMode::Table);
        for _ in 0..50 {
            ge.process(m_exact.store(), std::slice::from_ref(&w), 0.05).unwrap();
            gt.process(m_table.store(), std::slice::from_ref(&w), 0.05).unwrap();
        }
        for r in 0..30u32 {
            for (x, y) in m_exact.m_in().row(r).iter().zip(m_table.m_in().row(r)) {
                assert!((x - y).abs() < 0.02, "row {r}: {x} vs {y}");
            }
        }
        // And the table mode must actually learn.
        let sim = dot(m_table.m_in().row(1), m_table.m_out().row(10));
        assert!(sim > 0.4, "table-mode sim {sim}");
    }

    /// The fused single-pass kernel and the ablation-preserved gemm3
    /// chain must train the same model, window path and arena path alike
    /// (the arena case exercises slot-indirected reads/accumulation and a
    /// duplicated negative, i.e. the kernel's sequential fallback).
    #[test]
    fn fused_matches_gemm3_both_paths() {
        let dim = 24;
        let lr = 0.05f32;
        let windows = vec![
            window(&[1, 2, 3], 10, &[20, 21, 21, 22, 23]), // dup negative
            window(&[4], 11, &[20, 24, 25, 26, 27]),
            window(&[5, 6, 7, 8], 12, &[21, 22, 28, 29, 20]),
        ];
        for arena_path in [false, true] {
            let mut m_fused = SharedModel::init(40, dim, 77);
            let mut m_gemm3 = SharedModel::init(40, dim, 77);
            // Prewarm M_out identically (word2vec zero-init would zero
            // every dWi and hide the input-gradient half of the kernel).
            for m in [&mut m_fused, &mut m_gemm3] {
                for r in 0..40u32 {
                    for (i, x) in
                        m.m_out_mut().row_mut(r).iter_mut().enumerate()
                    {
                        *x = 0.02
                            * ((r as f32) - 19.5)
                            * if i % 2 == 0 { 0.05 } else { -0.05 };
                    }
                }
            }
            let mut gf =
                GemmBackend::new(dim, 16, 6).with_kernel(KernelMode::Fused);
            let mut g3 =
                GemmBackend::new(dim, 16, 6).with_kernel(KernelMode::Gemm3);
            if arena_path {
                let arena = arena_of(&windows, 16, 6);
                gf.process_arena(m_fused.store(), &arena, lr).unwrap();
                g3.process_arena(m_gemm3.store(), &arena, lr).unwrap();
            } else {
                gf.process(m_fused.store(), &windows, lr).unwrap();
                g3.process(m_gemm3.store(), &windows, lr).unwrap();
            }
            let mut moved = false;
            let init = SharedModel::init(40, dim, 77);
            for r in 0..40u32 {
                for (x, y) in
                    m_fused.m_in().row(r).iter().zip(m_gemm3.m_in().row(r))
                {
                    assert!(
                        (x - y).abs() < 1e-5,
                        "arena={arena_path} m_in row {r}: {x} vs {y}"
                    );
                }
                for (x, y) in
                    m_fused.m_out().row(r).iter().zip(m_gemm3.m_out().row(r))
                {
                    assert!(
                        (x - y).abs() < 1e-5,
                        "arena={arena_path} m_out row {r}: {x} vs {y}"
                    );
                }
                moved |= m_fused
                    .m_in()
                    .row(r)
                    .iter()
                    .zip(init.m_in().row(r))
                    .any(|(a, b)| (a - b).abs() > 1e-6);
            }
            assert!(moved, "arena={arena_path}: model did not move");
        }
    }

    /// `--sigmoid table` forces the gemm3 chain even under kernel Auto
    /// (the fused kernel evaluates the exact sigmoid only) — the model
    /// must still train.
    #[test]
    fn table_sigmoid_takes_gemm3_path_under_auto() {
        let dim = 16;
        let model = SharedModel::init(30, dim, 8);
        let mut g = GemmBackend::new(dim, 16, 6)
            .with_kernel(KernelMode::Auto)
            .with_sigmoid(SigmoidMode::Table);
        assert!(!g.use_fused());
        let w = window(&[1, 2, 3], 10, &[20, 21, 22, 23, 24]);
        let arena = arena_of(std::slice::from_ref(&w), 16, 6);
        for _ in 0..50 {
            g.process_arena(model.store(), &arena, 0.05).unwrap();
        }
        let sim = dot(model.m_in().row(1), model.m_out().row(10));
        assert!(sim > 0.4, "table-under-auto sim {sim}");
    }

    #[test]
    fn learns_positive_pairs() {
        let model = SharedModel::init(20, 16, 3);
        let mut g = GemmBackend::new(16, 16, 6);
        let w = window(&[1, 2, 3], 10, &[11, 12, 13, 14, 15]);
        let sim = |a: u32, b_: u32| dot(model.m_in().row(a), model.m_out().row(b_));
        for _ in 0..300 {
            g.process(model.store(), std::slice::from_ref(&w), 0.05).unwrap();
        }
        assert!(sim(1, 10) > 0.5);
        assert!(sim(1, 11) < 0.1);
    }

    #[test]
    fn duplicate_input_words_accumulate() {
        // The same word appearing twice in the batch gets both deltas
        // (scatter-ADD, not overwrite).
        let dim = 8;
        let model = SharedModel::init(10, dim, 9);
        let w_dup = window(&[1, 1], 5, &[6, 7]);
        let w_single = window(&[1], 5, &[6, 7]);

        let model_single = SharedModel::init(10, dim, 9);
        let mut g1 = GemmBackend::new(dim, 16, 6);
        let mut g2 = GemmBackend::new(dim, 16, 6);
        g1.process(model.store(), &[w_dup], 0.05).unwrap();
        g2.process(model_single.store(), &[w_single], 0.05).unwrap();
        // Dup delta on M_in[1] must be ~2x the single delta.
        let base = SharedModel::init(10, dim, 9);
        let d_dup: Vec<f32> = model
            .m_in()
            .row(1)
            .iter()
            .zip(base.m_in().row(1))
            .map(|(a, b)| a - b)
            .collect();
        let d_single: Vec<f32> = model_single
            .m_in()
            .row(1)
            .iter()
            .zip(base.m_in().row(1))
            .map(|(a, b)| a - b)
            .collect();
        for l in 0..dim {
            assert!((d_dup[l] - 2.0 * d_single[l]).abs() < 1e-6, "dim {l}");
        }
    }

    #[test]
    fn adagrad_rule_damps_over_time() {
        let dim = 8;
        let mut model = SharedModel::init(10, dim, 13);
        // Prewarm M_out (word2vec zero-init would make the first dwi zero
        // and hide the damping behaviour under test).
        for r in 0..10u32 {
            for (i, x) in model.m_out_mut().row_mut(r).iter_mut().enumerate() {
                *x = 0.05 * ((r as f32) - 4.0) * if i % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let ag = Arc::new(AdaGrad::new(10, dim));
        let mut g =
            GemmBackend::new(dim, 16, 6).with_rule(UpdateRule::Adagrad(ag));
        let w = window(&[1], 5, &[6, 7]);
        let mut deltas = Vec::new();
        let mut prev = model.m_in().row(1).to_vec();
        for _ in 0..5 {
            g.process(model.store(), std::slice::from_ref(&w), 0.05).unwrap();
            let cur = model.m_in().row(1).to_vec();
            let step: f32 = cur
                .iter()
                .zip(&prev)
                .map(|(a, b)| (a - b).abs())
                .sum();
            deltas.push(step);
            prev = cur;
        }
        // First adjusted step is the sign-normalised AdaGrad step; later
        // steps must shrink as the accumulator grows.
        assert!(deltas[0] > 0.0, "{deltas:?}");
        assert!(deltas[4] < deltas[0] * 0.9, "should shrink: {deltas:?}");
    }
}
