//! THE PAPER'S SCHEME (Sec. III-B/C): minibatched, shared-negative-sample
//! SGNS with all model updates deferred to the end of the window block,
//! in one of two kernel organisations (`--kernel {auto,fused,gemm3}`):
//!
//! **gemm3** — three level-3 BLAS calls per window (Fig. 2 right),
//! preserved bit-for-bit from the pre-fusion crate for ablations:
//!
//! ```text
//! gather:  Wi[B,D] <- M_in[inputs],  Wo[S,D] <- M_out[target + negatives]
//! GEMM 1:  logits = Wi · Woᵀ                  (level-3, reuses Wo across B)
//! elem:    err    = (label - σ(logits)) · lr   (fused SIMD kernel)
//! GEMM 2:  dWi    = err · Wo
//! GEMM 3:  dWo    = errᵀ · Wi
//! scatter: M_in[inputs] += dWi rows, M_out[outputs] += dWo rows (Hogwild)
//! ```
//!
//! **fused** (default) — ONE call to [`simd::sgns_fused`] per window: the
//! dot products, the `(label − σ)·lr` error, and both gradient
//! accumulations happen in the same register tiles, so the gathered
//! blocks are swept ~once instead of three-plus times and the
//! `logits`/`err` intermediates never round-trip between kernels.  On the
//! arena path the kernel additionally reads `Wo` rows and accumulates
//! `dWo` THROUGH the superbatch dedup slots, which deletes the per-window
//! `Wo` block assembly copy and the per-window `dWo` accumulation pass
//! that the gemm3 chain needs.  The fused kernel evaluates the exact
//! sigmoid; under `--sigmoid table` the backend keeps the gemm3 chain
//! (`--kernel fused --sigmoid table` is rejected at config validation).
//!
//! All kernels go through [`crate::linalg::simd`], so the backend runs the
//! AVX2+FMA path on capable CPUs and the portable path under
//! `--simd scalar` (bit-identical to the pre-SIMD crate).
//!
//! Two processing surfaces:
//!
//! * [`Backend::process`] — window-at-a-time over `&[Window]` (reference
//!   semantics: each window gathers fresh rows, scatters immediately);
//! * [`Backend::process_arena`] — the trainer's zero-allocation superbatch
//!   path over a flat [`SuperbatchArena`].  `Wo` rows are gathered ONCE
//!   per superbatch per distinct id (shared negatives repeat heavily under
//!   the Zipf unigram distribution), window blocks are assembled from that
//!   L1-hot copy, and `dWo` accumulates per distinct id with a single
//!   Hogwild update at the end — extending the paper's Sec. III-C
//!   update-count reduction from the window to the superbatch.
//!
//! Optionally wraps the scatter in AdaGrad/RMSProp per-parameter rescaling
//! for the Sec. III-E ablation.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::Arc;

use super::lr::{AdaGrad, RmsProp};
use super::Backend;
use crate::config::{KernelMode, ReuseMode, SigmoidMode};
use crate::linalg::sigmoid::SigmoidTable;
use crate::linalg::simd;
use crate::model::ModelRef;
use crate::sampling::batch::{SuperbatchArena, Window};

/// FxHash-style multiply-mix hasher for the `u32` output-id dedup map:
/// SipHash (the `HashMap` default) is a measurable tax at millions of
/// lookups per second on exactly the hot path this backend optimises,
/// and hash-flooding resistance buys nothing against word ids.
#[derive(Default)]
struct FxU32Hasher(u64);

impl Hasher for FxU32Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.mix(n);
    }
}

impl FxU32Hasher {
    #[inline]
    fn mix(&mut self, n: u64) {
        self.0 = (self.0.rotate_left(5) ^ n).wrapping_mul(0x51_7c_c1_b7_27_22_0a_95);
    }
}

type FxU32Map<V> = HashMap<u32, V, BuildHasherDefault<FxU32Hasher>>;

/// Max windows per reuse run (`--reuse sentence`): bounds the scratch
/// growth (`RUN_CAP ×` the per-window `Wi`/`dWi`/`logits` blocks, sized
/// once in [`GemmBackend::with_reuse`]) and keeps a run's shared
/// negative rows + `dWo` accumulators register/L1-resident in the
/// vector run kernels.  Past ~8 windows the gathered context rows — not
/// the shared negatives — dominate the traffic, so longer runs stop
/// paying (EXPERIMENTS.md §Fused reuse).
const RUN_CAP: usize = 8;

/// Per-parameter update rule applied at scatter time.
#[derive(Clone, Default)]
pub enum UpdateRule {
    #[default]
    Plain,
    Adagrad(Arc<AdaGrad>),
    Rmsprop(Arc<RmsProp>),
}

pub struct GemmBackend {
    dim: usize,
    /// Scratch (per worker thread): gathered blocks + intermediates.
    wi: Vec<f32>,
    wo: Vec<f32>,
    logits: Vec<f32>,
    dwi: Vec<f32>,
    dwo: Vec<f32>,
    rule: UpdateRule,
    /// `Some` = EXP_TABLE sigmoid (config `sigmoid = table`); `None` =
    /// exact sigmoid through the fused SIMD kernel.
    sigmoid_table: Option<SigmoidTable>,
    /// Kernel organisation (`--kernel`); see [`Self::use_fused`].
    kernel: KernelMode,
    /// Negative-reuse driver (`--reuse`); see [`Self::process_arena_runs`].
    reuse: ReuseMode,
    /// CSR window→row offsets of the current reuse run (reused;
    /// steady-state allocation-free).
    run_offs: Vec<u32>,
    /// Identity slot map `0..s` for the fused window-at-a-time path
    /// (reused; steady-state allocation-free).
    win_slots: Vec<u32>,
    /// Superbatch dedup scratch (reused; steady-state allocation-free).
    uniq_ids: Vec<u32>,
    slot_of: FxU32Map<u32>,
    out_slots: Vec<u32>,
    wo_uniq: Vec<f32>,
    dwo_uniq: Vec<f32>,
}

impl GemmBackend {
    pub fn new(dim: usize, batch_cap: usize, samples: usize) -> Self {
        Self {
            dim,
            wi: vec![0.0; batch_cap * dim],
            wo: vec![0.0; samples * dim],
            logits: vec![0.0; batch_cap * samples],
            dwi: vec![0.0; batch_cap * dim],
            dwo: vec![0.0; samples * dim],
            rule: UpdateRule::Plain,
            sigmoid_table: None,
            kernel: KernelMode::Auto,
            reuse: ReuseMode::Off,
            run_offs: Vec::new(),
            win_slots: Vec::new(),
            uniq_ids: Vec::new(),
            slot_of: FxU32Map::default(),
            out_slots: Vec::new(),
            wo_uniq: Vec::new(),
            dwo_uniq: Vec::new(),
        }
    }

    pub fn with_rule(mut self, rule: UpdateRule) -> Self {
        self.rule = rule;
        self
    }

    /// Select the sigmoid the fused error kernel evaluates.
    pub fn with_sigmoid(mut self, mode: SigmoidMode) -> Self {
        self.sigmoid_table = match mode {
            SigmoidMode::Exact => None,
            SigmoidMode::Table => Some(SigmoidTable::default_table()),
        };
        self
    }

    /// Select the kernel organisation (`--kernel`).
    pub fn with_kernel(mut self, kernel: KernelMode) -> Self {
        self.kernel = kernel;
        self
    }

    /// Select the negative-reuse driver (`--reuse`).  `Sentence` grows
    /// the per-window scratch to hold a whole run ([`RUN_CAP`] windows
    /// of `Wi`/`dWi`/`logits` rows) HERE, at construction, so the run
    /// path stays allocation-free at steady state
    /// (`tests/alloc_steadystate.rs`); `Window` keeps the per-window
    /// sizing — its runs never exceed one window.
    pub fn with_reuse(mut self, reuse: ReuseMode) -> Self {
        if reuse == ReuseMode::Sentence && self.reuse != ReuseMode::Sentence {
            let wi_len = self.wi.len();
            self.wi.resize(wi_len * RUN_CAP, 0.0);
            let dwi_len = self.dwi.len();
            self.dwi.resize(dwi_len * RUN_CAP, 0.0);
            let logits_len = self.logits.len();
            self.logits.resize(logits_len * RUN_CAP, 0.0);
            self.run_offs.reserve(RUN_CAP + 1);
        }
        self.reuse = reuse;
        self
    }

    /// The fused single-pass kernel runs unless the caller pinned `gemm3`
    /// or configured the EXP_TABLE sigmoid (the fused kernel evaluates
    /// the exact sigmoid only; the contradictory `--kernel fused
    /// --sigmoid table` is rejected by `TrainConfig::validate`).
    #[inline]
    fn use_fused(&self) -> bool {
        self.kernel != KernelMode::Gemm3 && self.sigmoid_table.is_none()
    }

    /// `logits[..b*s] <- (label - σ) · lr` under the configured sigmoid.
    #[inline]
    fn err_inplace(&mut self, b: usize, s: usize, lr: f32) {
        self.err_rows(0, b, s, lr);
    }

    /// The row-slice form of [`err_inplace`](Self::err_inplace) for
    /// run-gathered logits: `logits[lo*s..hi*s] <- (label - σ) · lr`.
    /// Each window's rows are a self-contained `s`-wide tile, so the
    /// label pattern is identical whatever `lo` is.
    #[inline]
    fn err_rows(&mut self, lo: usize, hi: usize, s: usize, lr: f32) {
        let logits = &mut self.logits[lo * s..hi * s];
        match &self.sigmoid_table {
            None => simd::sgns_err(logits, s, lr),
            Some(t) => {
                for (idx, x) in logits.iter_mut().enumerate() {
                    let label = if idx % s == 0 { 1.0 } else { 0.0 };
                    *x = (label - t.get(*x)) * lr;
                }
            }
        }
    }

    /// One window: gather → fused kernel (or 3-GEMM chain) → scatter.
    fn window(&mut self, model: ModelRef<'_>, w: &Window, lr: f32) {
        let d = self.dim;
        let b = w.inputs.len();
        let s = w.outputs.len();
        debug_assert!(b * d <= self.wi.len() && s * d <= self.wo.len());

        // Gather rows into contiguous blocks (the paper's "minibatching").
        for (i, &inp) in w.inputs.iter().enumerate() {
            // SAFETY: Hogwild contract (model::hogwild docs).
            let row = unsafe { model.row_in(inp) };
            self.wi[i * d..(i + 1) * d].copy_from_slice(row);
        }
        for (j, &out) in w.outputs.iter().enumerate() {
            // SAFETY: Hogwild contract.
            let row = unsafe { model.row_out(out) };
            self.wo[j * d..(j + 1) * d].copy_from_slice(row);
        }

        if self.use_fused() {
            // One single-pass kernel call over the gathered blocks
            // (identity slots: the window block IS the wo/dwo storage).
            self.win_slots.clear();
            self.win_slots.extend(0..s as u32);
            self.dwo[..s * d].fill(0.0);
            simd::sgns_fused(
                s,
                d,
                lr,
                &self.wi[..b * d],
                &self.wo[..s * d],
                &self.win_slots[..s],
                &mut self.logits[..b * s],
                &mut self.dwi[..b * d],
                &mut self.dwo[..s * d],
            );
        } else {
            // GEMM 1: logits = Wi · Woᵀ.
            simd::gemm_nt(
                b,
                s,
                d,
                1.0,
                &self.wi[..b * d],
                &self.wo[..s * d],
                0.0,
                &mut self.logits[..b * s],
            );

            // err = (label - sigma(logits)) * lr, in place.
            self.err_inplace(b, s, lr);

            // GEMM 2 + 3 from the PRE-update blocks.
            simd::gemm_nn(
                b,
                d,
                s,
                1.0,
                &self.logits[..b * s],
                &self.wo[..s * d],
                0.0,
                &mut self.dwi[..b * d],
            );
            simd::gemm_tn(
                s,
                d,
                b,
                1.0,
                &self.logits[..b * s],
                &self.wi[..b * d],
                0.0,
                &mut self.dwo[..s * d],
            );
        }

        // Scatter-add (one Hogwild update per touched row).
        self.scatter_dwi(model, &w.inputs);
        for (j, &out) in w.outputs.iter().enumerate() {
            let delta = &mut self.dwo[j * d..(j + 1) * d];
            match &self.rule {
                UpdateRule::Plain => {}
                UpdateRule::Adagrad(ag) => ag.adjust_out(out, delta),
                UpdateRule::Rmsprop(rp) => rp.adjust_out(out, delta),
            }
            model.add_out(out, delta);
        }
    }

    /// Scatter `dwi` rows for `inputs`, applying the update rule.
    fn scatter_dwi(&mut self, model: ModelRef<'_>, inputs: &[u32]) {
        self.scatter_dwi_from(model, inputs, 0);
    }

    /// The run-offset form of [`scatter_dwi`](Self::scatter_dwi):
    /// window rows live at `base..base+inputs.len()` of the gathered
    /// run block.
    fn scatter_dwi_from(
        &mut self,
        model: ModelRef<'_>,
        inputs: &[u32],
        base: usize,
    ) {
        let d = self.dim;
        for (i, &inp) in inputs.iter().enumerate() {
            let row = base + i;
            let delta = &mut self.dwi[row * d..(row + 1) * d];
            match &self.rule {
                UpdateRule::Plain => {}
                UpdateRule::Adagrad(ag) => ag.adjust_in(inp, delta),
                UpdateRule::Rmsprop(rp) => rp.adjust_in(inp, delta),
            }
            model.add_in(inp, delta);
        }
    }

    /// Reuse-path driver (`--reuse {window,sentence}`): walk the arena
    /// in maximal RUNS of consecutive windows licensed to share one
    /// negative set, gather each run's `Wi` rows back to back, hand
    /// fused runs to [`simd::sgns_fused_run`] as ONE call, and defer
    /// the input-row scatter to the end of the run — the FULL-W2V
    /// lifetime extension: the shared negative rows and their `dWo`
    /// accumulators stay register/L1-resident across the whole run
    /// instead of being re-streamed per window.
    ///
    /// A run grows past its head window only while ALL of:
    ///
    /// * same sentence serial ([`SuperbatchArena::sentence_of`]) — the
    ///   builder only shares draws within a sentence;
    /// * identical negative slots (`slots[1..]` equality — the
    ///   authoritative check, which also backstops sentence-serial wrap
    ///   collisions);
    /// * duplicate-free slots on BOTH sides (a positive colliding with
    ///   a shared negative routes that window into its own singleton
    ///   run, where the window kernel's sequential-fallback semantics
    ///   apply);
    /// * run length < [`RUN_CAP`].
    ///
    /// Under `ReuseMode::Window` the cap is 1: every window is its own
    /// run, and a one-window run is BITWISE the `Off` path (same
    /// gathers, same kernel call — the run kernels delegate `R == 1` to
    /// the window kernel — same scatter), so `--reuse window` isolates
    /// pure driver overhead for the ablation.  Deferring the input
    /// scatter to run end matches the scalar reference
    /// [`crate::linalg::simd::scalar::sgns_fused_run`]: a run's rows
    /// are all read up front, so an input repeating across a run's
    /// windows accumulates every gradient against the same pre-run row.
    fn process_arena_runs(
        &mut self,
        model: ModelRef<'_>,
        arena: &SuperbatchArena,
        lr: f32,
        fused: bool,
    ) {
        fn has_dup(sl: &[u32]) -> bool {
            sl.iter().enumerate().any(|(j, x)| sl[..j].contains(x))
        }
        let d = self.dim;
        let s = arena.s();
        let n = arena.len();
        let u = self.uniq_ids.len();
        let run_cap = match self.reuse {
            ReuseMode::Sentence => RUN_CAP,
            _ => 1,
        };
        let mut w = 0;
        while w < n {
            // Grow the run (reads only slots + serials; no model state).
            let mut r_n = 1;
            {
                let head = &self.out_slots[w * s..(w + 1) * s];
                if !has_dup(head) {
                    while r_n < run_cap && w + r_n < n {
                        let r = w + r_n;
                        if arena.sentence_of(r) != arena.sentence_of(w) {
                            break;
                        }
                        let sl = &self.out_slots[r * s..(r + 1) * s];
                        if sl[1..] != head[1..] || has_dup(sl) {
                            break;
                        }
                        r_n += 1;
                    }
                }
            }

            // Gather the run's Wi rows back to back; `run_offs` holds
            // the CSR window→row offsets the run kernel consumes.
            self.run_offs.clear();
            self.run_offs.push(0);
            let mut rows = 0usize;
            for win in w..w + r_n {
                for &inp in arena.inputs_of(win) {
                    // SAFETY: Hogwild contract (model::hogwild docs).
                    let row = unsafe { model.row_in(inp) };
                    self.wi[rows * d..(rows + 1) * d].copy_from_slice(row);
                    rows += 1;
                }
                self.run_offs.push(rows as u32);
            }
            debug_assert!(rows * d <= self.wi.len(), "run exceeds scratch");

            if fused {
                // ONE call per run: negatives' Wo rows + dWo slot
                // accumulators live across all r_n windows.
                simd::sgns_fused_run(
                    s,
                    d,
                    lr,
                    &self.wi[..rows * d],
                    &self.run_offs,
                    &self.wo_uniq[..u * d],
                    &self.out_slots[w * s..(w + r_n) * s],
                    &mut self.logits[..rows * s],
                    &mut self.dwi[..rows * d],
                    &mut self.dwo_uniq[..u * d],
                );
            } else {
                // gemm3 ablation under reuse: per-window 3-GEMM chain
                // over slices of the gathered run — identical per-window
                // math to the Off path, so fused-vs-gemm3 comparisons
                // stay apples-to-apples at every reuse setting.
                for k in 0..r_n {
                    let lo = self.run_offs[k] as usize;
                    let hi = self.run_offs[k + 1] as usize;
                    let b = hi - lo;
                    let win = w + k;
                    {
                        let slots = &self.out_slots[win * s..(win + 1) * s];
                        for (j, &slot) in slots.iter().enumerate() {
                            let src = slot as usize * d;
                            self.wo[j * d..(j + 1) * d]
                                .copy_from_slice(&self.wo_uniq[src..src + d]);
                        }
                    }
                    simd::gemm_nt(
                        b,
                        s,
                        d,
                        1.0,
                        &self.wi[lo * d..hi * d],
                        &self.wo[..s * d],
                        0.0,
                        &mut self.logits[lo * s..hi * s],
                    );
                    self.err_rows(lo, hi, s, lr);
                    simd::gemm_nn(
                        b,
                        d,
                        s,
                        1.0,
                        &self.logits[lo * s..hi * s],
                        &self.wo[..s * d],
                        0.0,
                        &mut self.dwi[lo * d..hi * d],
                    );
                    simd::gemm_tn(
                        s,
                        d,
                        b,
                        1.0,
                        &self.logits[lo * s..hi * s],
                        &self.wi[lo * d..hi * d],
                        0.0,
                        &mut self.dwo[..s * d],
                    );
                    let slots = &self.out_slots[win * s..(win + 1) * s];
                    for (j, &slot) in slots.iter().enumerate() {
                        let dst = slot as usize * d;
                        simd::axpy(
                            1.0,
                            &self.dwo[j * d..(j + 1) * d],
                            &mut self.dwo_uniq[dst..dst + d],
                        );
                    }
                }
            }

            // Deferred input scatter: after the WHOLE run, matching the
            // up-front gather above (run-kernel reference semantics).
            for k in 0..r_n {
                let base = self.run_offs[k] as usize;
                self.scatter_dwi_from(model, arena.inputs_of(w + k), base);
            }
            w += r_n;
        }
    }
}

impl Backend for GemmBackend {
    fn process(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        for w in windows {
            anyhow::ensure!(
                w.inputs.len() * self.dim <= self.wi.len()
                    && w.outputs.len() * self.dim <= self.wo.len(),
                "window exceeds backend capacity"
            );
            self.window(model, w, lr);
        }
        Ok(())
    }

    /// Flat superbatch path: zero allocations at steady state, one `Wo`
    /// gather and one `dWo` Hogwild update per DISTINCT output id per
    /// superbatch.
    fn process_arena(
        &mut self,
        model: ModelRef<'_>,
        arena: &SuperbatchArena,
        lr: f32,
    ) -> anyhow::Result<()> {
        let d = self.dim;
        let s = arena.s();
        anyhow::ensure!(
            s * d <= self.wo.len() && arena.b_cap() * d <= self.wi.len(),
            "arena geometry exceeds backend capacity"
        );

        // Deduplicate output ids across the whole superbatch.
        self.slot_of.clear();
        self.uniq_ids.clear();
        self.out_slots.clear();
        {
            let uniq = &mut self.uniq_ids;
            let slots = &mut self.out_slots;
            let map = &mut self.slot_of;
            for &id in arena.outputs_flat() {
                let slot = *map.entry(id).or_insert_with(|| {
                    let next = uniq.len() as u32;
                    uniq.push(id);
                    next
                });
                slots.push(slot);
            }
        }

        // Gather each distinct Wo row ONCE (pre-superbatch state — the
        // same deferred-read semantics as the PJRT artifact path).
        let u = self.uniq_ids.len();
        if self.wo_uniq.len() < u * d {
            self.wo_uniq.resize(u * d, 0.0);
            self.dwo_uniq.resize(u * d, 0.0);
        }
        for (slot, &id) in self.uniq_ids.iter().enumerate() {
            // SAFETY: Hogwild contract (model::hogwild docs).
            let row = unsafe { model.row_out(id) };
            self.wo_uniq[slot * d..(slot + 1) * d].copy_from_slice(row);
        }
        self.dwo_uniq[..u * d].fill(0.0);

        let fused = self.use_fused();
        if self.reuse != ReuseMode::Off {
            // FULL-W2V-style run driver: group consecutive windows that
            // share one negative set and extend the gathered rows' /
            // accumulators' lifetime across the whole run.
            self.process_arena_runs(model, arena, lr, fused);
        } else {
            for w in 0..arena.len() {
                let b = arena.inputs_of(w).len();
                debug_assert!(b >= 1 && b <= arena.b_cap());

                // Gather Wi fresh per window (context rows rarely repeat).
                for (i, &inp) in arena.inputs_of(w).iter().enumerate() {
                    // SAFETY: Hogwild contract.
                    let row = unsafe { model.row_in(inp) };
                    self.wi[i * d..(i + 1) * d].copy_from_slice(row);
                }

                if fused {
                    // One single-pass kernel call that reads Wo rows and
                    // accumulates dWo THROUGH the dedup slots — no per-window
                    // Wo block assembly, no per-window dWo accumulation pass.
                    simd::sgns_fused(
                        s,
                        d,
                        lr,
                        &self.wi[..b * d],
                        &self.wo_uniq[..u * d],
                        &self.out_slots[w * s..(w + 1) * s],
                        &mut self.logits[..b * s],
                        &mut self.dwi[..b * d],
                        &mut self.dwo_uniq[..u * d],
                    );
                    self.scatter_dwi(model, arena.inputs_of(w));
                    continue;
                }

                // Assemble the window's Wo block from the L1-hot dedup copy.
                let slots = &self.out_slots[w * s..(w + 1) * s];
                for (j, &slot) in slots.iter().enumerate() {
                    let src = slot as usize * d;
                    self.wo[j * d..(j + 1) * d]
                        .copy_from_slice(&self.wo_uniq[src..src + d]);
                }

                simd::gemm_nt(
                    b,
                    s,
                    d,
                    1.0,
                    &self.wi[..b * d],
                    &self.wo[..s * d],
                    0.0,
                    &mut self.logits[..b * s],
                );
                self.err_inplace(b, s, lr);
                simd::gemm_nn(
                    b,
                    d,
                    s,
                    1.0,
                    &self.logits[..b * s],
                    &self.wo[..s * d],
                    0.0,
                    &mut self.dwi[..b * d],
                );
                simd::gemm_tn(
                    s,
                    d,
                    b,
                    1.0,
                    &self.logits[..b * s],
                    &self.wi[..b * d],
                    0.0,
                    &mut self.dwo[..s * d],
                );

                // Wi scatters stay per window; dWo accumulates per slot.
                self.scatter_dwi(model, arena.inputs_of(w));
                let slots = &self.out_slots[w * s..(w + 1) * s];
                for (j, &slot) in slots.iter().enumerate() {
                    let dst = slot as usize * d;
                    simd::axpy(
                        1.0,
                        &self.dwo[j * d..(j + 1) * d],
                        &mut self.dwo_uniq[dst..dst + d],
                    );
                }
            }
        }

        // One Hogwild update per distinct output id per superbatch.
        for (slot, &id) in self.uniq_ids.iter().enumerate() {
            let delta = &mut self.dwo_uniq[slot * d..(slot + 1) * d];
            match &self.rule {
                UpdateRule::Plain => {}
                UpdateRule::Adagrad(ag) => ag.adjust_out(id, delta),
                UpdateRule::Rmsprop(rp) => rp.adjust_out(id, delta),
            }
            model.add_out(id, delta);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "gemm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SharedModel;
    use crate::linalg::sigmoid::sigmoid_exact;
    use crate::linalg::vecops::dot;
    use crate::sampling::batch::SuperbatchArena;

    fn window(inputs: &[u32], target: u32, negs: &[u32]) -> Window {
        let mut outputs = vec![target];
        outputs.extend_from_slice(negs);
        Window {
            inputs: inputs.to_vec(),
            outputs,
        }
    }

    fn arena_of(windows: &[Window], b_cap: usize, s: usize) -> SuperbatchArena {
        let mut a = SuperbatchArena::new(b_cap, s);
        for w in windows {
            a.push_window(&w.inputs, &w.outputs);
        }
        a
    }

    /// The GEMM backend must produce EXACTLY the same deltas as a naive
    /// per-pair computation with end-of-window updates (the semantics the
    /// python oracle also checks for the kernel).
    #[test]
    fn matches_naive_end_of_window_semantics() {
        let dim = 24;
        let model_g = SharedModel::init(40, dim, 11);
        let model_n = SharedModel::init(40, dim, 11); // same seed => same init
        let w = window(&[1, 2, 3, 4], 10, &[20, 21, 22, 23, 24]);
        let lr = 0.07f32;

        let mut g = GemmBackend::new(dim, 16, 6);
        g.process(model_g.store(), std::slice::from_ref(&w), lr).unwrap();

        // Naive: compute ALL gradients from pre-update state, apply at end.
        let b = w.inputs.len();
        let s = w.outputs.len();
        let mut dwi = vec![0.0f32; b * dim];
        let mut dwo = vec![0.0f32; s * dim];
        for (i, &inp) in w.inputs.iter().enumerate() {
            for (j, &out) in w.outputs.iter().enumerate() {
                let wi = model_n.m_in().row(inp);
                let wo = model_n.m_out().row(out);
                let label = if j == 0 { 1.0 } else { 0.0 };
                let gld = (label - sigmoid_exact(dot(wi, wo))) * lr;
                for l in 0..dim {
                    dwi[i * dim + l] += gld * wo[l];
                    dwo[j * dim + l] += gld * wi[l];
                }
            }
        }
        for (i, &inp) in w.inputs.iter().enumerate() {
            model_n.add_in(inp, &dwi[i * dim..(i + 1) * dim]);
        }
        for (j, &out) in w.outputs.iter().enumerate() {
            model_n.add_out(out, &dwo[j * dim..(j + 1) * dim]);
        }

        for r in 0..40u32 {
            let (a, b_) = (model_g.m_in().row(r), model_n.m_in().row(r));
            for l in 0..dim {
                assert!((a[l] - b_[l]).abs() < 1e-5, "m_in row {r} dim {l}");
            }
            let (a, b_) = (model_g.m_out().row(r), model_n.m_out().row(r));
            for l in 0..dim {
                assert!((a[l] - b_[l]).abs() < 1e-5, "m_out row {r} dim {l}");
            }
        }
    }

    /// For a SINGLE window the arena path must equal the window path
    /// (dedup + deferred dWo scatter collapse to the same computation).
    #[test]
    fn arena_single_window_matches_process() {
        let dim = 24;
        let model_w = SharedModel::init(40, dim, 31);
        let model_a = SharedModel::init(40, dim, 31);
        // Duplicate negative (21 twice) exercises the dedup accumulation.
        let w = window(&[1, 2, 3], 10, &[20, 21, 21, 22, 23]);
        let mut g1 = GemmBackend::new(dim, 16, 6);
        let mut g2 = GemmBackend::new(dim, 16, 6);
        g1.process(model_w.store(), std::slice::from_ref(&w), 0.05).unwrap();
        let arena = arena_of(std::slice::from_ref(&w), 16, 6);
        g2.process_arena(model_a.store(), &arena, 0.05).unwrap();
        for r in 0..40u32 {
            for (x, y) in model_w.m_in().row(r).iter().zip(model_a.m_in().row(r)) {
                assert!((x - y).abs() < 1e-6, "m_in row {r}");
            }
            for (x, y) in model_w.m_out().row(r).iter().zip(model_a.m_out().row(r)) {
                assert!((x - y).abs() < 1e-6, "m_out row {r}");
            }
        }
    }

    /// Multi-window arena: same gradients as the naive end-of-superbatch
    /// computation (all reads from pre-superbatch state for Wo, fresh Wi).
    #[test]
    fn arena_learns_and_dedups() {
        let dim = 16;
        let model = SharedModel::init(30, dim, 5);
        // Shared negatives repeat across windows: 6 windows, negatives all
        // drawn from {20..25}.
        let windows: Vec<Window> = (0..6u32)
            .map(|t| window(&[t + 1, t + 2], t + 10, &[20, 21, 22, 23, 24]))
            .collect();
        let arena = arena_of(&windows, 16, 6);
        let mut g = GemmBackend::new(dim, 16, 6);
        let before = crate::train::ns_objective(&model, &windows);
        for _ in 0..200 {
            g.process_arena(model.store(), &arena, 0.05).unwrap();
        }
        let after = crate::train::ns_objective(&model, &windows);
        assert!(after > before, "{before} -> {after}");
        let sim = |a: u32, b_: u32| dot(model.m_in().row(a), model.m_out().row(b_));
        assert!(sim(1, 10) > 0.5);
        assert!(sim(1, 20) < 0.1);
    }

    /// The EXP_TABLE sigmoid mode trains equivalently to exact at window
    /// scale (the table is a ≲2e-3 approximation).
    #[test]
    fn sigmoid_table_mode_close_to_exact() {
        let dim = 16;
        let m_exact = SharedModel::init(30, dim, 8);
        let m_table = SharedModel::init(30, dim, 8);
        let w = window(&[1, 2, 3], 10, &[20, 21, 22, 23, 24]);
        let mut ge = GemmBackend::new(dim, 16, 6).with_sigmoid(SigmoidMode::Exact);
        let mut gt = GemmBackend::new(dim, 16, 6).with_sigmoid(SigmoidMode::Table);
        for _ in 0..50 {
            ge.process(m_exact.store(), std::slice::from_ref(&w), 0.05).unwrap();
            gt.process(m_table.store(), std::slice::from_ref(&w), 0.05).unwrap();
        }
        for r in 0..30u32 {
            for (x, y) in m_exact.m_in().row(r).iter().zip(m_table.m_in().row(r)) {
                assert!((x - y).abs() < 0.02, "row {r}: {x} vs {y}");
            }
        }
        // And the table mode must actually learn.
        let sim = dot(m_table.m_in().row(1), m_table.m_out().row(10));
        assert!(sim > 0.4, "table-mode sim {sim}");
    }

    /// The fused single-pass kernel and the ablation-preserved gemm3
    /// chain must train the same model, window path and arena path alike
    /// (the arena case exercises slot-indirected reads/accumulation and a
    /// duplicated negative, i.e. the kernel's sequential fallback).
    #[test]
    fn fused_matches_gemm3_both_paths() {
        let dim = 24;
        let lr = 0.05f32;
        let windows = vec![
            window(&[1, 2, 3], 10, &[20, 21, 21, 22, 23]), // dup negative
            window(&[4], 11, &[20, 24, 25, 26, 27]),
            window(&[5, 6, 7, 8], 12, &[21, 22, 28, 29, 20]),
        ];
        for arena_path in [false, true] {
            let mut m_fused = SharedModel::init(40, dim, 77);
            let mut m_gemm3 = SharedModel::init(40, dim, 77);
            // Prewarm M_out identically (word2vec zero-init would zero
            // every dWi and hide the input-gradient half of the kernel).
            for m in [&mut m_fused, &mut m_gemm3] {
                for r in 0..40u32 {
                    for (i, x) in
                        m.m_out_mut().row_mut(r).iter_mut().enumerate()
                    {
                        *x = 0.02
                            * ((r as f32) - 19.5)
                            * if i % 2 == 0 { 0.05 } else { -0.05 };
                    }
                }
            }
            let mut gf =
                GemmBackend::new(dim, 16, 6).with_kernel(KernelMode::Fused);
            let mut g3 =
                GemmBackend::new(dim, 16, 6).with_kernel(KernelMode::Gemm3);
            if arena_path {
                let arena = arena_of(&windows, 16, 6);
                gf.process_arena(m_fused.store(), &arena, lr).unwrap();
                g3.process_arena(m_gemm3.store(), &arena, lr).unwrap();
            } else {
                gf.process(m_fused.store(), &windows, lr).unwrap();
                g3.process(m_gemm3.store(), &windows, lr).unwrap();
            }
            let mut moved = false;
            let init = SharedModel::init(40, dim, 77);
            for r in 0..40u32 {
                for (x, y) in
                    m_fused.m_in().row(r).iter().zip(m_gemm3.m_in().row(r))
                {
                    assert!(
                        (x - y).abs() < 1e-5,
                        "arena={arena_path} m_in row {r}: {x} vs {y}"
                    );
                }
                for (x, y) in
                    m_fused.m_out().row(r).iter().zip(m_gemm3.m_out().row(r))
                {
                    assert!(
                        (x - y).abs() < 1e-5,
                        "arena={arena_path} m_out row {r}: {x} vs {y}"
                    );
                }
                moved |= m_fused
                    .m_in()
                    .row(r)
                    .iter()
                    .zip(init.m_in().row(r))
                    .any(|(a, b)| (a - b).abs() > 1e-6);
            }
            assert!(moved, "arena={arena_path}: model did not move");
        }
    }

    /// `--sigmoid table` forces the gemm3 chain even under kernel Auto
    /// (the fused kernel evaluates the exact sigmoid only) — the model
    /// must still train.
    #[test]
    fn table_sigmoid_takes_gemm3_path_under_auto() {
        let dim = 16;
        let model = SharedModel::init(30, dim, 8);
        let mut g = GemmBackend::new(dim, 16, 6)
            .with_kernel(KernelMode::Auto)
            .with_sigmoid(SigmoidMode::Table);
        assert!(!g.use_fused());
        let w = window(&[1, 2, 3], 10, &[20, 21, 22, 23, 24]);
        let arena = arena_of(std::slice::from_ref(&w), 16, 6);
        for _ in 0..50 {
            g.process_arena(model.store(), &arena, 0.05).unwrap();
        }
        let sim = dot(model.m_in().row(1), model.m_out().row(10));
        assert!(sim > 0.4, "table-under-auto sim {sim}");
    }

    #[test]
    fn learns_positive_pairs() {
        let model = SharedModel::init(20, 16, 3);
        let mut g = GemmBackend::new(16, 16, 6);
        let w = window(&[1, 2, 3], 10, &[11, 12, 13, 14, 15]);
        let sim = |a: u32, b_: u32| dot(model.m_in().row(a), model.m_out().row(b_));
        for _ in 0..300 {
            g.process(model.store(), std::slice::from_ref(&w), 0.05).unwrap();
        }
        assert!(sim(1, 10) > 0.5);
        assert!(sim(1, 11) < 0.1);
    }

    #[test]
    fn duplicate_input_words_accumulate() {
        // The same word appearing twice in the batch gets both deltas
        // (scatter-ADD, not overwrite).
        let dim = 8;
        let model = SharedModel::init(10, dim, 9);
        let w_dup = window(&[1, 1], 5, &[6, 7]);
        let w_single = window(&[1], 5, &[6, 7]);

        let model_single = SharedModel::init(10, dim, 9);
        let mut g1 = GemmBackend::new(dim, 16, 6);
        let mut g2 = GemmBackend::new(dim, 16, 6);
        g1.process(model.store(), &[w_dup], 0.05).unwrap();
        g2.process(model_single.store(), &[w_single], 0.05).unwrap();
        // Dup delta on M_in[1] must be ~2x the single delta.
        let base = SharedModel::init(10, dim, 9);
        let d_dup: Vec<f32> = model
            .m_in()
            .row(1)
            .iter()
            .zip(base.m_in().row(1))
            .map(|(a, b)| a - b)
            .collect();
        let d_single: Vec<f32> = model_single
            .m_in()
            .row(1)
            .iter()
            .zip(base.m_in().row(1))
            .map(|(a, b)| a - b)
            .collect();
        for l in 0..dim {
            assert!((d_dup[l] - 2.0 * d_single[l]).abs() < 1e-6, "dim {l}");
        }
    }

    /// Deterministic M_out prewarm (word2vec zero-init would zero every
    /// dWi and hide the input-gradient half of the reuse driver).
    fn prewarm_out(m: &mut SharedModel, rows: u32) {
        for r in 0..rows {
            for (i, x) in m.m_out_mut().row_mut(r).iter_mut().enumerate() {
                *x = 0.02
                    * ((r as f32) - 19.5)
                    * if i % 2 == 0 { 0.05 } else { -0.05 };
            }
        }
    }

    fn assert_models_bitwise(a: &SharedModel, b: &SharedModel, rows: u32, tag: &str) {
        for r in 0..rows {
            for (l, (x, y)) in
                a.m_in().row(r).iter().zip(b.m_in().row(r)).enumerate()
            {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} m_in row {r} dim {l}");
            }
            for (l, (x, y)) in
                a.m_out().row(r).iter().zip(b.m_out().row(r)).enumerate()
            {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} m_out row {r} dim {l}");
            }
        }
    }

    /// Arena of sentence-grouped windows sharing one negative set —
    /// what `BatchBuilder` emits under `--reuse sentence`.
    fn grouped_arena(sentences: &[&[Window]], b_cap: usize, s: usize) -> SuperbatchArena {
        let mut a = SuperbatchArena::new(b_cap, s);
        for (serial, sent) in sentences.iter().enumerate() {
            for w in *sent {
                a.push_window_in_sentence(&w.inputs, &w.outputs, serial as u32);
            }
        }
        a
    }

    /// `--reuse window` is a pure driver ablation: runs are pinned to
    /// one window, so the model must equal `--reuse off` BIT FOR BIT on
    /// the same arena — for both kernel organisations, even when the
    /// arena is grouped so that `sentence` reuse WOULD form runs.
    #[test]
    fn reuse_window_is_bitwise_off_both_kernels() {
        let dim = 24;
        let negs = [20u32, 21, 22, 23, 24];
        let sent: Vec<Window> = (0..4u32)
            .map(|t| window(&[t * 2 + 1, t * 2 + 2], t + 10, &negs))
            .collect();
        let arena = grouped_arena(&[&sent], 16, 6);
        for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
            let mut m_off = SharedModel::init(40, dim, 91);
            let mut m_win = SharedModel::init(40, dim, 91);
            prewarm_out(&mut m_off, 40);
            prewarm_out(&mut m_win, 40);
            let mut g_off = GemmBackend::new(dim, 16, 6).with_kernel(kernel);
            let mut g_win = GemmBackend::new(dim, 16, 6)
                .with_kernel(kernel)
                .with_reuse(ReuseMode::Window);
            g_off.process_arena(m_off.store(), &arena, 0.05).unwrap();
            g_win.process_arena(m_win.store(), &arena, 0.05).unwrap();
            assert_models_bitwise(&m_off, &m_win, 40, "window-vs-off");
        }
    }

    /// Satellite regression: duplicate slots WITHIN a window (positive
    /// colliding with a shared negative) and ACROSS consecutive windows,
    /// in both orders (dup-first and dup-later).  With all-distinct
    /// input rows the deferred scatter is unobservable, so `sentence`
    /// reuse must equal `off` BIT FOR BIT: dup windows drop into
    /// singleton runs whose kernels keep the sequential reference
    /// semantics, clean neighbours still group.
    #[test]
    fn reuse_sentence_dup_slots_bitwise_off() {
        let dim = 24;
        let negs = [20u32, 21, 22, 23, 24];
        // Sentence 0: clean, DUP (target 21 ∈ negs), clean.
        let s0 = [
            window(&[1, 2], 10, &negs),
            window(&[3], 21, &negs),
            window(&[4, 5, 6], 12, &negs),
        ];
        // Sentence 1: DUP first (target 22 ∈ negs), then two clean.
        let s1 = [
            window(&[7], 22, &negs),
            window(&[8, 9], 13, &negs),
            window(&[11], 14, &negs),
        ];
        let arena = grouped_arena(&[&s0, &s1], 16, 6);
        for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
            let mut m_off = SharedModel::init(40, dim, 47);
            let mut m_sen = SharedModel::init(40, dim, 47);
            prewarm_out(&mut m_off, 40);
            prewarm_out(&mut m_sen, 40);
            let mut g_off = GemmBackend::new(dim, 16, 6).with_kernel(kernel);
            let mut g_sen = GemmBackend::new(dim, 16, 6)
                .with_kernel(kernel)
                .with_reuse(ReuseMode::Sentence);
            g_off.process_arena(m_off.store(), &arena, 0.05).unwrap();
            g_sen.process_arena(m_sen.store(), &arena, 0.05).unwrap();
            assert_models_bitwise(&m_off, &m_sen, 40, "sentence-vs-off");
        }
    }

    /// An input word repeating across two windows of one run makes the
    /// deferred scatter observable: both its gradients must be computed
    /// against the PRE-RUN row (the run kernel read all rows up front).
    /// Pinned against a naive all-from-initial-state computation, and
    /// fused/gemm3 must agree under reuse like they do without it.
    #[test]
    fn reuse_sentence_defers_repeated_input_scatter() {
        let dim = 16;
        let lr = 0.05f32;
        let negs = [20u32, 21, 22, 23, 24];
        // Input 3 appears in windows 0 and 2 of the same run.
        let sent = [
            window(&[1, 3], 10, &negs),
            window(&[2], 11, &negs),
            window(&[3, 4], 12, &negs),
        ];
        let arena = grouped_arena(&[&sent], 16, 6);

        let mut m_fused = SharedModel::init(30, dim, 63);
        let mut m_gemm3 = SharedModel::init(30, dim, 63);
        let mut m_naive = SharedModel::init(30, dim, 63);
        for m in [&mut m_fused, &mut m_gemm3, &mut m_naive] {
            prewarm_out(m, 30);
        }
        let mut gf = GemmBackend::new(dim, 16, 6)
            .with_kernel(KernelMode::Fused)
            .with_reuse(ReuseMode::Sentence);
        let mut g3 = GemmBackend::new(dim, 16, 6)
            .with_kernel(KernelMode::Gemm3)
            .with_reuse(ReuseMode::Sentence);
        gf.process_arena(m_fused.store(), &arena, lr).unwrap();
        g3.process_arena(m_gemm3.store(), &arena, lr).unwrap();

        // Naive: EVERY gradient from the initial state, applied at end
        // (one run spans the whole superbatch here, so pre-run == pre-
        // superbatch for the Wi rows too).
        let mut d_in: HashMap<u32, Vec<f32>> = HashMap::new();
        let mut d_out: HashMap<u32, Vec<f32>> = HashMap::new();
        for w in &sent {
            for &inp in &w.inputs {
                for (j, &out) in w.outputs.iter().enumerate() {
                    let wi = m_naive.m_in().row(inp).to_vec();
                    let wo = m_naive.m_out().row(out).to_vec();
                    let label = if j == 0 { 1.0 } else { 0.0 };
                    let gld = (label - sigmoid_exact(dot(&wi, &wo))) * lr;
                    let di = d_in.entry(inp).or_insert_with(|| vec![0.0; dim]);
                    let dp = d_out.entry(out).or_insert_with(|| vec![0.0; dim]);
                    for l in 0..dim {
                        di[l] += gld * wo[l];
                        dp[l] += gld * wi[l];
                    }
                }
            }
        }
        for (inp, delta) in &d_in {
            m_naive.add_in(*inp, delta);
        }
        for (out, delta) in &d_out {
            m_naive.add_out(*out, delta);
        }

        for r in 0..30u32 {
            for (x, y) in m_fused.m_in().row(r).iter().zip(m_gemm3.m_in().row(r)) {
                assert!((x - y).abs() < 1e-5, "fused-vs-gemm3 m_in row {r}");
            }
            for (x, y) in m_fused.m_in().row(r).iter().zip(m_naive.m_in().row(r)) {
                assert!((x - y).abs() < 1e-5, "fused-vs-naive m_in row {r}");
            }
            for (x, y) in m_fused.m_out().row(r).iter().zip(m_naive.m_out().row(r)) {
                assert!((x - y).abs() < 1e-5, "fused-vs-naive m_out row {r}");
            }
        }
        // And the deferral is real: window 2's gradient for input 3 was
        // NOT taken against a row already moved by window 0 (which the
        // Off driver would do), so Off and Sentence must differ here.
        let mut m_off = SharedModel::init(30, dim, 63);
        prewarm_out(&mut m_off, 30);
        let mut g_off = GemmBackend::new(dim, 16, 6).with_kernel(KernelMode::Fused);
        g_off.process_arena(m_off.store(), &arena, lr).unwrap();
        let differs = m_off
            .m_in()
            .row(3)
            .iter()
            .zip(m_fused.m_in().row(3))
            .any(|(x, y)| x.to_bits() != y.to_bits());
        assert!(differs, "deferred scatter had no observable effect");
    }

    #[test]
    fn adagrad_rule_damps_over_time() {
        let dim = 8;
        let mut model = SharedModel::init(10, dim, 13);
        // Prewarm M_out (word2vec zero-init would make the first dwi zero
        // and hide the damping behaviour under test).
        for r in 0..10u32 {
            for (i, x) in model.m_out_mut().row_mut(r).iter_mut().enumerate() {
                *x = 0.05 * ((r as f32) - 4.0) * if i % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let ag = Arc::new(AdaGrad::new(10, dim));
        let mut g =
            GemmBackend::new(dim, 16, 6).with_rule(UpdateRule::Adagrad(ag));
        let w = window(&[1], 5, &[6, 7]);
        let mut deltas = Vec::new();
        let mut prev = model.m_in().row(1).to_vec();
        for _ in 0..5 {
            g.process(model.store(), std::slice::from_ref(&w), 0.05).unwrap();
            let cur = model.m_in().row(1).to_vec();
            let step: f32 = cur
                .iter()
                .zip(&prev)
                .map(|(a, b)| (a - b).abs())
                .sum();
            deltas.push(step);
            prev = cur;
        }
        // First adjusted step is the sign-normalised AdaGrad step; later
        // steps must shrink as the accumulator grows.
        assert!(deltas[0] > 0.0, "{deltas:?}");
        assert!(deltas[4] < deltas[0] * 0.9, "should shrink: {deltas:?}");
    }
}
