//! The ORIGINAL word2vec trainer: Hogwild scalar SGD, a faithful port of
//! Algorithm 1 of the paper (Mikolov's C reference).
//!
//! Level-1 BLAS only: per (input, sample) pair one dot product and two
//! axpy updates, model mutated after EVERY pair.  Negatives are drawn per
//! input word (NOT shared across the batch) from the unigram table with
//! the original's LCG-driven lookup, and the EXP_TABLE sigmoid
//! approximation is used, including its saturation behaviour.
//!
//! This is the baseline every figure/table of the paper compares against;
//! keeping it faithful (rather than lightly batched) is what makes the
//! measured speedups meaningful.

use super::Backend;
use crate::linalg::sigmoid::SigmoidTable;
use crate::linalg::vecops::{axpy, dot};
use crate::model::ModelRef;
use crate::sampling::batch::Window;
use crate::sampling::unigram::UnigramSampler;
use crate::util::rng::Xoshiro256ss;

pub struct ScalarBackend<'a> {
    sampler: &'a UnigramSampler,
    negative: usize,
    sigmoid: SigmoidTable,
    rng: Xoshiro256ss,
    /// `temp` accumulator of Algorithm 1 (the input-row delta).
    temp: Vec<f32>,
}

impl<'a> ScalarBackend<'a> {
    pub fn new(sampler: &'a UnigramSampler, negative: usize, dim: usize, seed: u64) -> Self {
        Self {
            sampler,
            negative,
            sigmoid: SigmoidTable::default_table(),
            rng: Xoshiro256ss::new(seed),
            temp: vec![0.0; dim],
        }
    }

    /// Lines 2–21 of Algorithm 1 for one (input word, target) pair set.
    #[inline]
    fn train_pair(
        &mut self,
        model: ModelRef<'_>,
        input: u32,
        target: u32,
        lr: f32,
    ) {
        // SAFETY: Hogwild contract (model::hogwild module docs).
        let wi = unsafe { model.row_in(input) };
        self.temp.fill(0.0);
        for k in 0..=self.negative {
            let (word, label) = if k == 0 {
                (target, 1.0f32)
            } else {
                (self.sampler.sample_excluding(target, &mut self.rng), 0.0)
            };
            // SAFETY: Hogwild contract.
            let wo = unsafe { model.row_out(word) };
            let inn = dot(wi, wo);
            // The original skips the gradient entirely when the logit
            // saturates the EXP_TABLE and the label agrees; otherwise it
            // clamps to the table ends.
            let g = if inn > self.sigmoid.max() {
                if label == 1.0 {
                    continue;
                }
                (label - 1.0) * lr
            } else if inn < -self.sigmoid.max() {
                if label == 0.0 {
                    continue;
                }
                label * lr
            } else {
                (label - self.sigmoid.get(inn)) * lr
            };
            // temp += g * M_out[word]; M_out[word] += g * M_in[input]
            axpy(g, wo, &mut self.temp);
            axpy(g, wi, wo);
        }
        // M_in[input] += temp
        axpy(1.0, &self.temp, wi);
    }
}

impl<'a> Backend for ScalarBackend<'a> {
    fn process(
        &mut self,
        model: ModelRef<'_>,
        windows: &[Window],
        lr: f32,
    ) -> anyhow::Result<()> {
        for w in windows {
            let target = w.target();
            // NOTE: w.negatives() is intentionally ignored — the original
            // draws fresh negatives per input word.
            for i in 0..w.inputs.len() {
                self.train_pair(model, w.inputs[i], target, lr);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;
    use crate::model::SharedModel;
    use std::collections::HashMap;

    fn setup(v: usize, dim: usize) -> (SharedModel, UnigramSampler) {
        let counts: HashMap<String, u64> = (0..v)
            .map(|i| (format!("w{i:03}"), (1000 / (i + 1)) as u64))
            .collect();
        let vocab = Vocab::from_counts(counts, 1);
        let sampler = UnigramSampler::alias(&vocab, 0.75);
        (SharedModel::init(v, dim, 7), sampler)
    }

    fn window(inputs: &[u32], target: u32, negs: &[u32]) -> Window {
        let mut outputs = vec![target];
        outputs.extend_from_slice(negs);
        Window {
            inputs: inputs.to_vec(),
            outputs,
        }
    }

    #[test]
    fn updates_touch_expected_rows() {
        let (model, sampler) = setup(50, 16);
        let mut b = ScalarBackend::new(&sampler, 5, 16, 1);
        let before_in: Vec<Vec<f32>> =
            (0..50u32).map(|w| model.m_in().row(w).to_vec()).collect();
        let w = window(&[3, 4], 9, &[1, 2, 5, 6, 7]);
        // Two passes: M_out starts at zero (word2vec init), so the very
        // first pair leaves M_in unchanged (temp += g·0); the second pass
        // sees the updated M_out and moves M_in.
        b.process(model.store(), std::slice::from_ref(&w), 0.05).unwrap();
        b.process(model.store(), &[w], 0.05).unwrap();
        // Input rows 3 and 4 must change...
        assert_ne!(model.m_in().row(3), &before_in[3][..]);
        assert_ne!(model.m_in().row(4), &before_in[4][..]);
        // ...and no other input row may.
        for w in 0..50u32 {
            if w != 3 && w != 4 {
                assert_eq!(model.m_in().row(w), &before_in[w as usize][..], "row {w}");
            }
        }
    }

    #[test]
    fn positive_pair_similarity_increases() {
        let (model, sampler) = setup(50, 16);
        let mut b = ScalarBackend::new(&sampler, 5, 16, 2);
        let sim = |m: &SharedModel| dot(m.m_in().row(3), m.m_out().row(9));
        let before = sim(&model);
        for _ in 0..200 {
            b.process(model.store(), &[window(&[3], 9, &[1, 2, 5, 6, 7])], 0.05)
                .unwrap();
        }
        assert!(sim(&model) > before + 0.5, "similarity did not grow");
    }

    #[test]
    fn objective_improves_over_training() {
        // On a tiny planted corpus the NS objective of the trained pairs
        // must improve (ascent direction end-to-end).
        let (model, sampler) = setup(30, 8);
        let mut b = ScalarBackend::new(&sampler, 3, 8, 3);
        let windows: Vec<Window> = (0..10u32)
            .map(|t| window(&[(t + 1) % 30, (t + 2) % 30], t, &[]))
            .map(|mut w| {
                w.outputs.extend([20, 21, 22]);
                w
            })
            .collect();
        let obj = |m: &SharedModel| -> f64 {
            windows
                .iter()
                .flat_map(|w| {
                    w.inputs.iter().map(|&i| {
                        let x = dot(m.m_in().row(i), m.m_out().row(w.target()));
                        -(1.0 + (-x as f64).exp()).ln()
                    })
                })
                .sum()
        };
        let before = obj(&model);
        for _ in 0..100 {
            b.process(model.store(), &windows, 0.05).unwrap();
        }
        assert!(obj(&model) > before, "positive-pair objective fell");
    }

    #[test]
    fn deterministic_given_seed() {
        let (m1, sampler) = setup(50, 16);
        let (m2, _) = setup(50, 16);
        let w = window(&[3, 4, 5], 9, &[]);
        let mut w1 = w.clone();
        w1.outputs.extend([1, 2, 6, 7, 8]);
        let mut b1 = ScalarBackend::new(&sampler, 5, 16, 42);
        let mut b2 = ScalarBackend::new(&sampler, 5, 16, 42);
        b1.process(m1.store(), std::slice::from_ref(&w1), 0.05).unwrap();
        b2.process(m2.store(), std::slice::from_ref(&w1), 0.05).unwrap();
        assert_eq!(m1.m_in().data(), m2.m_in().data());
        assert_eq!(m1.m_out().data(), m2.m_out().data());
    }
}
