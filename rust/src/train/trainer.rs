//! Multi-threaded Hogwild training orchestration.
//!
//! Mirrors the original word2vec's threading discipline: the corpus file is
//! split into `threads` byte ranges; each worker streams its range
//! (epochs× times), subsamples, builds windows/superbatches, and drives its
//! own [`Backend`] instance against the shared model.
//!
//! The hot loop is allocation-free at steady state: each worker owns one
//! reused sentence buffer (`SentenceSource::next_sentence_into`, served
//! by the streaming text reader or — under `--corpus-cache` — the
//! pre-encoded `u32` cache, which also deletes per-epoch vocab hashing)
//! and one `SuperbatchArena` that `BatchBuilder::fill_arena` refills in
//! place;
//! back-ends consume the arena directly via [`Backend::process_arena`].
//! `train` also pins the SIMD dispatch level from `cfg.simd` before the
//! workers start (`--simd {auto,avx512,avx2,scalar}`).  The learning rate
//! decays with GLOBAL progress (an atomic word counter), exactly like the
//! original's `word_count_actual`.

use std::path::Path;
use std::sync::Arc;

use super::lr::LrState;
use super::route::{Exchange, Outbox, RouteSink, RowRouter, ROUTE_BLOCKS};
use super::sgd_bidmach::BidmachBackend;
use super::sgd_gemm::{GemmBackend, UpdateRule};
use super::sgd_pjrt::PjrtBackend;
use super::sgd_scalar::ScalarBackend;
use super::Backend;
use crate::config::{Backend as BackendKind, LrSchedule, TrainConfig};
use crate::corpus::reader::MAX_SENTENCE_LEN;
use crate::corpus::shard::{shards_for_len, Shard};
use crate::corpus::source::Corpus;
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::linalg::simd;
use crate::metrics::{Counters, Snapshot};
use crate::model::{set_access_node, ModelRef, NumaModel, ShardMap, SharedModel};
use crate::runtime::topology::{self, Topology};
use crate::runtime::{Manifest, Runtime, StepExecutable};
use crate::sampling::batch::{BatchBuilder, SuperbatchArena};
use crate::sampling::unigram::UnigramSampler;
use crate::util::rng::Xoshiro256ss;

#[derive(Debug)]
pub struct TrainOutcome {
    pub snapshot: Snapshot,
    /// Final learning rate (diagnostics).
    pub final_lr: f32,
}

/// Train with the back-end selected by `cfg.backend`.
pub fn train(
    cfg: &TrainConfig,
    corpus: &Path,
    vocab: &Vocab,
    model: &SharedModel,
) -> anyhow::Result<TrainOutcome> {
    cfg.validate()?;
    anyhow::ensure!(vocab.len() == model.vocab(), "vocab/model size mismatch");
    // Apply the kernel dispatch policy for this run (Auto unpins back to
    // detection, so an earlier pinned run never leaks into this one).
    simd::configure(cfg.simd)?;
    let sampler = UnigramSampler::alias(vocab, cfg.unigram_power);

    // The PJRT executable is compiled once and shared by all workers.
    let pjrt_exe: Option<Arc<StepExecutable>> = if cfg.backend == BackendKind::Pjrt {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let v = manifest.by_geometry(
            cfg.superbatch,
            cfg.batch,
            cfg.samples(),
            cfg.dim,
        )?;
        let rt = Runtime::cpu()?;
        Some(Arc::new(rt.compile_variant(&manifest, v)?))
    } else {
        None
    };

    let factory = |tid: usize| -> anyhow::Result<Box<dyn Backend + '_>> {
        let seed = cfg.seed ^ (0x9E37 + tid as u64 * 0x51_7C_C1);
        Ok(match cfg.backend {
            BackendKind::Scalar => Box::new(ScalarBackend::new(
                &sampler,
                cfg.negative,
                cfg.dim,
                seed,
            )),
            BackendKind::Bidmach => Box::new(BidmachBackend::new(cfg.batch)),
            BackendKind::Gemm => Box::new(
                GemmBackend::new(cfg.dim, cfg.batch, cfg.samples())
                    .with_rule(UpdateRule::Plain)
                    .with_sigmoid(cfg.sigmoid_mode)
                    .with_kernel(cfg.kernel)
                    .with_reuse(cfg.reuse),
            ),
            BackendKind::Pjrt => Box::new(PjrtBackend::new(
                pjrt_exe.as_ref().expect("pjrt exe prepared above").clone(),
            )),
        })
    };
    train_with_factory(cfg, corpus, vocab, model, &sampler, &factory)
}

/// Train with an arbitrary per-thread backend factory (benches use this to
/// inject AdaGrad/RMSProp rules or custom schemes).
pub fn train_with_factory<'f>(
    cfg: &TrainConfig,
    corpus: &Path,
    vocab: &Vocab,
    model: &SharedModel,
    sampler: &'f UnigramSampler,
    factory: &(dyn Fn(usize) -> anyhow::Result<Box<dyn Backend + 'f>> + Sync),
) -> anyhow::Result<TrainOutcome> {
    let total_words = vocab.total_words() * cfg.epochs as u64;
    let lr_state = match cfg.lr_schedule {
        LrSchedule::DistScaled => {
            LrState::dist_scaled(cfg.lr, cfg.lr_min_frac, total_words, 1)
        }
        _ => LrState::linear(cfg.lr, cfg.lr_min_frac, total_words),
    };
    let subsampler = Subsampler::new(vocab, cfg.sample);
    let counters = Counters::new();
    // `--corpus-cache {off,auto,<path>}`: Off streams the text file per
    // epoch; Auto/Path open (building if needed) the encoded `u32` cache.
    // Shard geometry is text-byte based either way, so the cache policy
    // never changes which sentences a worker sees.
    let source = Corpus::open(corpus, vocab, &cfg.corpus_cache)?;
    let shards = shards_for_len(source.shard_len(), cfg.threads);
    // `--route {off,owner,head=<K>}`: Off keeps every worker on its own
    // window stream (bit-for-bit the pre-routing path); otherwise the
    // routed head cutoff resolves HERE, where the vocabulary's Zipf
    // counts are in reach (`owner` = smallest id prefix covering 90% of
    // corpus mass).
    let route_head = cfg.route.head_k(vocab);
    let ctx = WorkerCtx {
        cfg,
        source: &source,
        shards: &shards,
        lr_state: &lr_state,
        counters: &counters,
        subsampler: &subsampler,
        sampler,
        factory,
        route_head,
    };

    // `--numa off`: the flat model, unpinned workers — bit-for-bit the
    // pre-NUMA path.  Otherwise: shard the model rows across the resolved
    // topology (each node's segment first-touched by a pinned thread),
    // pin workers round-robin over nodes, train against the sharded
    // store, and copy the rows back into the caller's flat model.  The
    // values computed are identical — only page placement, thread
    // affinity, and therefore cross-socket traffic change
    // (tests/numa_parity.rs pins 1-thread bitwise equality).  COST: the
    // caller's flat model stays alive next to the sharded copy until
    // copy_back — transient 2x model residency (documented in
    // EXPERIMENTS.md §NUMA); the dist path avoids this by init-ing each
    // replica in place on its node.
    match topology::resolve(cfg.numa)? {
        None => run_workers(&ctx, model.store(), None)?,
        Some(topo) => {
            // Under `auto`, never shard across more nodes than there
            // are workers: a node with no pinned worker would make
            // every access to its rows remote — WORSE than the flat
            // path at low thread counts.  The clamp keeps the FIRST
            // `threads` real nodes (boundaries intact, placement stays
            // node-pure).  An explicit `--numa <n>` is the
            // ablation/test knob and is honoured as given.
            use crate::runtime::topology::NumaMode;
            let topo = match cfg.numa {
                NumaMode::Auto if cfg.threads < topo.nodes() => {
                    topo.take_nodes(cfg.threads)
                }
                _ => topo,
            };
            if cfg.numa == NumaMode::Auto && topo.nodes() == 1 {
                // `auto` resolved to a single node (single-socket box,
                // or clamped to 1 worker): there is no cross-socket
                // traffic to save, so sharding would pay the 2x
                // transient residency and per-access shard-map lookup
                // for nothing.  The flat path is bitwise-identical.
                run_workers(&ctx, model.store(), None)?;
            } else {
                let numa = NumaModel::from_model(model, &topo);
                run_workers(&ctx, numa.store(), Some(&topo))?;
                numa.copy_back(model);
            }
        }
    }

    Ok(TrainOutcome {
        snapshot: counters.snapshot(),
        final_lr: lr_state.current(),
    })
}

/// Shared borrows of everything a worker thread needs (keeps the spawn
/// closure tidy across the flat and NUMA-sharded paths).
struct WorkerCtx<'a, 'f> {
    cfg: &'a TrainConfig,
    source: &'a Corpus<'a>,
    shards: &'a [Shard],
    lr_state: &'a LrState,
    counters: &'a Counters,
    subsampler: &'a Subsampler,
    sampler: &'f UnigramSampler,
    factory: &'a (dyn Fn(usize) -> anyhow::Result<Box<dyn Backend + 'f>> + Sync),
    /// Routed-head cutoff resolved from `cfg.route` (`None` = routing
    /// off — take the unrouted worker loop, bit-for-bit).
    route_head: Option<usize>,
}

/// Spawn one worker per corpus shard against `model`.  Under `topo`,
/// worker `i` pins itself to node `i % nodes` BEFORE allocating its
/// backend scratch, superbatch arena, and sentence buffer, so those hot
/// per-worker buffers are first-touched node-locally too.  Under
/// `--route` the workers additionally exchange generated windows by
/// output-row ownership ([`run_workers_routed`]); `--route off` takes
/// the unrouted loop, bit-for-bit the pre-routing path.
fn run_workers(
    ctx: &WorkerCtx<'_, '_>,
    model: ModelRef<'_>,
    topo: Option<&Topology>,
) -> anyhow::Result<()> {
    match ctx.route_head {
        None => run_workers_unrouted(ctx, model, topo),
        Some(head_k) => run_workers_routed(ctx, model, topo, head_k),
    }
}

fn run_workers_unrouted(
    ctx: &WorkerCtx<'_, '_>,
    model: ModelRef<'_>,
    topo: Option<&Topology>,
) -> anyhow::Result<()> {
    let cfg = ctx.cfg;
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for shard in ctx.shards {
            let handle = scope.spawn(move || -> anyhow::Result<()> {
                if let Some(t) = topo {
                    t.pin_to_node(shard.index % t.nodes());
                    // Debug-only remote-row share counters (no-op in
                    // release — the unrouted path stays bit-for-bit).
                    set_access_node(Some(shard.index % t.nodes()));
                }
                let mut backend = (ctx.factory)(shard.index)?;
                let mut rng = Xoshiro256ss::new(
                    cfg.seed ^ (shard.index as u64 * 0xA5A5_1234 + 17),
                );
                let mut builder = BatchBuilder::new(
                    ctx.sampler,
                    cfg.window,
                    cfg.batch,
                    cfg.negative,
                )
                .with_reuse(cfg.reuse);
                // Reused across the whole shard: zero allocations per
                // window at steady state (tests/alloc_steadystate.rs).
                // Sentence-slack sizing: `fill_arena` appends a whole
                // sentence BEFORE the superbatch check below, so the
                // arena must absorb a MAX_SENTENCE_LEN overshoot without
                // reallocating.
                let mut arena = SuperbatchArena::with_sentence_slack(
                    cfg.superbatch,
                    cfg.batch,
                    cfg.samples(),
                );
                let mut sent: Vec<u32> = Vec::with_capacity(MAX_SENTENCE_LEN);
                let mut raw_words = 0u64;
                for _epoch in 0..cfg.epochs {
                    let mut reader =
                        ctx.source.open_range(shard.start, shard.end)?;
                    while reader.next_sentence_into(&mut sent)? {
                        raw_words += sent.len() as u64;
                        ctx.subsampler.filter(&mut sent, &mut rng);
                        builder.fill_arena(&sent, &mut rng, &mut arena);
                        if arena.len() >= cfg.superbatch {
                            let lr = ctx.lr_state.advance(raw_words);
                            ctx.counters.add_words(raw_words);
                            raw_words = 0;
                            backend.process_arena(model, &arena, lr)?;
                            ctx.counters.add_windows(arena.len() as u64);
                            ctx.counters.add_calls(1);
                            arena.clear();
                        }
                    }
                }
                if !arena.is_empty() {
                    let lr = ctx.lr_state.advance(raw_words);
                    ctx.counters.add_words(raw_words);
                    backend.process_arena(model, &arena, lr)?;
                    ctx.counters.add_windows(arena.len() as u64);
                    ctx.counters.add_calls(1);
                } else if raw_words > 0 {
                    ctx.lr_state.advance(raw_words);
                    ctx.counters.add_words(raw_words);
                }
                Ok(())
            });
            handles.push(handle);
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    })
}

/// The ownership-routed worker loop (`--route {owner,head=<K>}`).
///
/// Same shape as the unrouted loop, plus the exchange: windows are
/// classified at generation time (the [`RouteSink`] steers routed-head
/// targets into per-destination mailbox blocks, everything else into the
/// worker's own arena), each worker adopts incoming blocks once per
/// sentence, pending partial blocks are flushed before every local
/// superbatch, and after its shard a worker keeps draining peers until
/// every producer has closed.  Producers never block (saturated
/// destinations fall back to local processing), so the tail loop always
/// terminates.  Word accounting and lr decay stay with the GENERATING
/// worker — routing moves windows, never words, so totals are unchanged
/// (`tests/routing_parity.rs`).
fn run_workers_routed(
    ctx: &WorkerCtx<'_, '_>,
    model: ModelRef<'_>,
    topo: Option<&Topology>,
    head_k: usize,
) -> anyhow::Result<()> {
    let cfg = ctx.cfg;
    let workers = ctx.shards.len();
    let nodes = topo.map_or(1, |t| t.nodes());
    // The SAME contiguous partition `NumaModel` places rows with, so a
    // routed window's home node is literally where its target row's
    // pages live under `--numa` (one trivial node otherwise).
    let router = RowRouter::new(
        ShardMap::contiguous(model.vocab(), nodes),
        head_k,
    );
    // Exchange sizing: mailbox blocks are lazily seeded (idle pairs
    // cost two empty ring headers), but every worker's arena still
    // reserves route slack for `max_inflight()` windows — so cap the
    // per-consumer in-flight bound, or many-core runs would reserve
    // O(workers) slack in EVERY worker (O(workers²) total).  Below ~33
    // workers the cap leaves the 64-window blocks untouched.
    const INFLIGHT_CAP_WINDOWS: usize = 4096;
    let mut block_windows = cfg.superbatch.clamp(1, 64);
    if workers > 1 {
        block_windows = block_windows
            .min((INFLIGHT_CAP_WINDOWS / (ROUTE_BLOCKS * (workers - 1))).max(1));
    }
    let exch = Exchange::new(
        workers,
        ROUTE_BLOCKS,
        block_windows,
        cfg.batch,
        cfg.samples(),
    );
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for shard in ctx.shards {
            let (router, exch) = (&router, &exch);
            let handle = scope.spawn(move || -> anyhow::Result<()> {
                let me = shard.index;
                // Armed before the first fallible op: peers' tail loops
                // wait for OUR close, so an early `?` error or panic
                // must still close our rings or the scope hangs.
                let _close_on_exit = exch.producer_guard(me);
                if let Some(t) = topo {
                    t.pin_to_node(me % t.nodes());
                    set_access_node(Some(me % t.nodes()));
                }
                let mut backend = (ctx.factory)(me)?;
                let mut rng = Xoshiro256ss::new(
                    cfg.seed ^ (me as u64 * 0xA5A5_1234 + 17),
                );
                let mut builder = BatchBuilder::new(
                    ctx.sampler,
                    cfg.window,
                    cfg.batch,
                    cfg.negative,
                )
                .with_reuse(cfg.reuse);
                // Route slack = sentence slack + everything peers can
                // have in flight toward us (bounded block rings), so the
                // routed arena never reallocates after construction
                // either (tests/alloc_steadystate.rs, routed leg).
                let mut arena = SuperbatchArena::with_route_slack(
                    cfg.superbatch,
                    cfg.batch,
                    cfg.samples(),
                    exch.max_inflight(),
                );
                let mut outbox = Outbox::new(exch, router, me);
                let mut sent: Vec<u32> = Vec::with_capacity(MAX_SENTENCE_LEN);
                let mut raw_words = 0u64;
                for _epoch in 0..cfg.epochs {
                    let mut reader =
                        ctx.source.open_range(shard.start, shard.end)?;
                    while reader.next_sentence_into(&mut sent)? {
                        raw_words += sent.len() as u64;
                        ctx.subsampler.filter(&mut sent, &mut rng);
                        {
                            let mut sink =
                                RouteSink::new(&mut arena, &mut outbox);
                            builder.fill_arena_routed(
                                &sent, &mut rng, &mut sink,
                            );
                        }
                        // The exchange step: adopt whatever peers routed
                        // here (cheap when empty — one relaxed load per
                        // peer), then process a full local superbatch.
                        exch.drain_into(me, &mut arena);
                        if arena.len() >= cfg.superbatch {
                            outbox.flush();
                            let lr = ctx.lr_state.advance(raw_words);
                            ctx.counters.add_words(raw_words);
                            raw_words = 0;
                            backend.process_arena(model, &arena, lr)?;
                            ctx.counters.add_windows(arena.len() as u64);
                            ctx.counters.add_calls(1);
                            arena.clear();
                        }
                    }
                }
                // Generation done: hand off pending partial blocks,
                // close our outgoing rings, account the tail words.
                outbox.flush();
                exch.close_producer(me);
                if raw_words > 0 {
                    ctx.lr_state.advance(raw_words);
                    ctx.counters.add_words(raw_words);
                }
                // Consume peers' routed windows until every producer has
                // closed.  Reading `producers_done` BEFORE the drain
                // makes the final iteration complete: close is
                // Release-stored after a producer's last push, so a
                // drain that follows an observed close sees everything.
                loop {
                    let done = exch.producers_done(me);
                    exch.drain_into(me, &mut arena);
                    if !arena.is_empty() {
                        let lr = ctx.lr_state.current();
                        backend.process_arena(model, &arena, lr)?;
                        ctx.counters.add_windows(arena.len() as u64);
                        ctx.counters.add_calls(1);
                        arena.clear();
                    }
                    if done {
                        break;
                    }
                    std::thread::yield_now();
                }
                Ok(())
            });
            handles.push(handle);
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{LatentModel, SyntheticConfig};

    fn tiny_corpus() -> (std::path::PathBuf, Vocab) {
        let mut scfg = SyntheticConfig::test_tiny();
        scfg.tokens = 30_000;
        let lm = LatentModel::new(scfg);
        let path = std::env::temp_dir().join(format!(
            "pw2v_trainer_corpus_{}.txt",
            std::process::id()
        ));
        lm.write_corpus(&path).unwrap();
        let vocab = Vocab::build_from_file(&path, 1).unwrap();
        (path, vocab)
    }

    fn run(cfg: &TrainConfig, path: &Path, vocab: &Vocab) -> (SharedModel, TrainOutcome) {
        let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        let out = train(cfg, path, vocab, &model).unwrap();
        (model, out)
    }

    #[test]
    fn all_native_backends_train_and_count_words() {
        let (path, vocab) = tiny_corpus();
        for backend in [
            crate::config::Backend::Scalar,
            crate::config::Backend::Bidmach,
            crate::config::Backend::Gemm,
        ] {
            let mut cfg = TrainConfig::test_tiny();
            cfg.backend = backend;
            cfg.sample = 0.0;
            let (model, out) = run(&cfg, &path, &vocab);
            assert_eq!(
                out.snapshot.words,
                vocab.total_words(),
                "backend {backend}: word count"
            );
            assert!(out.snapshot.windows > 0);
            // Model must have moved away from init.
            let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
            assert_ne!(model.m_in().data(), init.m_in().data());
            assert!(out.final_lr < cfg.lr);
        }
        std::fs::remove_file(&path).ok();
    }

    /// Both kernel organisations drive the full trainer: identical word
    /// accounting, and both move the model off its init.
    #[test]
    fn gemm_kernel_modes_both_train() {
        let (path, vocab) = tiny_corpus();
        for kernel in [
            crate::config::KernelMode::Fused,
            crate::config::KernelMode::Gemm3,
        ] {
            let mut cfg = TrainConfig::test_tiny();
            cfg.backend = crate::config::Backend::Gemm;
            cfg.kernel = kernel;
            cfg.sample = 0.0;
            let (model, out) = run(&cfg, &path, &vocab);
            assert_eq!(
                out.snapshot.words,
                vocab.total_words(),
                "kernel {kernel}: word count"
            );
            let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
            assert_ne!(model.m_in().data(), init.m_in().data());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn multithreaded_processes_whole_corpus() {
        let (path, vocab) = tiny_corpus();
        let mut cfg = TrainConfig::test_tiny();
        cfg.threads = 4;
        cfg.sample = 0.0;
        let (_, out) = run(&cfg, &path, &vocab);
        assert_eq!(out.snapshot.words, vocab.total_words());
        std::fs::remove_file(&path).ok();
    }

    /// `--corpus-cache auto` builds the cache on first use, reuses it on
    /// the second run, and accounts the exact same word totals as the
    /// text path (bitwise model parity is pinned in
    /// `tests/corpus_parity.rs`).
    #[test]
    fn auto_corpus_cache_trains_identically_counted() {
        // Private corpus file: this test asserts cache-file mtimes, so it
        // must not share `tiny_corpus()`'s path with concurrent tests.
        let mut scfg = SyntheticConfig::test_tiny();
        scfg.tokens = 30_000;
        let lm = LatentModel::new(scfg);
        let path = std::env::temp_dir().join(format!(
            "pw2v_trainer_cc_{}.txt",
            std::process::id()
        ));
        lm.write_corpus(&path).unwrap();
        let vocab = Vocab::build_from_file(&path, 1).unwrap();
        let cache =
            crate::corpus::encoded::EncodedCorpus::cache_path_for(&path);
        std::fs::remove_file(&cache).ok();
        let mut cfg = TrainConfig::test_tiny();
        cfg.threads = 2;
        cfg.epochs = 2;
        cfg.sample = 0.0;
        cfg.corpus_cache = crate::config::CorpusCacheMode::Auto;
        let (_, out) = run(&cfg, &path, &vocab);
        assert_eq!(out.snapshot.words, 2 * vocab.total_words());
        assert!(cache.exists(), "auto mode must leave the cache behind");
        // Second run reuses the cache (mtime/content untouched).
        let before = std::fs::metadata(&cache).unwrap().modified().unwrap();
        let (_, out) = run(&cfg, &path, &vocab);
        assert_eq!(out.snapshot.words, 2 * vocab.total_words());
        let after = std::fs::metadata(&cache).unwrap().modified().unwrap();
        assert_eq!(before, after, "valid cache must not be rebuilt");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }

    /// The ownership-routed worker loop conserves word/window accounting
    /// (routing moves windows, never words) and still trains; at one
    /// thread the routed knob reproduces the unrouted model bitwise (the
    /// full cross-feature matrix lives in tests/routing_parity.rs).
    #[test]
    fn routed_workers_account_and_train() {
        let (path, vocab) = tiny_corpus();
        let mut cfg = TrainConfig::test_tiny();
        cfg.sample = 0.0;
        let (flat, base) = run(&cfg, &path, &vocab);
        cfg.route = crate::train::route::RouteMode::Owner;
        let (routed1, out1) = run(&cfg, &path, &vocab);
        assert_eq!(out1.snapshot.words, base.snapshot.words);
        assert_eq!(out1.snapshot.windows, base.snapshot.windows);
        assert_eq!(
            flat.m_in().data(),
            routed1.m_in().data(),
            "1-thread routed must be bitwise the unrouted path"
        );
        cfg.threads = 3;
        let (routed3, out3) = run(&cfg, &path, &vocab);
        assert_eq!(out3.snapshot.words, vocab.total_words());
        assert_eq!(
            out3.snapshot.windows, base.snapshot.windows,
            "routing must conserve the total window count"
        );
        let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        assert_ne!(routed3.m_in().data(), init.m_in().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn epochs_multiply_words() {
        let (path, vocab) = tiny_corpus();
        let mut cfg = TrainConfig::test_tiny();
        cfg.epochs = 3;
        cfg.sample = 0.0;
        let (_, out) = run(&cfg, &path, &vocab);
        assert_eq!(out.snapshot.words, 3 * vocab.total_words());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gemm_reduces_update_count_vs_scalar() {
        // Sec. III-C: our scheme performs fewer, larger model updates.
        // Proxy: windows per call — scalar conceptually updates per pair.
        let (path, vocab) = tiny_corpus();
        let mut cfg = TrainConfig::test_tiny();
        cfg.backend = crate::config::Backend::Gemm;
        let (_, out) = run(&cfg, &path, &vocab);
        assert!(
            out.snapshot.windows / out.snapshot.calls.max(1)
                >= cfg.superbatch as u64 / 2,
            "superbatching not effective"
        );
        std::fs::remove_file(&path).ok();
    }
}
