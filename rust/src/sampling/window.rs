//! Dynamic context windows, as in the original word2vec: for each center
//! position the effective window is `c - b` where `b` is drawn uniformly
//! from `0..c`, i.e. the actual half-width is uniform in `1..=c`.  This
//! implicitly weights close-by context words higher.

use crate::util::rng::Xoshiro256ss;

/// Draw the effective half-window (uniform in 1..=max_window).
#[inline]
pub fn dynamic_window(max_window: usize, rng: &mut Xoshiro256ss) -> usize {
    1 + rng.below(max_window)
}

/// Enumerate the context positions of `center` in a sentence of length
/// `len` under half-window `win`: `[center-win, center+win] \ {center}`,
/// clipped to the sentence.
pub fn context_range(center: usize, win: usize, len: usize) -> impl Iterator<Item = usize> {
    let lo = center.saturating_sub(win);
    let hi = (center + win).min(len.saturating_sub(1));
    (lo..=hi).filter(move |&p| p != center)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_in_bounds() {
        let mut rng = Xoshiro256ss::new(1);
        for _ in 0..10_000 {
            let w = dynamic_window(5, &mut rng);
            assert!((1..=5).contains(&w));
        }
    }

    #[test]
    fn window_uniform() {
        let mut rng = Xoshiro256ss::new(2);
        let mut counts = [0usize; 5];
        let n = 500_000;
        for _ in 0..n {
            counts[dynamic_window(5, &mut rng) - 1] += 1;
        }
        for c in counts {
            let f = c as f64 / n as f64;
            assert!((f - 0.2).abs() < 0.01, "f={f}");
        }
    }

    #[test]
    fn context_excludes_center_and_clips() {
        let ctx: Vec<usize> = context_range(0, 2, 5).collect();
        assert_eq!(ctx, vec![1, 2]);
        let ctx: Vec<usize> = context_range(4, 2, 5).collect();
        assert_eq!(ctx, vec![2, 3]);
        let ctx: Vec<usize> = context_range(2, 2, 5).collect();
        assert_eq!(ctx, vec![0, 1, 3, 4]);
    }

    #[test]
    fn context_of_singleton_sentence_empty() {
        assert_eq!(context_range(0, 5, 1).count(), 0);
    }
}
