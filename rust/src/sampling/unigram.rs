//! The negative-sampling distribution P_n(w) ∝ count(w)^0.75 (Mikolov et
//! al. 2013), in two implementations:
//!
//! * [`UnigramSampler::table`] — the original C code's precomputed index
//!   table (default size 1e8, configurable), sampled with the word2vec LCG.
//!   Used by the scalar baseline for fidelity.
//! * [`UnigramSampler::alias`] — Walker alias method, O(1) with no giant
//!   table; used by the batched trainers.
//!
//! Both expose the same `sample` interface and the same distribution, which
//! a test asserts.

use super::alias::AliasTable;
use crate::corpus::vocab::Vocab;
use crate::util::rng::Xoshiro256ss;

pub enum UnigramSampler {
    Table { table: Vec<u32> },
    Alias { table: AliasTable },
}

impl UnigramSampler {
    /// The original's table method (`InitUnigramTable`).
    pub fn table(vocab: &Vocab, power: f32, table_size: usize) -> Self {
        assert!(!vocab.is_empty());
        let pow_sum: f64 = vocab
            .counts()
            .iter()
            .map(|&c| (c as f64).powf(power as f64))
            .sum();
        let mut table = vec![0u32; table_size];
        let mut i = 0usize;
        let mut cum = (vocab.count(0) as f64).powf(power as f64) / pow_sum;
        for (a, slot) in table.iter_mut().enumerate() {
            *slot = i as u32;
            if a as f64 / table_size as f64 > cum {
                if i < vocab.len() - 1 {
                    i += 1;
                }
                cum += (vocab.count(i as u32) as f64).powf(power as f64) / pow_sum;
            }
        }
        Self::Table { table }
    }

    /// Alias-method sampler over the same distribution.
    pub fn alias(vocab: &Vocab, power: f32) -> Self {
        assert!(!vocab.is_empty());
        let weights: Vec<f64> = vocab
            .counts()
            .iter()
            .map(|&c| (c as f64).powf(power as f64))
            .collect();
        Self::Alias {
            table: AliasTable::new(&weights),
        }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256ss) -> u32 {
        match self {
            Self::Table { table } => table[rng.below(table.len())],
            Self::Alias { table } => table.sample(rng),
        }
    }

    /// Draw a negative sample avoiding `exclude` (the positive target), as
    /// the original does (resamples on collision).
    #[inline]
    pub fn sample_excluding(&self, exclude: u32, rng: &mut Xoshiro256ss) -> u32 {
        loop {
            let s = self.sample(rng);
            if s != exclude {
                return s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn zipf_vocab(v: usize) -> Vocab {
        let counts: HashMap<String, u64> = (0..v)
            .map(|i| (format!("w{i:04}"), (100_000 / (i + 1)) as u64))
            .collect();
        Vocab::from_counts(counts, 1)
    }

    fn empirical(s: &UnigramSampler, v: usize, draws: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256ss::new(seed);
        let mut counts = vec![0usize; v];
        for _ in 0..draws {
            counts[s.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    fn expected(vocab: &Vocab, power: f32) -> Vec<f64> {
        let pow: Vec<f64> = vocab
            .counts()
            .iter()
            .map(|&c| (c as f64).powf(power as f64))
            .collect();
        let sum: f64 = pow.iter().sum();
        pow.iter().map(|p| p / sum).collect()
    }

    #[test]
    fn table_matches_power_distribution() {
        let v = zipf_vocab(50);
        let s = UnigramSampler::table(&v, 0.75, 1_000_000);
        let emp = empirical(&s, 50, 500_000, 1);
        let want = expected(&v, 0.75);
        for i in 0..50 {
            assert!(
                (emp[i] - want[i]).abs() < 0.01,
                "word {i}: {} vs {}",
                emp[i],
                want[i]
            );
        }
    }

    #[test]
    fn alias_matches_power_distribution() {
        let v = zipf_vocab(50);
        let s = UnigramSampler::alias(&v, 0.75);
        let emp = empirical(&s, 50, 500_000, 2);
        let want = expected(&v, 0.75);
        for i in 0..50 {
            assert!((emp[i] - want[i]).abs() < 0.01, "word {i}");
        }
    }

    #[test]
    fn table_and_alias_agree() {
        let v = zipf_vocab(100);
        let t = UnigramSampler::table(&v, 0.75, 2_000_000);
        let a = UnigramSampler::alias(&v, 0.75);
        let et = empirical(&t, 100, 400_000, 3);
        let ea = empirical(&a, 100, 400_000, 4);
        for i in 0..100 {
            assert!((et[i] - ea[i]).abs() < 0.01, "word {i}");
        }
    }

    #[test]
    fn excluding_never_returns_excluded() {
        let v = zipf_vocab(10);
        let s = UnigramSampler::alias(&v, 0.75);
        let mut rng = Xoshiro256ss::new(5);
        for _ in 0..10_000 {
            assert_ne!(s.sample_excluding(0, &mut rng), 0);
        }
    }

    #[test]
    fn power_one_is_plain_unigram() {
        let v = zipf_vocab(20);
        let s = UnigramSampler::alias(&v, 1.0);
        let emp = empirical(&s, 20, 400_000, 6);
        let want = expected(&v, 1.0);
        for i in 0..20 {
            assert!((emp[i] - want[i]).abs() < 0.01);
        }
    }
}
