//! Sampling substrate: the unigram^0.75 negative-sampling distribution
//! (both the original's table method and an O(1) alias method), dynamic
//! context windows, and the minibatch/superbatch builder that implements
//! the paper's "negative sample sharing" (Sec. III-B).

pub mod alias;
pub mod batch;
pub mod unigram;
pub mod window;

pub use alias::AliasTable;
pub use batch::{BatchBuilder, Superbatch, SuperbatchArena, Window};
pub use unigram::UnigramSampler;
pub use window::dynamic_window;
