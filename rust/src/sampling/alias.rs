//! Walker's alias method: O(1) sampling from an arbitrary discrete
//! distribution with O(n) setup.  Used for the negative-sampling unigram
//! distribution and by the synthetic-corpus generator's per-cluster
//! emission distributions.

use crate::util::rng::Xoshiro256ss;

#[derive(Clone, Debug)]
pub struct AliasTable {
    /// Acceptance threshold per bucket, scaled to u64 range for a
    /// branch-cheap integer comparison.
    prob: Vec<u64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from unnormalised non-negative weights (at least one > 0).
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        let sum: f64 = weights.iter().sum();
        assert!(
            sum > 0.0 && weights.iter().all(|&w| w >= 0.0),
            "weights must be non-negative with positive sum"
        );
        // Scaled probabilities * n; split into small/large worklists.
        let mut scaled: Vec<f64> =
            weights.iter().map(|&w| w / sum * n as f64).collect();
        let mut prob = vec![0u64; n];
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s as usize] = (scaled[s as usize] * u64::MAX as f64) as u64;
            alias[s as usize] = l;
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = u64::MAX;
        }
        Self { prob, alias }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one index.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256ss) -> u32 {
        let i = rng.below(self.prob.len());
        if rng.next_u64() <= self.prob[i] {
            i as u32
        } else {
            self.alias[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(table: &AliasTable, n: usize, draws: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256ss::new(seed);
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_weights() {
        let t = AliasTable::new(&[1.0; 8]);
        let f = empirical(&t, 8, 400_000, 1);
        for p in f {
            assert!((p - 0.125).abs() < 0.005, "p={p}");
        }
    }

    #[test]
    fn skewed_weights() {
        let w = [8.0, 4.0, 2.0, 1.0, 1.0];
        let t = AliasTable::new(&w);
        let f = empirical(&t, 5, 800_000, 2);
        let sum: f64 = w.iter().sum();
        for (i, p) in f.iter().enumerate() {
            let want = w[i] / sum;
            assert!((p - want).abs() < 0.005, "i={i} p={p} want={want}");
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let t = AliasTable::new(&[1.0, 0.0, 1.0]);
        let f = empirical(&t, 3, 200_000, 3);
        assert_eq!(f[1], 0.0);
    }

    #[test]
    fn single_element() {
        let t = AliasTable::new(&[5.0]);
        let mut rng = Xoshiro256ss::new(4);
        for _ in 0..100 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_all_zero() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    fn zipf_like_large() {
        // Sanity at vocabulary scale: top-1 mass of Zipf(1) over 10k.
        let w: Vec<f64> = (1..=10_000).map(|r| 1.0 / r as f64).collect();
        let t = AliasTable::new(&w);
        let f = empirical(&t, 10_000, 500_000, 5);
        let h: f64 = (1..=10_000).map(|r| 1.0 / r as f64).sum();
        assert!((f[0] - 1.0 / h).abs() < 0.01);
    }
}
