//! Minibatch / superbatch assembly — the heart of the paper's
//! parallelization scheme (Sec. III-B, Fig. 2 right).
//!
//! For each center position `t` of a sentence we form one [`Window`]:
//!
//! * `inputs`  — the context words around `t` (at most `B` of them; the
//!   dynamic window already bounds this by `2*window`), which become the
//!   rows of `Wi[B, D]`;
//! * `outputs` — the center word (positive target) followed by `K`
//!   negative samples drawn ONCE and **shared by every input in the
//!   batch** ("negative sample sharing"), the rows of `Wo[S, D]`.
//!
//! [`BatchBuilder`] packs `W` consecutive windows into a [`Superbatch`] so
//! one kernel/PJRT call covers many windows (our artifact-amortisation
//! knob; the pure-rust GEMM trainer uses W=1-equivalent inner loops).

use super::unigram::UnigramSampler;
use super::window::{context_range, dynamic_window};
use crate::config::ReuseMode;
use crate::corpus::reader::MAX_SENTENCE_LEN;
use crate::util::rng::Xoshiro256ss;

/// One training window: a batch of input words sharing target + negatives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Window {
    /// Context word ids (the rows of Wi). Non-empty, len <= batch cap.
    pub inputs: Vec<u32>,
    /// Target (index 0) then the K shared negative ids (rows of Wo).
    pub outputs: Vec<u32>,
}

impl Window {
    pub fn target(&self) -> u32 {
        self.outputs[0]
    }

    pub fn negatives(&self) -> &[u32] {
        &self.outputs[1..]
    }
}

/// A fixed-geometry batch of `W` windows, padded for the AOT artifact path.
#[derive(Clone, Debug)]
pub struct Superbatch {
    pub windows: Vec<Window>,
    /// Geometry every window is padded to by the PJRT trainer.
    pub b: usize,
    pub s: usize,
    /// Tokens consumed building this superbatch (for throughput/lr decay);
    /// counts every center position processed, as the original does.
    pub words: u64,
}

/// Flat structure-of-arrays storage for a superbatch of windows — the
/// zero-allocation counterpart of `Vec<Window>`.
///
/// `windows_of` heap-allocates two `Vec<u32>` per window, which at the
/// paper's rates is millions of allocator round-trips per second on the
/// hot path.  The arena instead keeps three flat reusable buffers:
///
/// * `inputs`        — all context ids, windows back to back;
/// * `input_offsets` — `len()+1` cumulative offsets delimiting each
///   window's inputs (CSR-style);
/// * `outputs`       — exactly `s` ids per window (target, then the K
///   shared negatives).
///
/// [`BatchBuilder::fill_arena`] appends windows in place and
/// [`clear`](Self::clear) resets lengths without releasing capacity, so a
/// steady-state training loop performs no allocations per window
/// (asserted by `tests/alloc_steadystate.rs`).
#[derive(Clone, Debug)]
pub struct SuperbatchArena {
    inputs: Vec<u32>,
    input_offsets: Vec<u32>,
    outputs: Vec<u32>,
    /// Sentence serial of each window (one entry per window) — the
    /// reuse-lifetime bookkeeping: the GEMM backend's run-grouping
    /// driver may only share negatives across CONSECUTIVE windows with
    /// equal serials (`--reuse sentence`).  Serials are wrapping u32
    /// counters, unique enough to separate sentences within one arena;
    /// a wrap collision is backstopped by the driver's slots-equality
    /// check (and a false-positive group with identical negatives IS
    /// the defined reuse semantics, deterministically).
    sent: Vec<u32>,
    /// Serial stamped on the next [`push_window`](Self::push_window)
    /// (each direct push is its own sentence; the builder fills stamp
    /// their own per-sentence serial and advance this past it).
    next_sent: u32,
    /// Output rows per window (1 + K).
    s: usize,
    /// Input batch cap B (windows never exceed it).
    b_cap: usize,
}

impl SuperbatchArena {
    pub fn new(b_cap: usize, s: usize) -> Self {
        assert!(b_cap >= 1 && s >= 1);
        Self {
            inputs: Vec::new(),
            input_offsets: vec![0],
            outputs: Vec::new(),
            sent: Vec::new(),
            next_sent: 0,
            s,
            b_cap,
        }
    }

    /// Pre-size for `windows` windows so the first superbatch already runs
    /// allocation-free.
    pub fn with_capacity(windows: usize, b_cap: usize, s: usize) -> Self {
        let mut a = Self::new(b_cap, s);
        a.inputs.reserve(windows * b_cap);
        a.input_offsets.reserve(windows + 1);
        a.outputs.reserve(windows * s);
        a.sent.reserve(windows);
        a
    }

    /// The trainer-loop constructor: capacity for `superbatch` windows
    /// PLUS the worst-case overshoot of one appended sentence.
    ///
    /// The hot loop appends a WHOLE sentence via
    /// [`BatchBuilder::fill_arena`] before checking `len() >= superbatch`,
    /// so the arena can legitimately hold up to `superbatch − 1 +
    /// MAX_SENTENCE_LEN` windows at flush time (a sentence emits at most
    /// one window per token, and the reader clips sentences at
    /// [`MAX_SENTENCE_LEN`]).  Sizing for exactly `superbatch` windows
    /// made that overshoot reallocate — an a-priori bound here means the
    /// arena NEVER reallocates after construction, whatever the corpus
    /// streams in (asserted by `tests/alloc_steadystate.rs`).
    pub fn with_sentence_slack(superbatch: usize, b_cap: usize, s: usize) -> Self {
        Self::with_capacity(superbatch + MAX_SENTENCE_LEN, b_cap, s)
    }

    /// The ROUTED trainer-loop constructor (`--route {owner,head=<K>}`):
    /// sentence slack PLUS headroom for the windows that peers can have in
    /// flight toward this worker through the exchange mailboxes.
    ///
    /// Under routing the flush-time bound grows: right before a worker
    /// processes, its arena holds up to `superbatch − 1` pending windows,
    /// plus one clipped-at-[`MAX_SENTENCE_LEN`] appended sentence, plus
    /// everything [`append_from`](Self::append_from) adopted from the
    /// mailboxes — at most `inflight` windows, because the block rings are
    /// bounded and drained once per sentence (the trainer passes
    /// `Exchange::max_inflight()` here).  Sizing for all three terms keeps
    /// the no-realloc-after-construction guarantee
    /// (`with_sentence_slack`'s contract) on the routed path too, which
    /// `tests/alloc_steadystate.rs`'s routed leg asserts.
    pub fn with_route_slack(
        superbatch: usize,
        b_cap: usize,
        s: usize,
        inflight: usize,
    ) -> Self {
        Self::with_capacity(superbatch + MAX_SENTENCE_LEN + inflight, b_cap, s)
    }

    /// Number of windows currently stored.
    ///
    /// NOTE on capacity semantics: `len()` can legitimately exceed the
    /// `superbatch`/`windows` count a constructor was sized for — the
    /// constructors reserve CAPACITY, they do not cap occupancy.  The
    /// trainer's flush check is `len() >= superbatch`, and the slack
    /// constructors ([`with_sentence_slack`](Self::with_sentence_slack),
    /// [`with_route_slack`](Self::with_route_slack)) size for the exact
    /// worst-case overshoot of their fill pattern so that exceeding
    /// `superbatch` never reallocates; per-mailbox blocks are instead
    /// capped BY THE FILLER (the outbox flushes a block before it would
    /// pass `block_windows`), not by this type.
    #[inline]
    pub fn len(&self) -> usize {
        self.input_offsets.len() - 1
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Output rows per window (1 + K).
    #[inline]
    pub fn s(&self) -> usize {
        self.s
    }

    /// Input batch cap B.
    #[inline]
    pub fn b_cap(&self) -> usize {
        self.b_cap
    }

    /// Reset to empty, KEEPING all buffer capacity.  The sentence-serial
    /// counter is NOT reset, so windows filled after a clear never share
    /// a serial with windows from before it.
    pub fn clear(&mut self) {
        self.inputs.clear();
        self.input_offsets.clear();
        self.input_offsets.push(0);
        self.outputs.clear();
        self.sent.clear();
    }

    /// Context ids of window `w`.
    #[inline]
    pub fn inputs_of(&self, w: usize) -> &[u32] {
        let lo = self.input_offsets[w] as usize;
        let hi = self.input_offsets[w + 1] as usize;
        &self.inputs[lo..hi]
    }

    /// Target + shared negatives of window `w`.
    #[inline]
    pub fn outputs_of(&self, w: usize) -> &[u32] {
        &self.outputs[w * self.s..(w + 1) * self.s]
    }

    /// All output ids, windows back to back (`len() * s` entries) — the
    /// view the GEMM backend deduplicates shared negatives over.
    #[inline]
    pub fn outputs_flat(&self) -> &[u32] {
        &self.outputs
    }

    /// Sentence serial of window `w` — equal serials on CONSECUTIVE
    /// windows license the reuse driver to group them into one run.
    #[inline]
    pub fn sentence_of(&self, w: usize) -> u32 {
        self.sent[w]
    }

    /// Append one window directly (tests / custom drivers; the trainer
    /// fills through [`BatchBuilder::fill_arena`]).  Each direct push is
    /// stamped as its OWN sentence, so hand-built arenas never group
    /// into reuse runs unless pushed through
    /// [`push_window_in_sentence`](Self::push_window_in_sentence).
    pub fn push_window(&mut self, inputs: &[u32], outputs: &[u32]) {
        let serial = self.next_sent;
        self.next_sent = self.next_sent.wrapping_add(1);
        self.push_window_in_sentence(inputs, outputs, serial);
    }

    /// Append one window stamped with an explicit sentence serial
    /// (tests / custom drivers building multi-window reuse runs).
    pub fn push_window_in_sentence(
        &mut self,
        inputs: &[u32],
        outputs: &[u32],
        sentence: u32,
    ) {
        assert!(!inputs.is_empty() && inputs.len() <= self.b_cap);
        assert_eq!(outputs.len(), self.s);
        self.inputs.extend_from_slice(inputs);
        self.outputs.extend_from_slice(outputs);
        self.sent.push(sentence);
        self.input_offsets.push(self.inputs.len() as u32);
        self.next_sent = self.next_sent.max(sentence.wrapping_add(1));
    }

    /// Append every window of `other` (same geometry) — how a routed
    /// worker adopts a mailbox block into its working arena.  One slice
    /// copy per flat buffer plus an offset rebase; no per-window work.
    /// Sentence serials are adopted verbatim (the mailbox block was
    /// filled sentence-at-a-time by its producer, so serial runs stay
    /// contiguous within the block).
    pub fn append_from(&mut self, other: &SuperbatchArena) {
        assert_eq!(self.s, other.s, "append_from: S mismatch");
        assert_eq!(self.b_cap, other.b_cap, "append_from: B cap mismatch");
        let base = self.inputs.len() as u32;
        self.inputs.extend_from_slice(&other.inputs);
        self.outputs.extend_from_slice(&other.outputs);
        self.sent.extend_from_slice(&other.sent);
        self.input_offsets
            .extend(other.input_offsets[1..].iter().map(|&o| o + base));
        self.next_sent = self.next_sent.max(other.next_sent);
    }

    /// Materialise as allocated [`Window`]s (compatibility path for
    /// back-ends without a native arena implementation).
    pub fn to_windows(&self) -> Vec<Window> {
        (0..self.len())
            .map(|w| Window {
                inputs: self.inputs_of(w).to_vec(),
                outputs: self.outputs_of(w).to_vec(),
            })
            .collect()
    }
}

/// Where a generated window lands: the routed fill asks the sink for a
/// destination arena PER WINDOW, keyed by the window's target (output row
/// 0) — classification happens at generation time, BEFORE arena
/// placement, so a routed window's ids only ever enter the arena of the
/// worker that will process it (superbatch dedup slots stay node-local).
///
/// The trivial sink is an arena itself (everything local — exactly
/// [`BatchBuilder::fill_arena`]); the routing sink
/// (`train::route::RouteSink`) steers hot-target windows into per-worker
/// mailbox blocks instead.
pub trait WindowSink {
    /// Arena the next window with this target must be appended to.  The
    /// returned arena's geometry must match the builder's (`s == 1+K`,
    /// `b_cap == batch`).
    fn arena_for(&mut self, target: u32) -> &mut SuperbatchArena;
}

impl WindowSink for SuperbatchArena {
    #[inline]
    fn arena_for(&mut self, _target: u32) -> &mut SuperbatchArena {
        self
    }
}

/// Streams sentences into windows/superbatches.
pub struct BatchBuilder<'a> {
    sampler: &'a UnigramSampler,
    /// Max half-window c.
    window: usize,
    /// Input batch cap B.
    batch: usize,
    /// Negative samples K.
    negative: usize,
    /// Negative-draw lifetime (`--reuse`): `Off`/`Window` draw K
    /// negatives per window (identical RNG streams); `Sentence` draws K
    /// once per sentence and shares them across all its windows.
    reuse: ReuseMode,
    /// Sentence-scoped negative buffer (pre-sized to K at construction,
    /// so the steady-state fill stays allocation-free).
    neg_buf: Vec<u32>,
    /// Serial stamped on every window of the next filled sentence.
    sent_serial: u32,
}

impl<'a> BatchBuilder<'a> {
    pub fn new(
        sampler: &'a UnigramSampler,
        window: usize,
        batch: usize,
        negative: usize,
    ) -> Self {
        assert!(window >= 1 && batch >= 1 && negative >= 1);
        Self {
            sampler,
            window,
            batch,
            negative,
            reuse: ReuseMode::Off,
            neg_buf: Vec::with_capacity(negative),
            sent_serial: 0,
        }
    }

    /// Builder-style reuse selection (`--reuse`); `Off` is the default
    /// and keeps the per-window draw stream bit-for-bit.
    pub fn with_reuse(mut self, reuse: ReuseMode) -> Self {
        self.reuse = reuse;
        self
    }

    /// Output rows per window (1 + K).
    pub fn samples(&self) -> usize {
        1 + self.negative
    }

    /// Build the windows of one (already subsampled) sentence.
    ///
    /// Matches the original skip-gram traversal: every position is a
    /// center; its context words are the inputs; the center is the shared
    /// positive target.  Negatives exclude the target (resampled on
    /// collision), like the original.
    pub fn windows_of(
        &self,
        sentence: &[u32],
        rng: &mut Xoshiro256ss,
    ) -> Vec<Window> {
        let mut out = Vec::with_capacity(sentence.len());
        for t in 0..sentence.len() {
            let win = dynamic_window(self.window, rng);
            let mut inputs: Vec<u32> = context_range(t, win, sentence.len())
                .map(|p| sentence[p])
                .collect();
            if inputs.is_empty() {
                continue;
            }
            inputs.truncate(self.batch);
            let target = sentence[t];
            let mut outputs = Vec::with_capacity(1 + self.negative);
            outputs.push(target);
            for _ in 0..self.negative {
                outputs.push(self.sampler.sample_excluding(target, rng));
            }
            out.push(Window { inputs, outputs });
        }
        out
    }

    /// Append the windows of one (already subsampled) sentence into
    /// `arena` WITHOUT allocating per window — the zero-allocation
    /// counterpart of [`windows_of`](Self::windows_of).
    ///
    /// Consumes the RNG identically to `windows_of` (one dynamic-window
    /// draw per position, K negative draws per emitted window), so the two
    /// paths produce the same windows for the same seed (tested below).
    pub fn fill_arena(
        &mut self,
        sentence: &[u32],
        rng: &mut Xoshiro256ss,
        arena: &mut SuperbatchArena,
    ) {
        // Hard asserts (once per sentence): a geometry mismatch would
        // silently interleave windows at the wrong stride.
        assert_eq!(arena.s(), self.samples(), "arena S != builder 1+K");
        assert_eq!(arena.b_cap(), self.batch, "arena B cap != builder batch");
        // The arena IS the trivial all-local sink, so the unrouted and
        // routed fills are the same code by construction — `--route off`
        // cannot drift from the routed generator, and both consume the
        // RNG identically (one dynamic-window draw per position, K
        // negative draws per emitted window).
        self.fill_arena_routed(sentence, rng, arena);
    }

    /// The routed counterpart of [`fill_arena`](Self::fill_arena): every
    /// window is CLASSIFIED by its target before placement — the sink
    /// picks the destination arena (the worker's own, or a mailbox block
    /// bound for the worker whose NUMA node owns the target row).
    ///
    /// RNG consumption is independent of the sink's decisions (same draws
    /// as `fill_arena` for the same sentence), so routing never perturbs
    /// the generated window stream — only where each window is processed
    /// (`tests/routing_parity.rs` pins 1-thread bitwise equality).
    /// RNG NOTE under `--reuse sentence`: the per-sentence negative set
    /// is drawn up front (K draws, excluding the sentence's FIRST
    /// center), so the stream differs from the per-window modes by
    /// design — sentence reuse is a different (cheaper) sampling
    /// schedule, not a reordering of the same draws.  A later center
    /// that collides with one of the shared negatives simply yields a
    /// duplicate-slot window; the reuse driver routes those into
    /// singleton runs where the kernels' sequential fallback keeps the
    /// reference semantics.
    pub fn fill_arena_routed(
        &mut self,
        sentence: &[u32],
        rng: &mut Xoshiro256ss,
        sink: &mut impl WindowSink,
    ) {
        let sentence_negs =
            self.reuse == ReuseMode::Sentence && sentence.len() >= 2;
        if sentence_negs {
            self.neg_buf.clear();
            for _ in 0..self.negative {
                self.neg_buf
                    .push(self.sampler.sample_excluding(sentence[0], rng));
            }
        }
        let serial = self.sent_serial;
        self.sent_serial = self.sent_serial.wrapping_add(1);
        for t in 0..sentence.len() {
            let win = dynamic_window(self.window, rng);
            // Singleton sentences emit no window for their only center
            // (no context; the draw above still happens, like
            // `windows_of`).  Checked BEFORE consulting the sink so its
            // routed/local accounting only ever sees real windows.  For
            // `len >= 2` every center has ≥1 context word (win >= 1),
            // so a classified window is always emitted.
            if sentence.len() < 2 {
                continue;
            }
            let target = sentence[t];
            let arena = sink.arena_for(target);
            debug_assert_eq!(arena.s(), self.samples(), "arena S != builder 1+K");
            debug_assert_eq!(arena.b_cap(), self.batch, "arena B != builder");
            let start = arena.inputs.len();
            for p in context_range(t, win, sentence.len()) {
                if arena.inputs.len() - start == self.batch {
                    break;
                }
                arena.inputs.push(sentence[p]);
            }
            debug_assert!(arena.inputs.len() > start, "center lost its context");
            arena.outputs.push(target);
            if sentence_negs {
                arena.outputs.extend_from_slice(&self.neg_buf);
            } else {
                for _ in 0..self.negative {
                    arena
                        .outputs
                        .push(self.sampler.sample_excluding(target, rng));
                }
            }
            arena.sent.push(serial);
            arena.input_offsets.push(arena.inputs.len() as u32);
            arena.next_sent = arena.next_sent.max(serial.wrapping_add(1));
        }
    }

    /// Pack an iterator of sentences into superbatches of `w` windows.
    /// The trailing partial superbatch (if any) is returned too.
    pub fn superbatches<I>(
        &self,
        sentences: I,
        w: usize,
        rng: &mut Xoshiro256ss,
    ) -> Vec<Superbatch>
    where
        I: IntoIterator<Item = Vec<u32>>,
    {
        let mut out = Vec::new();
        let mut cur = Vec::with_capacity(w);
        let mut words = 0u64;
        for sent in sentences {
            words += sent.len() as u64;
            for win in self.windows_of(&sent, rng) {
                cur.push(win);
                if cur.len() == w {
                    out.push(Superbatch {
                        windows: std::mem::replace(&mut cur, Vec::with_capacity(w)),
                        b: self.batch,
                        s: self.samples(),
                        words: std::mem::take(&mut words),
                    });
                }
            }
        }
        if !cur.is_empty() {
            out.push(Superbatch {
                windows: cur,
                b: self.batch,
                s: self.samples(),
                words,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;
    use std::collections::HashMap;

    fn vocab(v: usize) -> Vocab {
        let counts: HashMap<String, u64> = (0..v)
            .map(|i| (format!("w{i:03}"), (1000 / (i + 1)) as u64))
            .collect();
        Vocab::from_counts(counts, 1)
    }

    fn builder_parts(v: usize) -> (Vocab, UnigramSampler) {
        let vc = vocab(v);
        let s = UnigramSampler::alias(&vc, 0.75);
        (vc, s)
    }

    #[test]
    fn every_position_is_a_center() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(1);
        let sent: Vec<u32> = (0..20).collect();
        let ws = b.windows_of(&sent, &mut rng);
        assert_eq!(ws.len(), 20);
        for (t, w) in ws.iter().enumerate() {
            assert_eq!(w.target(), sent[t]);
        }
    }

    #[test]
    fn negatives_shared_and_exclude_target() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(2);
        let sent: Vec<u32> = (0..10).collect();
        for w in b.windows_of(&sent, &mut rng) {
            assert_eq!(w.outputs.len(), 6);
            // one shared negative set per window, none equal to target
            for &n in w.negatives() {
                assert_ne!(n, w.target());
            }
            assert!(!w.inputs.is_empty());
            assert!(w.inputs.len() <= 16);
        }
    }

    #[test]
    fn inputs_are_context_words() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 2, 16, 5);
        let mut rng = Xoshiro256ss::new(3);
        let sent: Vec<u32> = vec![10, 11, 12, 13, 14];
        for (t, w) in b.windows_of(&sent, &mut rng).iter().enumerate() {
            for &inp in &w.inputs {
                let pos = sent.iter().position(|&x| x == inp).unwrap();
                assert!(pos != t);
                assert!((pos as isize - t as isize).unsigned_abs() <= 2);
            }
        }
    }

    #[test]
    fn batch_cap_respected() {
        let (_, s) = builder_parts(200);
        let b = BatchBuilder::new(&s, 50, 4, 5);
        let mut rng = Xoshiro256ss::new(4);
        let sent: Vec<u32> = (0..100).collect();
        for w in b.windows_of(&sent, &mut rng) {
            assert!(w.inputs.len() <= 4);
        }
    }

    #[test]
    fn singleton_sentence_yields_nothing() {
        let (_, s) = builder_parts(10);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(5);
        assert!(b.windows_of(&[3], &mut rng).is_empty());
    }

    #[test]
    fn superbatch_packing_and_word_counts() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(6);
        let sents: Vec<Vec<u32>> = (0..10).map(|_| (0..17).collect()).collect();
        let sbs = b.superbatches(sents.clone(), 64, &mut rng);
        let total_windows: usize = sbs.iter().map(|sb| sb.windows.len()).sum();
        assert_eq!(total_windows, 170); // every position a center
        let total_words: u64 = sbs.iter().map(|sb| sb.words).sum();
        assert_eq!(total_words, 170);
        for sb in &sbs[..sbs.len() - 1] {
            assert_eq!(sb.windows.len(), 64);
        }
    }

    /// The arena path must produce EXACTLY the windows of `windows_of`
    /// for the same seed (same RNG consumption, same truncation).
    #[test]
    fn arena_matches_windows_of() {
        let (_, s) = builder_parts(80);
        let mut b = BatchBuilder::new(&s, 5, 4, 5);
        let sent: Vec<u32> = (0..40).map(|i| i % 80).collect();
        let windows = b.windows_of(&sent, &mut Xoshiro256ss::new(21));
        let mut arena = SuperbatchArena::new(4, 6);
        b.fill_arena(&sent, &mut Xoshiro256ss::new(21), &mut arena);
        assert_eq!(arena.len(), windows.len());
        assert_eq!(arena.to_windows(), windows);
        for (w, win) in windows.iter().enumerate() {
            assert_eq!(arena.inputs_of(w), &win.inputs[..]);
            assert_eq!(arena.outputs_of(w), &win.outputs[..]);
        }
    }

    /// `clear` keeps capacity: refilling with the same stream allocates
    /// nothing (capacity pointers stay put).
    #[test]
    fn arena_clear_keeps_capacity() {
        let (_, s) = builder_parts(50);
        let mut b = BatchBuilder::new(&s, 5, 16, 5);
        let sent: Vec<u32> = (0..30).collect();
        let mut arena = SuperbatchArena::new(16, 6);
        b.fill_arena(&sent, &mut Xoshiro256ss::new(3), &mut arena);
        let caps = (
            arena.inputs.capacity(),
            arena.input_offsets.capacity(),
            arena.outputs.capacity(),
        );
        for round in 0..5 {
            arena.clear();
            assert!(arena.is_empty(), "round {round}");
            b.fill_arena(&sent, &mut Xoshiro256ss::new(3), &mut arena);
            assert_eq!(
                caps,
                (
                    arena.inputs.capacity(),
                    arena.input_offsets.capacity(),
                    arena.outputs.capacity(),
                ),
                "capacity changed on refill round {round}"
            );
        }
    }

    #[test]
    fn arena_with_capacity_presizes() {
        let a = SuperbatchArena::with_capacity(64, 16, 6);
        assert!(a.inputs.capacity() >= 64 * 16);
        assert!(a.outputs.capacity() >= 64 * 6);
        assert!(a.input_offsets.capacity() >= 65);
        assert_eq!(a.len(), 0);
    }

    /// Sentence-slack sizing covers the worst legal overshoot: a
    /// superbatch one window short of full, plus a clipped-at-maximum
    /// sentence appended on top — no buffer may reallocate.
    #[test]
    fn sentence_slack_absorbs_max_sentence_overshoot() {
        let (_, s) = builder_parts(50);
        let superbatch = 4usize;
        let mut b = BatchBuilder::new(&s, 5, 16, 5);
        let mut arena = SuperbatchArena::with_sentence_slack(superbatch, 16, 6);
        let caps = (
            arena.inputs.capacity(),
            arena.input_offsets.capacity(),
            arena.outputs.capacity(),
        );
        let mut rng = Xoshiro256ss::new(11);
        // superbatch − 1 windows already pending...
        let stub: Vec<u32> = (0..(superbatch as u32 - 1)).collect();
        b.fill_arena(&stub, &mut rng, &mut arena);
        assert_eq!(arena.len(), superbatch - 1);
        // ...then one maximum-length sentence lands in one append.
        let long: Vec<u32> =
            (0..MAX_SENTENCE_LEN as u32).map(|i| i % 50).collect();
        b.fill_arena(&long, &mut rng, &mut arena);
        assert_eq!(arena.len(), superbatch - 1 + MAX_SENTENCE_LEN);
        assert_eq!(
            caps,
            (
                arena.inputs.capacity(),
                arena.input_offsets.capacity(),
                arena.outputs.capacity(),
            ),
            "sentence-slack arena reallocated on worst-case overshoot"
        );
    }

    /// `append_from` rebases input offsets correctly: a concatenated
    /// arena reads back window-for-window as source A then source B.
    #[test]
    fn append_from_concatenates_and_rebases_offsets() {
        let (_, s) = builder_parts(60);
        let mut b = BatchBuilder::new(&s, 4, 8, 5);
        let sa: Vec<u32> = (0..15).collect();
        let sb: Vec<u32> = (20..50).collect();
        let mut a = SuperbatchArena::new(8, 6);
        let mut bb = SuperbatchArena::new(8, 6);
        b.fill_arena(&sa, &mut Xoshiro256ss::new(7), &mut a);
        b.fill_arena(&sb, &mut Xoshiro256ss::new(8), &mut bb);
        let (la, lb) = (a.len(), bb.len());
        let mut merged = SuperbatchArena::new(8, 6);
        merged.append_from(&a);
        merged.append_from(&bb);
        assert_eq!(merged.len(), la + lb);
        for w in 0..la {
            assert_eq!(merged.inputs_of(w), a.inputs_of(w), "window {w}");
            assert_eq!(merged.outputs_of(w), a.outputs_of(w), "window {w}");
        }
        for w in 0..lb {
            assert_eq!(merged.inputs_of(la + w), bb.inputs_of(w), "window {w}");
            assert_eq!(merged.outputs_of(la + w), bb.outputs_of(w), "window {w}");
        }
    }

    /// A sink that splits windows by target parity across two arenas —
    /// the routed fill must (a) consume the RNG exactly like the plain
    /// fill and (b) partition the plain fill's windows without loss,
    /// duplication, or reordering within each destination.
    #[test]
    fn routed_fill_partitions_plain_fill() {
        struct ParitySink {
            even: SuperbatchArena,
            odd: SuperbatchArena,
        }
        impl WindowSink for ParitySink {
            fn arena_for(&mut self, target: u32) -> &mut SuperbatchArena {
                if target % 2 == 0 {
                    &mut self.even
                } else {
                    &mut self.odd
                }
            }
        }
        let (_, s) = builder_parts(80);
        let mut b = BatchBuilder::new(&s, 5, 4, 5);
        let sent: Vec<u32> = (0..40).map(|i| (i * 13) % 80).collect();
        let mut plain = SuperbatchArena::new(4, 6);
        b.fill_arena(&sent, &mut Xoshiro256ss::new(31), &mut plain);
        let mut sink = ParitySink {
            even: SuperbatchArena::new(4, 6),
            odd: SuperbatchArena::new(4, 6),
        };
        b.fill_arena_routed(&sent, &mut Xoshiro256ss::new(31), &mut sink);
        assert_eq!(sink.even.len() + sink.odd.len(), plain.len());
        let (mut ie, mut io) = (0usize, 0usize);
        for w in 0..plain.len() {
            let target = plain.outputs_of(w)[0];
            let (dst, idx) = if target % 2 == 0 {
                (&sink.even, &mut ie)
            } else {
                (&sink.odd, &mut io)
            };
            assert_eq!(dst.inputs_of(*idx), plain.inputs_of(w), "window {w}");
            assert_eq!(dst.outputs_of(*idx), plain.outputs_of(w), "window {w}");
            *idx += 1;
        }
        assert_eq!(ie, sink.even.len());
        assert_eq!(io, sink.odd.len());
    }

    /// Route-slack sizing covers the routed worst case: a superbatch one
    /// window short of full, a clipped-at-maximum appended sentence, AND
    /// a full complement of in-flight mailbox windows adopted via
    /// `append_from` — no buffer may reallocate (the satellite fix for
    /// the `len()`-vs-capacity mismatch risk under routed fills).
    #[test]
    fn route_slack_absorbs_sentence_plus_inflight_overshoot() {
        let (_, s) = builder_parts(50);
        let superbatch = 4usize;
        let inflight = 96usize;
        let mut b = BatchBuilder::new(&s, 5, 16, 5);
        let mut arena =
            SuperbatchArena::with_route_slack(superbatch, 16, 6, inflight);
        let caps = (
            arena.inputs.capacity(),
            arena.input_offsets.capacity(),
            arena.outputs.capacity(),
        );
        let mut rng = Xoshiro256ss::new(13);
        // superbatch − 1 windows already pending...
        let stub: Vec<u32> = (0..(superbatch as u32 - 1)).collect();
        b.fill_arena(&stub, &mut rng, &mut arena);
        // ...then one maximum-length sentence in one append...
        let long: Vec<u32> =
            (0..MAX_SENTENCE_LEN as u32).map(|i| i % 50).collect();
        b.fill_arena(&long, &mut rng, &mut arena);
        // ...then the worst-case mailbox drain: `inflight` windows, every
        // one at the full B cap (worse than any real block mix).
        let mut block = SuperbatchArena::with_capacity(inflight, 16, 6);
        let inputs = [1u32; 16];
        let outputs = [2u32; 6];
        for _ in 0..inflight {
            block.push_window(&inputs, &outputs);
        }
        arena.append_from(&block);
        assert_eq!(
            arena.len(),
            superbatch - 1 + MAX_SENTENCE_LEN + inflight
        );
        assert_eq!(
            caps,
            (
                arena.inputs.capacity(),
                arena.input_offsets.capacity(),
                arena.outputs.capacity(),
            ),
            "route-slack arena reallocated on worst-case routed overshoot"
        );
    }

    #[test]
    fn determinism_per_seed() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let sent: Vec<u32> = (0..30).collect();
        let w1 = b.windows_of(&sent, &mut Xoshiro256ss::new(9));
        let w2 = b.windows_of(&sent, &mut Xoshiro256ss::new(9));
        assert_eq!(w1, w2);
    }

    /// `reuse window` must not perturb generation at all: same RNG
    /// stream, same windows, bit for bit — only the driver changes.
    #[test]
    fn window_reuse_generates_identical_windows() {
        let (_, s) = builder_parts(60);
        let sent: Vec<u32> = (0..25).map(|i| (i * 7) % 60).collect();
        let mut off = SuperbatchArena::new(16, 6);
        let mut win = SuperbatchArena::new(16, 6);
        let mut b_off = BatchBuilder::new(&s, 5, 16, 5);
        let mut b_win =
            BatchBuilder::new(&s, 5, 16, 5).with_reuse(ReuseMode::Window);
        b_off.fill_arena(&sent, &mut Xoshiro256ss::new(17), &mut off);
        b_win.fill_arena(&sent, &mut Xoshiro256ss::new(17), &mut win);
        assert_eq!(off.to_windows(), win.to_windows());
    }

    /// Sentence reuse: every window of a sentence carries the SAME K
    /// negatives (drawn once, excluding the first center), each window
    /// keeps its own positive, and all windows share one serial.
    #[test]
    fn sentence_reuse_shares_negatives_and_serial() {
        let (_, s) = builder_parts(60);
        let mut b =
            BatchBuilder::new(&s, 5, 16, 5).with_reuse(ReuseMode::Sentence);
        let sent: Vec<u32> = (0..20).map(|i| (i * 3) % 60).collect();
        let mut arena = SuperbatchArena::new(16, 6);
        b.fill_arena(&sent, &mut Xoshiro256ss::new(23), &mut arena);
        assert_eq!(arena.len(), sent.len());
        let negs = arena.outputs_of(0)[1..].to_vec();
        for n in &negs {
            assert_ne!(*n, sent[0], "negatives exclude the first center");
        }
        let serial = arena.sentence_of(0);
        for w in 0..arena.len() {
            assert_eq!(arena.outputs_of(w)[0], sent[w], "positive per window");
            assert_eq!(
                &arena.outputs_of(w)[1..],
                &negs[..],
                "window {w}: negatives not shared"
            );
            assert_eq!(arena.sentence_of(w), serial, "window {w} serial");
        }
        // A second sentence gets a fresh serial and fresh negatives.
        let sent2: Vec<u32> = (30..45).collect();
        b.fill_arena(&sent2, &mut Xoshiro256ss::new(24), &mut arena);
        assert_ne!(arena.sentence_of(sent.len()), serial);
        for w in sent.len()..arena.len() {
            assert_eq!(arena.sentence_of(w), arena.sentence_of(sent.len()));
        }
    }

    /// Serial bookkeeping across the arena plumbing: direct pushes are
    /// one sentence each, explicit-serial pushes group, `append_from`
    /// adopts serials verbatim, and `clear` never recycles a serial.
    #[test]
    fn sentence_serial_bookkeeping() {
        let mut a = SuperbatchArena::new(4, 3);
        a.push_window(&[1, 2], &[7, 8, 9]);
        a.push_window(&[3], &[10, 11, 12]);
        assert_ne!(a.sentence_of(0), a.sentence_of(1), "direct pushes split");
        a.push_window_in_sentence(&[4], &[13, 14, 15], 40);
        a.push_window_in_sentence(&[5], &[13, 14, 15], 40);
        assert_eq!(a.sentence_of(2), 40);
        assert_eq!(a.sentence_of(3), 40);
        // next_sent advanced past the explicit serial: a later direct
        // push cannot collide with sentence 40.
        a.push_window(&[6], &[16, 17, 18]);
        assert_ne!(a.sentence_of(4), 40);

        let mut b = SuperbatchArena::new(4, 3);
        b.append_from(&a);
        for w in 0..a.len() {
            assert_eq!(b.sentence_of(w), a.sentence_of(w), "window {w}");
        }

        // clear keeps the counter running: post-clear pushes never share
        // a serial with pre-clear windows.
        let before = a.sentence_of(4);
        a.clear();
        a.push_window(&[1], &[7, 8, 9]);
        assert!(a.sentence_of(0) > before);
    }
}
