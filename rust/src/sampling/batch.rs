//! Minibatch / superbatch assembly — the heart of the paper's
//! parallelization scheme (Sec. III-B, Fig. 2 right).
//!
//! For each center position `t` of a sentence we form one [`Window`]:
//!
//! * `inputs`  — the context words around `t` (at most `B` of them; the
//!   dynamic window already bounds this by `2*window`), which become the
//!   rows of `Wi[B, D]`;
//! * `outputs` — the center word (positive target) followed by `K`
//!   negative samples drawn ONCE and **shared by every input in the
//!   batch** ("negative sample sharing"), the rows of `Wo[S, D]`.
//!
//! [`BatchBuilder`] packs `W` consecutive windows into a [`Superbatch`] so
//! one kernel/PJRT call covers many windows (our artifact-amortisation
//! knob; the pure-rust GEMM trainer uses W=1-equivalent inner loops).

use super::unigram::UnigramSampler;
use super::window::{context_range, dynamic_window};
use crate::util::rng::Xoshiro256ss;

/// One training window: a batch of input words sharing target + negatives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Window {
    /// Context word ids (the rows of Wi). Non-empty, len <= batch cap.
    pub inputs: Vec<u32>,
    /// Target (index 0) then the K shared negative ids (rows of Wo).
    pub outputs: Vec<u32>,
}

impl Window {
    pub fn target(&self) -> u32 {
        self.outputs[0]
    }

    pub fn negatives(&self) -> &[u32] {
        &self.outputs[1..]
    }
}

/// A fixed-geometry batch of `W` windows, padded for the AOT artifact path.
#[derive(Clone, Debug)]
pub struct Superbatch {
    pub windows: Vec<Window>,
    /// Geometry every window is padded to by the PJRT trainer.
    pub b: usize,
    pub s: usize,
    /// Tokens consumed building this superbatch (for throughput/lr decay);
    /// counts every center position processed, as the original does.
    pub words: u64,
}

/// Streams sentences into windows/superbatches.
pub struct BatchBuilder<'a> {
    sampler: &'a UnigramSampler,
    /// Max half-window c.
    window: usize,
    /// Input batch cap B.
    batch: usize,
    /// Negative samples K.
    negative: usize,
}

impl<'a> BatchBuilder<'a> {
    pub fn new(
        sampler: &'a UnigramSampler,
        window: usize,
        batch: usize,
        negative: usize,
    ) -> Self {
        assert!(window >= 1 && batch >= 1 && negative >= 1);
        Self {
            sampler,
            window,
            batch,
            negative,
        }
    }

    /// Output rows per window (1 + K).
    pub fn samples(&self) -> usize {
        1 + self.negative
    }

    /// Build the windows of one (already subsampled) sentence.
    ///
    /// Matches the original skip-gram traversal: every position is a
    /// center; its context words are the inputs; the center is the shared
    /// positive target.  Negatives exclude the target (resampled on
    /// collision), like the original.
    pub fn windows_of(
        &self,
        sentence: &[u32],
        rng: &mut Xoshiro256ss,
    ) -> Vec<Window> {
        let mut out = Vec::with_capacity(sentence.len());
        for t in 0..sentence.len() {
            let win = dynamic_window(self.window, rng);
            let mut inputs: Vec<u32> = context_range(t, win, sentence.len())
                .map(|p| sentence[p])
                .collect();
            if inputs.is_empty() {
                continue;
            }
            inputs.truncate(self.batch);
            let target = sentence[t];
            let mut outputs = Vec::with_capacity(1 + self.negative);
            outputs.push(target);
            for _ in 0..self.negative {
                outputs.push(self.sampler.sample_excluding(target, rng));
            }
            out.push(Window { inputs, outputs });
        }
        out
    }

    /// Pack an iterator of sentences into superbatches of `w` windows.
    /// The trailing partial superbatch (if any) is returned too.
    pub fn superbatches<I>(
        &self,
        sentences: I,
        w: usize,
        rng: &mut Xoshiro256ss,
    ) -> Vec<Superbatch>
    where
        I: IntoIterator<Item = Vec<u32>>,
    {
        let mut out = Vec::new();
        let mut cur = Vec::with_capacity(w);
        let mut words = 0u64;
        for sent in sentences {
            words += sent.len() as u64;
            for win in self.windows_of(&sent, rng) {
                cur.push(win);
                if cur.len() == w {
                    out.push(Superbatch {
                        windows: std::mem::replace(&mut cur, Vec::with_capacity(w)),
                        b: self.batch,
                        s: self.samples(),
                        words: std::mem::take(&mut words),
                    });
                }
            }
        }
        if !cur.is_empty() {
            out.push(Superbatch {
                windows: cur,
                b: self.batch,
                s: self.samples(),
                words,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;
    use std::collections::HashMap;

    fn vocab(v: usize) -> Vocab {
        let counts: HashMap<String, u64> = (0..v)
            .map(|i| (format!("w{i:03}"), (1000 / (i + 1)) as u64))
            .collect();
        Vocab::from_counts(counts, 1)
    }

    fn builder_parts(v: usize) -> (Vocab, UnigramSampler) {
        let vc = vocab(v);
        let s = UnigramSampler::alias(&vc, 0.75);
        (vc, s)
    }

    #[test]
    fn every_position_is_a_center() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(1);
        let sent: Vec<u32> = (0..20).collect();
        let ws = b.windows_of(&sent, &mut rng);
        assert_eq!(ws.len(), 20);
        for (t, w) in ws.iter().enumerate() {
            assert_eq!(w.target(), sent[t]);
        }
    }

    #[test]
    fn negatives_shared_and_exclude_target() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(2);
        let sent: Vec<u32> = (0..10).collect();
        for w in b.windows_of(&sent, &mut rng) {
            assert_eq!(w.outputs.len(), 6);
            // one shared negative set per window, none equal to target
            for &n in w.negatives() {
                assert_ne!(n, w.target());
            }
            assert!(!w.inputs.is_empty());
            assert!(w.inputs.len() <= 16);
        }
    }

    #[test]
    fn inputs_are_context_words() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 2, 16, 5);
        let mut rng = Xoshiro256ss::new(3);
        let sent: Vec<u32> = vec![10, 11, 12, 13, 14];
        for (t, w) in b.windows_of(&sent, &mut rng).iter().enumerate() {
            for &inp in &w.inputs {
                let pos = sent.iter().position(|&x| x == inp).unwrap();
                assert!(pos != t);
                assert!((pos as isize - t as isize).unsigned_abs() <= 2);
            }
        }
    }

    #[test]
    fn batch_cap_respected() {
        let (_, s) = builder_parts(200);
        let b = BatchBuilder::new(&s, 50, 4, 5);
        let mut rng = Xoshiro256ss::new(4);
        let sent: Vec<u32> = (0..100).collect();
        for w in b.windows_of(&sent, &mut rng) {
            assert!(w.inputs.len() <= 4);
        }
    }

    #[test]
    fn singleton_sentence_yields_nothing() {
        let (_, s) = builder_parts(10);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(5);
        assert!(b.windows_of(&[3], &mut rng).is_empty());
    }

    #[test]
    fn superbatch_packing_and_word_counts() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let mut rng = Xoshiro256ss::new(6);
        let sents: Vec<Vec<u32>> = (0..10).map(|_| (0..17).collect()).collect();
        let sbs = b.superbatches(sents.clone(), 64, &mut rng);
        let total_windows: usize = sbs.iter().map(|sb| sb.windows.len()).sum();
        assert_eq!(total_windows, 170); // every position a center
        let total_words: u64 = sbs.iter().map(|sb| sb.words).sum();
        assert_eq!(total_words, 170);
        for sb in &sbs[..sbs.len() - 1] {
            assert_eq!(sb.windows.len(), 64);
        }
    }

    #[test]
    fn determinism_per_seed() {
        let (_, s) = builder_parts(50);
        let b = BatchBuilder::new(&s, 5, 16, 5);
        let sent: Vec<u32> = (0..30).collect();
        let w1 = b.windows_of(&sent, &mut Xoshiro256ss::new(9));
        let w2 = b.windows_of(&sent, &mut Xoshiro256ss::new(9));
        assert_eq!(w1, w2);
    }
}
