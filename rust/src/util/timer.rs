//! Timing + throughput measurement helpers (criterion is not vendored;
//! `crate::bench` builds the stats harness on top of these).

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Words-per-second meter with a monotonically growing count.
#[derive(Debug)]
pub struct ThroughputMeter {
    sw: Stopwatch,
    items: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        Self {
            sw: Stopwatch::new(),
            items: 0,
        }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    /// Items per second since construction.
    pub fn rate(&self) -> f64 {
        let s = self.sw.secs();
        if s <= 0.0 {
            0.0
        } else {
            self.items as f64 / s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }

    #[test]
    fn throughput_counts() {
        let mut m = ThroughputMeter::new();
        m.add(100);
        m.add(50);
        assert_eq!(m.items(), 150);
        std::thread::sleep(Duration::from_millis(2));
        assert!(m.rate() > 0.0);
    }
}
