//! Minimal JSON parser + writer (serde_json is not vendored offline).
//!
//! Covers the full JSON grammar minus some escape exotica; used to read
//! `artifacts/manifest.json` and to emit machine-readable bench results.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

// Hand-rolled Display/Error impls: thiserror is not vendored offline
// (anyhow is the crate's only external dependency).
impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience: `obj.field(k)` with a descriptive error.
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    /// Build an object from `(key, value)` pairs — the bench emitters'
    /// construction helper (`serde_json::json!` is not vendored offline).
    pub fn obj<I>(pairs: I) -> Json
    where
        I: IntoIterator<Item = (&'static str, Json)>,
    {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Shorthand numeric constructor.  Non-finite values are accepted
    /// here but serialise as `null` — JSON has no NaN/Infinity literal,
    /// and the writer must never emit a document its own parser rejects.
    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    /// Shorthand string constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

/// Write `s` as a JSON string literal (quotes + escapes) into any
/// `fmt::Write` sink.  This is the single escaping routine: `Json::Str`'s
/// `Display` delegates here, and the serve response path calls it
/// directly on a reusable `String` so emitting a response allocates
/// nothing beyond the buffer it is given.
pub fn write_json_str<W: fmt::Write>(w: &mut W, s: &str) -> fmt::Result {
    w.write_char('"')?;
    JsonEscaper(w).write_str(s)?;
    w.write_char('"')
}

/// `fmt::Write` adapter that JSON-escapes everything written through it
/// (content only — the caller writes the surrounding quotes).  Lets a
/// `Display` value be streamed straight into a JSON string field with
/// no intermediate allocation.
pub struct JsonEscaper<'a, W: fmt::Write>(pub &'a mut W);

impl<W: fmt::Write> fmt::Write for JsonEscaper<'_, W> {
    fn write_str(&mut self, s: &str) -> fmt::Result {
        for c in s.chars() {
            match c {
                '"' => self.0.write_str("\\\"")?,
                '\\' => self.0.write_str("\\\\")?,
                '\n' => self.0.write_str("\\n")?,
                '\r' => self.0.write_str("\\r")?,
                '\t' => self.0.write_str("\\t")?,
                c if (c as u32) < 0x20 => write!(self.0, "\\u{:04x}", c as u32)?,
                c => self.0.write_char(c)?,
            }
        }
        Ok(())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // Surrogate pairs unsupported (not needed for our
                            // machine-generated inputs); map to replacement.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (valid UTF-8 since input is &str).
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos]).unwrap(),
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                // JSON has no spelling for NaN/±Infinity: `{x}` would
                // emit `NaN`/`inf`, which this module's own parser (and
                // every other one) rejects.  Emit `null` instead so the
                // writer can never produce un-parseable output — the
                // asymmetry is pinned by `nonfinite_numbers_serialise_as_null`.
                if !x.is_finite() {
                    write!(f, "null")
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_json_str(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"b":16,"d":300,"file":"x.hlo.txt","name":"paper"}],"format":"hlo-text"}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""A""#).unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn obj_builder_constructs_and_serialises() {
        let j = Json::obj([
            ("name", Json::str("fused")),
            ("x", Json::num(1.5)),
            ("rows", Json::Arr(vec![Json::num(1), Json::num(2)])),
        ]);
        assert_eq!(j.get("name").unwrap().as_str(), Some("fused"));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn escaper_streams_display_values() {
        use fmt::Write as _;
        let mut out = String::new();
        write!(JsonEscaper(&mut out), "say \"hi\"\n{}", 1.5).unwrap();
        assert_eq!(out, "say \\\"hi\\\"\\n1.5");
        assert!(Json::parse(&format!("\"{out}\"")).is_ok());
    }

    #[test]
    fn nonfinite_numbers_serialise_as_null() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::num(x).to_string();
            assert_eq!(s, "null", "{x} must not leak into the output");
            assert_eq!(Json::parse(&s).unwrap(), Json::Null);
        }
        // Nested: a hostile value anywhere in a tree still yields a
        // document the parser accepts.
        let j = Json::obj([
            ("ok", Json::num(1.25)),
            ("bad", Json::Arr(vec![Json::num(f64::NAN), Json::num(2)])),
        ]);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bad").unwrap().as_arr().unwrap()[0], Json::Null);
        assert_eq!(back.get("ok").unwrap().as_f64(), Some(1.25));
    }

    #[test]
    fn numeric_write_parse_roundtrip_property() {
        // Every f64 the writer can see — integral, subnormal-adjacent,
        // huge, tiny, negative, and non-finite — must serialise to
        // something the parser accepts; finite values must round-trip
        // to an equal value (Rust's shortest-repr float Display is
        // exact; the sole canonicalisation is -0.0 -> "0").
        let mut rng = crate::util::rng::Xoshiro256ss::new(0x5EED_1234);
        let mut cases: Vec<f64> = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            1e-300,
            -1e300,
            1e15,
            -1e15,
            (1u64 << 53) as f64,
            f64::MIN_POSITIVE,
            f64::EPSILON,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
        ];
        for _ in 0..500 {
            // Random bit patterns cover exponent/mantissa space far
            // better than uniform [0,1) draws.
            cases.push(f64::from_bits(rng.next_u64()));
            cases.push(rng.next_f64() * 1e6 - 5e5);
        }
        for x in cases {
            let s = Json::num(x).to_string();
            let back = Json::parse(&s)
                .unwrap_or_else(|e| panic!("writer emitted unparseable {s:?} for {x}: {e}"));
            if x.is_finite() {
                let y = back.as_f64().unwrap_or_else(|| panic!("{x} -> {s:?} -> non-number"));
                assert!(y == x, "{x} -> {s:?} -> {y}");
                if x != 0.0 {
                    assert_eq!(y.to_bits(), x.to_bits(), "{x} -> {s:?} -> {y}");
                }
            } else {
                assert_eq!(back, Json::Null, "{x} -> {s:?}");
            }
        }
    }
}
