//! Raw read-only file mapping, shared by the corpus cache
//! (`corpus::encoded`) and the serve-side row store (`serve::store`).
//!
//! `std` already links the platform libc, so declaring
//! `mmap(2)`/`munmap(2)` directly keeps the offline build dependency-free
//! (the constants below are the Linux/BSD values for 64-bit targets;
//! other platforms take the buffered path).  Callers hold a [`Bytes`]:
//! a private read-only mapping where available, else the file read into
//! memory — behind `Deref<Target = [u8]>` the two are interchangeable.

use std::path::Path;

/// Backing storage for an open read-only file: a mmap where available,
/// else the file contents in memory.
pub enum Bytes {
    Owned(Vec<u8>),
    #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
    Mapped(Mmap),
}

impl std::ops::Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match self {
            Bytes::Owned(v) => v,
            #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
            Bytes::Mapped(m) => m.as_slice(),
        }
    }
}

/// Open `path` read-only.  With `prefer_map` set the file is mmapped on
/// 64-bit unix builds with the `mmap` feature; otherwise (or on any other
/// configuration) it is read into memory in one buffered pass.  Callers
/// own their opt-out policy — e.g. `corpus::encoded` consults
/// `PW2V_CORPUS_MMAP` before asking for a mapping.
pub fn load_bytes(path: &Path, prefer_map: bool) -> anyhow::Result<Bytes> {
    #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
    {
        if prefer_map {
            let f = std::fs::File::open(path)?;
            return Ok(Bytes::Mapped(Mmap::map(&f)?));
        }
    }
    let _ = prefer_map;
    Ok(Bytes::Owned(std::fs::read(path)?))
}

/// Raw read-only private mapping of a whole file.
#[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
pub struct Mmap {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

#[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
mod imp {
    use super::Mmap;
    use std::ffi::{c_int, c_void};
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, length: usize) -> c_int;
    }

    // SAFETY: the mapping is PROT_READ and private; no writer exists for
    // its lifetime, so shared immutable access from any thread is sound.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        pub fn map(f: &File) -> std::io::Result<Self> {
            let len = f.metadata()?.len() as usize;
            if len == 0 {
                // mmap(2) rejects zero-length mappings.
                return Ok(Self {
                    ptr: std::ptr::null_mut(),
                    len: 0,
                });
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    f.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Self { ptr, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            if self.len == 0 {
                return &[];
            }
            // SAFETY: `ptr` is a live PROT_READ mapping of `len` bytes.
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            if self.len > 0 {
                // SAFETY: `ptr`/`len` came from a successful mmap call.
                let _ = unsafe { munmap(self.ptr, self.len) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::path::PathBuf;

    fn write_tmp(name: &str, content: &[u8]) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("pw2v_mmap_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content).unwrap();
        path
    }

    #[test]
    fn mapped_and_owned_agree() {
        let path = write_tmp("agree.bin", b"hello mapped world");
        let mapped = load_bytes(&path, true).unwrap();
        let owned = load_bytes(&path, false).unwrap();
        assert_eq!(&mapped[..], b"hello mapped world");
        assert_eq!(&mapped[..], &owned[..]);
        assert!(matches!(owned, Bytes::Owned(_)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zero_length_file_maps_to_empty_slice() {
        let path = write_tmp("empty.bin", b"");
        let b = load_bytes(&path, true).unwrap();
        assert!(b.is_empty());
        std::fs::remove_file(&path).ok();
    }
}
