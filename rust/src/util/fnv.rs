//! FNV-1a 64-bit — the repo's one integrity/digest hash.
//!
//! Used by the checkpoint trailer (`model/io.rs`), the ring frame
//! checksum (`dist/net.rs`) and the config fingerprint
//! (`config.rs::TrainConfig::fingerprint`).  Not cryptographic; it
//! detects truncation and corruption, which is all those callers need.

const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Self(OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(PRIME);
        }
    }

    pub fn digest(&self) -> u64 {
        self.0
    }
}

/// One-shot convenience.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.digest()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.update(b"foo");
        h.update(b"bar");
        assert_eq!(h.digest(), fnv1a(b"foobar"));
    }

    #[test]
    fn detects_single_bit_flip() {
        let a = fnv1a(&[0u8; 64]);
        let mut buf = [0u8; 64];
        buf[63] = 1;
        assert_ne!(a, fnv1a(&buf));
    }
}
