//! Deterministic pseudo-random number generators.
//!
//! Two generators are provided:
//!
//! * [`W2vRng`] — the exact 64-bit LCG used by Mikolov's reference
//!   word2vec (`next_random = next_random * 25214903917 + 11`).  The
//!   scalar baseline trainer uses this so its sampling behaviour is
//!   bit-faithful to the original C code.
//! * [`SplitMix64`] / [`Xoshiro256ss`] — fast, well-distributed generators
//!   for everything else (corpus synthesis, initialization, shuffling).

/// The LCG from Mikolov's word2vec reference implementation.
#[derive(Clone, Debug)]
pub struct W2vRng {
    state: u64,
}

impl W2vRng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Advance and return the raw 64-bit LCG state (as the C code does).
    #[inline]
    pub fn next_raw(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(25_214_903_917)
            .wrapping_add(11);
        self.state
    }

    /// The >>16 & 0xFFFF draw the C code uses for table lookups.
    #[inline]
    pub fn next_u16(&mut self) -> u16 {
        ((self.next_raw() >> 16) & 0xFFFF) as u16
    }

    /// Uniform in [0, 1) with the 16-bit resolution of the original code
    /// (`(next_random & 0xFFFF) / 65536`).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_raw() & 0xFFFF) as f32 / 65_536.0
    }
}

/// SplitMix64 — used to seed and for one-shot hashing of seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the general-purpose generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256ss {
    s: [u64; 4],
}

impl Xoshiro256ss {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [
                sm.next_u64(),
                sm.next_u64(),
                sm.next_u64(),
                sm.next_u64(),
            ],
        }
    }

    /// Snapshot the generator state (checkpoint header payload).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`state`](Self::state) snapshot; the
    /// restored generator continues the exact sequence of the original
    /// (pinned by `state_roundtrip_resumes_sequence`).
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire's multiply-shift, debiased
    /// approximately — fine for sampling use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (pairs discarded; simple and fine
    /// for init + corpus synthesis).
    pub fn next_gauss(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn w2v_rng_matches_c_sequence() {
        // First few states of next_random starting from seed 1, computed
        // from the C recurrence.
        let mut r = W2vRng::new(1);
        assert_eq!(r.next_raw(), 25_214_903_928);
        assert_eq!(
            r.next_raw(),
            25_214_903_928u64
                .wrapping_mul(25_214_903_917)
                .wrapping_add(11)
        );
    }

    #[test]
    fn w2v_f32_in_unit_interval() {
        let mut r = W2vRng::new(7);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn xoshiro_uniformity_rough() {
        let mut r = Xoshiro256ss::new(42);
        let n = 100_000;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            buckets[(r.next_f64() * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket frac {frac}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Xoshiro256ss::new(3);
        for n in [1usize, 2, 7, 100, 1_000_000] {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Xoshiro256ss::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gauss();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256ss::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn state_roundtrip_resumes_sequence() {
        let mut a = Xoshiro256ss::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let mut b = Xoshiro256ss::from_state(snap);
        let resumed: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Xoshiro256ss::new(9);
        let mut b = Xoshiro256ss::new(9);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
