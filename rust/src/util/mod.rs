//! Self-contained utility substrates.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (clap, serde/serde_json, rand,
//! criterion, proptest) are unavailable.  Everything this crate needs from
//! them is implemented here, with tests — see DESIGN.md §3.

pub mod args;
pub mod csv;
pub mod fnv;
pub mod json;
pub mod mmap;
pub mod rng;
pub mod timer;

/// Round `x` up to the next multiple of `align`.
#[inline]
pub fn round_up(x: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    (x + align - 1) & !(align - 1)
}

/// Boundary `i` of the repo-wide ±1-balanced partition of `len` into
/// `parts` contiguous ranges: range `i` is
/// `split_point(len, parts, i)..split_point(len, parts, i+1)`.
///
/// This is THE split rule — corpus byte shards (`corpus::shard`), model
/// row shards (`model::ShardMap`), and cpu regrouping
/// (`runtime::topology`) all call it, so "sharded the same way" is a
/// shared function, not a cross-referenced comment that can drift.
/// Properties: `split_point(len, n, 0) == 0`,
/// `split_point(len, n, n) == len`, monotone in `i`, and adjacent
/// ranges differ in length by at most 1.
#[inline]
pub fn split_point(len: u64, parts: u64, i: u64) -> u64 {
    debug_assert!(parts >= 1 && i <= parts);
    len * i / parts
}

/// Human-readable SI formatting for rates ("5.8M", "110M", "1.2G").
pub fn si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 64), 0);
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn split_point_partitions_balanced() {
        for (len, n) in [(0u64, 4u64), (100, 7), (7, 7), (2, 4), (1_000_003, 32)] {
            assert_eq!(split_point(len, n, 0), 0);
            assert_eq!(split_point(len, n, n), len);
            let sizes: Vec<u64> = (0..n)
                .map(|i| split_point(len, n, i + 1) - split_point(len, n, i))
                .collect();
            assert_eq!(sizes.iter().sum::<u64>(), len);
            assert!(
                sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1,
                "({len},{n}): {sizes:?}"
            );
        }
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(5_800_000.0), "5.80M");
        assert_eq!(si(110e6), "110.00M");
        assert_eq!(si(1_234.0), "1.23K");
        assert_eq!(si(12.5), "12.50");
        assert_eq!(si(2.5e9), "2.50G");
    }
}
