//! Self-contained utility substrates.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (clap, serde/serde_json, rand,
//! criterion, proptest) are unavailable.  Everything this crate needs from
//! them is implemented here, with tests — see DESIGN.md §3.

pub mod args;
pub mod csv;
pub mod json;
pub mod rng;
pub mod timer;

/// Round `x` up to the next multiple of `align`.
#[inline]
pub fn round_up(x: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    (x + align - 1) & !(align - 1)
}

/// Human-readable SI formatting for rates ("5.8M", "110M", "1.2G").
pub fn si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 64), 0);
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(5_800_000.0), "5.80M");
        assert_eq!(si(110e6), "110.00M");
        assert_eq!(si(1_234.0), "1.23K");
        assert_eq!(si(12.5), "12.50");
        assert_eq!(si(2.5e9), "2.50G");
    }
}
