//! Tiny CSV writer for bench results (`bench_results/*.csv`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self {
            w,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            fields.len() == self.cols,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        let line: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.w, "{}", line.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

fn escape(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// Format a float field compactly.
pub fn num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("pw2v_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["x,y".into(), "z\"q\"".into()]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        w.flush().unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert_eq!(got, "a,b\n\"x,y\",\"z\"\"q\"\"\"\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_arity_rejected() {
        let dir = std::env::temp_dir().join("pw2v_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["only-one".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn num_formatting() {
        assert_eq!(num(3.0), "3");
        assert_eq!(num(0.5), "0.500000");
    }
}
