//! Tiny declarative CLI argument parser (clap is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals, with
//! typed accessors and a collected usage/error report.  Each subcommand in
//! `main.rs` builds one [`Args`] over its tail of argv.

use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Debug, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Keys consumed via accessors, to report unknown options.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    opts.insert(rest.to_string(), it.next().unwrap());
                } else {
                    flags.push(rest.to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self {
            opts,
            flags,
            positional,
            seen: Default::default(),
        }
    }

    pub fn from_env_tail(skip: usize) -> Self {
        Self::parse(std::env::args().skip(skip))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.seen.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt<T: FromStr>(&self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.seen.borrow_mut().push(name.to_string());
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|e| {
                anyhow::anyhow!("--{name}: cannot parse '{v}': {e}")
            }),
        }
    }

    pub fn get<T: FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt(name)?.unwrap_or(default))
    }

    pub fn required<T: FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.opt(name)?
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error on any provided `--option` never consumed by an accessor.
    /// Call after all accessors ran.
    pub fn check_unknown(&self) -> anyhow::Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown options: {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = args("--dim 300 --window=5 input.txt");
        assert_eq!(a.get::<usize>("dim", 0).unwrap(), 300);
        assert_eq!(a.get::<usize>("window", 0).unwrap(), 5);
        assert_eq!(a.positional(), &["input.txt".to_string()]);
    }

    #[test]
    fn flags_vs_opts() {
        let a = args("--verbose --threads 4");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get::<usize>("threads", 1).unwrap(), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = args("--x 1 --dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn defaults_and_required() {
        let a = args("--lr 0.05");
        assert_eq!(a.get::<f32>("lr", 0.025).unwrap(), 0.05);
        assert_eq!(a.get::<f32>("sample", 1e-4).unwrap(), 1e-4);
        assert!(a.required::<String>("corpus").is_err());
    }

    #[test]
    fn parse_error_mentions_option() {
        let a = args("--dim banana");
        let e = a.get::<usize>("dim", 0).unwrap_err().to_string();
        assert!(e.contains("--dim"), "{e}");
    }

    #[test]
    fn unknown_detection() {
        let a = args("--dim 1 --typo 2");
        let _ = a.get::<usize>("dim", 0).unwrap();
        assert!(a.check_unknown().is_err());
    }
}
