//! `pw2v` — the command-line launcher.
//!
//! Subcommands:
//!   gen-corpus   generate a synthetic latent-model corpus + test sets
//!   train        shared-memory training (backend selectable)
//!   train-dist   distributed data-parallel training (replica threads)
//!   eval         evaluate saved vectors on similarity/analogy sets
//!   serve        answer topk/analogy queries over a trained model
//!   simulate     regenerate the paper's Fig 3 / Fig 4 scaling curves
//!   info         runtime + artifact diagnostics

use std::path::PathBuf;

use pw2v::config::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::corpus::vocab::Vocab;
use pw2v::dist::{
    train_distributed, train_tcp_ring, CheckpointPolicy, DistConfig, FaultSpec, NetConfig,
    OnFailure, RingSpec, SyncPolicy,
};
use pw2v::eval;
use pw2v::model::{io as model_io, SharedModel};
use pw2v::perfmodel::{self, simulate};
use pw2v::train;
use pw2v::util::args::Args;
use pw2v::util::si;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_default();
    let args = Args::from_env_tail(2);
    match cmd.as_str() {
        "gen-corpus" => gen_corpus(&args),
        "train" => cmd_train(&args),
        "train-dist" => cmd_train_dist(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "info" => cmd_info(&args),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `pw2v help`)"),
    }
}

const HELP: &str = "\
pw2v — Parallelizing Word2Vec in Shared and Distributed Memory (Ji et al. 2016)

USAGE: pw2v <subcommand> [--key value ...]

  gen-corpus  --out corpus.txt [--tokens N --vocab V --seed S]
              [--simset sim.tsv --anaset ana.txt]
  train       --corpus corpus.txt --out vectors.txt
              [--backend scalar|bidmach|gemm|pjrt --threads T --dim D
               --simd auto|avx2|scalar --kernel auto|fused|gemm3
               --sigmoid exact|table --corpus-cache off|auto|PATH
               --numa off|auto|NODES --route off|owner|head=K ...]
              (--corpus-cache auto encodes <corpus>.pw2v.u32 once and
               trains from the u32 cache: no per-epoch re-tokenization;
               --numa auto shards M_in/M_out across NUMA nodes and pins
               workers so Hogwild scatters stay socket-local;
               --route owner additionally steers each hot-target window
               to the worker on the target row's home node — bounded
               mailboxes, local fallback under backpressure)
  train-dist  --corpus corpus.txt --nodes N [--sync-interval W --policy sub|full]
              [--numa off|auto|NODES --route off|owner|head=K
               --out vectors.txt]
              [--dist threads|tcp:RANK@ADDR0,ADDR1,...]
              [--checkpoint BASE --checkpoint-every ROUNDS --resume]
              [--net-timeout-ms MS --heartbeat-ms MS --connect-timeout-ms MS]
              [--on-failure abort|shrink|rejoin --rejoin-grace-ms MS]
              (--numa auto pins each replica to a NUMA node and
               first-touches it there — one replica per socket keeps
               training traffic node-local; --route is accepted for
               config parity but is a no-op here: each replica is one
               worker, so every window already processes on its home
               node.
               --dist tcp:... runs THIS process as one rank of a TCP
               ring — launch one process per address, each with its own
               rank; --nodes is implied by the address list.  Full-sync
               rings are bitwise-identical to thread mode.  --checkpoint
               writes two-slot crash-consistent snapshots at BASE.rankK.{a,b}
               every ROUNDS sync rounds; --resume continues from the
               newest round every rank can load.
               --on-failure shrink (needs --checkpoint) self-heals on a
               peer failure: survivors regroup at a new membership
               epoch, roll back to the newest checkpoint round all of
               them hold, re-shard over the smaller ring and continue;
               rejoin additionally holds the regroup open for
               --rejoin-grace-ms so a promptly respawned rank is
               re-admitted; abort (default) fails the whole run fast.
               Frame deadlines adapt to measured round time (EWMA);
               --net-timeout-ms is the floor.  PW2V_FAULT injects
               deterministic faults (kill-after=N | torn-frame=N |
               stall-after=N | panic-replica=I | kill-epoch=E |
               wedge-regroup=E | respawn-after=MS) for the fault suite)
  eval        --vectors vectors.txt [--simset sim.tsv] [--anaset ana.txt]
  serve       --vectors vectors.txt | --store model.rst
              [--save-store model.rst --quant off|int8
               --simd auto|avx2|scalar --listen HOST:PORT]
              (line-delimited JSON over stdin/stdout, or TCP with
               --listen.  Requests: {\"op\":\"topk\",\"word\":W,\"k\":K} and
               {\"op\":\"analogy\",\"a\":A,\"b\":B,\"c\":C,\"k\":K}; one JSON
               response per line.  --save-store writes the mmap-able
               binary row store (then serves from it); --store opens
               one directly — O(header+vocab) startup, no float
               parsing.  --quant int8 scans per-row symmetric int8
               codes: ~4x less scan bandwidth, recall gated in CI)
  simulate    --figure 3|4 [--machine bdw|knl|hsw]
  info        [--artifacts-dir artifacts]
";

fn gen_corpus(a: &Args) -> anyhow::Result<()> {
    let out: String = a.required("out")?;
    let mut scfg = SyntheticConfig::default();
    scfg.tokens = a.get("tokens", scfg.tokens)?;
    scfg.vocab = a.get("vocab", scfg.vocab)?;
    scfg.clusters = a.get("clusters", scfg.clusters)?;
    scfg.seed = a.get("seed", scfg.seed)?;
    let simset: Option<String> = a.opt("simset")?;
    let anaset: Option<String> = a.opt("anaset")?;
    a.check_unknown()?;

    eprintln!(
        "generating {} tokens, vocab {}, {} clusters ...",
        scfg.tokens, scfg.vocab, scfg.clusters
    );
    let lm = LatentModel::new(scfg);
    let n = lm.write_corpus(&out)?;
    eprintln!("wrote {n} tokens to {out}");
    if let Some(p) = simset {
        let set = eval::gen_similarity_set(&lm, 350, 7);
        eval::datasets::save_similarity_set(&p, &set)?;
        eprintln!("wrote {} similarity pairs to {p}", set.len());
    }
    if let Some(p) = anaset {
        let set = eval::gen_analogy_set(&lm);
        eval::datasets::save_analogy_set(&p, &set)?;
        eprintln!("wrote {} analogy questions to {p}", set.len());
    }
    Ok(())
}

fn cmd_train(a: &Args) -> anyhow::Result<()> {
    let corpus = PathBuf::from(a.required::<String>("corpus")?);
    let out: Option<String> = a.opt("out")?;
    let mut cfg = TrainConfig::default();
    if let Some(f) = a.opt::<String>("config")? {
        cfg.load_file(f)?;
    }
    cfg.apply_args(a)?;
    a.check_unknown()?;

    eprintln!("building vocabulary ...");
    let vocab = Vocab::build_from_file(&corpus, cfg.min_count)?;
    eprintln!(
        "vocab {} words, corpus {} tokens",
        vocab.len(),
        vocab.total_words()
    );
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    eprintln!(
        "training: backend={} threads={} dim={} epochs={} simd={} kernel={} \
         sigmoid={} corpus-cache={} numa={} route={}",
        cfg.backend,
        cfg.threads,
        cfg.dim,
        cfg.epochs,
        cfg.simd,
        cfg.kernel,
        cfg.sigmoid_mode,
        cfg.corpus_cache,
        cfg.numa,
        cfg.route
    );
    let outcome = train::train(&cfg, &corpus, &vocab, &model)?;
    let snap = outcome.snapshot;
    eprintln!(
        "done: {} words in {:.1}s = {} words/sec ({} windows, {} calls)",
        snap.words,
        snap.secs,
        si(snap.words_per_sec()),
        snap.windows,
        snap.calls
    );
    if let Some(p) = out {
        model_io::save_text(&p, &vocab, model.m_in())?;
        eprintln!("vectors saved to {p}");
    }
    Ok(())
}

fn cmd_train_dist(a: &Args) -> anyhow::Result<()> {
    let corpus = PathBuf::from(a.required::<String>("corpus")?);
    let out: Option<String> = a.opt("out")?;
    let mut cfg = TrainConfig::default();
    cfg.apply_args(a)?;

    // Transport: in-process replica threads (default) or one rank of a
    // multi-process TCP ring.
    let transport: String = a.get("dist", "threads".to_string())?;
    let ring = match transport.as_str() {
        "threads" => None,
        spec if spec.starts_with("tcp:") => Some(RingSpec::parse(spec)?),
        other => anyhow::bail!("unknown transport '{other}' (threads|tcp:RANK@ADDRS)"),
    };
    let nodes: usize = match &ring {
        Some(r) => {
            anyhow::ensure!(
                a.opt::<usize>("nodes")?.map_or(true, |n| n == r.nranks()),
                "--nodes disagrees with the tcp ring's address count"
            );
            r.nranks()
        }
        None => a.get("nodes", 2)?,
    };

    let mut dist = DistConfig::for_nodes(nodes);
    dist.sync_interval = a.get("sync-interval", dist.sync_interval)?;
    match a.opt::<String>("policy")?.as_deref() {
        Some("full") => dist.policy = SyncPolicy::Full,
        Some("sub") | None => {}
        Some(p) => anyhow::bail!("unknown policy '{p}' (sub|full)"),
    }
    if a.flag("no-lr-scaling") {
        dist.scale_lr = false;
    }
    if let Some(p) = a.opt::<String>("on-failure")? {
        dist.on_failure = p.parse::<OnFailure>()?;
        anyhow::ensure!(
            ring.is_some() || dist.on_failure == OnFailure::Abort,
            "--on-failure shrink/rejoin needs the tcp transport \
             (thread mode always fails fast)"
        );
    }
    // Thread-mode fault injection (TCP wire faults are read from the
    // environment by the transport itself).
    dist.fault = FaultSpec::from_env()
        .map_err(|e| anyhow::anyhow!("PW2V_FAULT: {e:#}"))?;

    let defaults = NetConfig::default();
    let net = NetConfig {
        connect_timeout_ms: a.get("connect-timeout-ms", defaults.connect_timeout_ms)?,
        io_timeout_ms: a.get("net-timeout-ms", defaults.io_timeout_ms)?,
        heartbeat_ms: a.get("heartbeat-ms", defaults.heartbeat_ms)?,
        rejoin_grace_ms: a.get("rejoin-grace-ms", defaults.rejoin_grace_ms)?,
    };
    let ckpt = CheckpointPolicy {
        base: a.opt::<String>("checkpoint")?.map(PathBuf::from),
        every: a.get("checkpoint-every", 8u64)?,
        resume: a.flag("resume"),
    };
    a.check_unknown()?;

    let vocab = Vocab::build_from_file(&corpus, cfg.min_count)?;
    let outcome = match &ring {
        None => {
            eprintln!(
                "distributed training: {} replica threads, sync every {} words, \
                 vocab {}, numa={} route={}",
                nodes,
                dist.sync_interval,
                vocab.len(),
                cfg.numa,
                cfg.route
            );
            train_distributed(&cfg, &dist, &corpus, &vocab)?
        }
        Some(spec) => {
            eprintln!(
                "distributed training: rank {}/{} on tcp ring, sync every {} \
                 words, vocab {}, checkpoint={}, on-failure={:?}",
                spec.rank,
                nodes,
                dist.sync_interval,
                vocab.len(),
                ckpt.base
                    .as_deref()
                    .map(|p| p.display().to_string())
                    .unwrap_or_else(|| "off".into()),
                dist.on_failure,
            );
            train_tcp_ring(&cfg, &dist, spec, &net, &ckpt, &corpus, &vocab)?
        }
    };
    eprintln!(
        "done: {} words in {:.1}s = {} words/sec aggregate",
        outcome.words,
        outcome.secs,
        si(outcome.words as f64 / outcome.secs.max(1e-9))
    );
    for (i, st) in outcome.sync_stats.iter().enumerate() {
        eprintln!(
            "  node {i}: {} rounds, {} rows synced, {} wire bytes",
            st.rounds,
            st.rows_synced,
            si(st.wire_bytes as f64)
        );
    }
    if let Some(n) = &outcome.net {
        eprintln!(
            "  ring: {} frames / {} bytes sent ({} slice bytes), \
             {} frames / {} bytes recv, {} heartbeats",
            n.frames_sent,
            si(n.bytes_sent as f64),
            si(n.slice_bytes_sent as f64),
            n.frames_recv,
            si(n.bytes_recv as f64),
            n.heartbeats_sent
        );
    }
    if let Some(p) = out {
        model_io::save_text(&p, &vocab, outcome.model.m_in())?;
        eprintln!("vectors saved to {p}");
    }
    Ok(())
}

fn cmd_eval(a: &Args) -> anyhow::Result<()> {
    let vectors: String = a.required("vectors")?;
    let simset: Option<String> = a.opt("simset")?;
    let anaset: Option<String> = a.opt("anaset")?;
    a.check_unknown()?;

    let (words, emb) = model_io::load_text(&vectors)?;
    // Rebuild a vocab view over the saved order (ranks become counts so
    // the frequency-sorted invariant holds).
    let n = words.len();
    let counts: std::collections::HashMap<String, u64> = words
        .iter()
        .enumerate()
        .map(|(i, w)| (w.clone(), (n - i) as u64))
        .collect();
    let vocab = Vocab::from_counts(counts, 1);
    eprintln!("loaded {} vectors of dim {}", n, emb.dim());

    if let Some(p) = simset {
        let pairs = eval::load_similarity_set(&p)?;
        let r = eval::eval_similarity(&pairs, &vocab, &emb);
        println!(
            "similarity: rho100 = {:.1} over {}/{} pairs",
            r.rho100, r.pairs_covered, r.pairs_total
        );
    }
    if let Some(p) = anaset {
        let qs = eval::load_analogy_set(&p)?;
        let r = eval::eval_analogy(&qs, &vocab, &emb);
        println!(
            "analogy: accuracy = {:.1}% over {}/{} questions",
            r.accuracy100(),
            r.covered,
            r.total
        );
    }
    Ok(())
}

fn cmd_serve(a: &Args) -> anyhow::Result<()> {
    use pw2v::config::QuantMode;
    use pw2v::linalg::simd::{self, SimdMode};
    use pw2v::serve::{run_listen, run_stdio, RowStore, ServeEngine};

    let vectors: Option<String> = a.opt("vectors")?;
    let store_path: Option<String> = a.opt("store")?;
    let save_store: Option<String> = a.opt("save-store")?;
    let quant: QuantMode = a.get("quant", QuantMode::default())?;
    let simd_mode: SimdMode = a.get("simd", SimdMode::default())?;
    let listen: Option<String> = a.opt("listen")?;
    a.check_unknown()?;

    let level = simd::configure(simd_mode)?;
    let store = match (vectors, store_path) {
        (Some(v), None) => {
            let (words, emb) = model_io::load_text(&v)?;
            let st = RowStore::from_model(words, &emb)?;
            eprintln!("serve: loaded {} vectors of dim {} from {v}", st.n_rows(), st.dim());
            st
        }
        (None, Some(p)) => {
            let st = RowStore::open(std::path::Path::new(&p))?;
            eprintln!("serve: opened row store {p} ({} rows, dim {})", st.n_rows(), st.dim());
            st
        }
        _ => anyhow::bail!("serve needs exactly one of --vectors or --store"),
    };
    if let Some(p) = save_store {
        store.save(std::path::Path::new(&p))?;
        eprintln!("serve: row store saved to {p}");
    }
    let eng = ServeEngine::from_store(store, quant);
    eprintln!("serve: simd={level:?} quant={quant}");
    match listen {
        Some(addr) => run_listen(&eng, &addr),
        None => run_stdio(&eng),
    }
}

fn cmd_simulate(a: &Args) -> anyhow::Result<()> {
    let figure: usize = a.get("figure", 3)?;
    let machine: String = a.get("machine", "bdw".to_string())?;
    a.check_unknown()?;
    let spec = match machine.as_str() {
        "bdw" => perfmodel::arch::broadwell(),
        "knl" => perfmodel::arch::knl(),
        "hsw" => perfmodel::arch::haswell(),
        m => anyhow::bail!("unknown machine '{m}' (bdw|knl|hsw)"),
    };
    let p = simulate::FigParams::default();
    match figure {
        3 => {
            let axis = simulate::fig3_thread_axis(&spec);
            let (scalar, gemm) =
                simulate::fig3_series(&spec, &p, 70_000.0, 182_000.0, &axis);
            println!("# Fig 3 ({}): threads original ours", spec.name);
            for (s, g) in scalar.iter().zip(&gemm) {
                println!(
                    "{:>3}  {:>10}  {:>10}",
                    s.x,
                    si(s.words_per_sec),
                    si(g.words_per_sec)
                );
            }
        }
        4 => {
            let fabric = if machine == "knl" {
                perfmodel::arch::omnipath()
            } else {
                perfmodel::arch::fdr_infiniband()
            };
            let nodes = [1, 2, 4, 8, 16, 32];
            let series =
                simulate::fig4_series(&spec, fabric, &p, 182_000.0, &nodes);
            println!("# Fig 4 ({} cluster): nodes words/sec", spec.name);
            for pt in series {
                println!("{:>3}  {:>10}", pt.x, si(pt.words_per_sec));
            }
        }
        f => anyhow::bail!("unknown figure {f} (3|4)"),
    }
    Ok(())
}

fn cmd_info(a: &Args) -> anyhow::Result<()> {
    let dir: String = a.get("artifacts-dir", "artifacts".to_string())?;
    a.check_unknown()?;
    println!("pw2v {}", env!("CARGO_PKG_VERSION"));
    match pw2v::runtime::Runtime::cpu() {
        Ok(rt) => println!("pjrt platform: {}", rt.platform()),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    match pw2v::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({dir}):");
            for v in &m.entries {
                println!(
                    "  {:<28} kind={:<6} W={} B={} S={} D={}",
                    v.name, v.kind, v.w, v.b, v.s, v.d
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}
