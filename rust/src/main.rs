//! `pw2v` — thin binary shim over the library CLI ([`pw2v::cli`]).
//!
//! All subcommand parsing, help text and handlers live in `src/cli/`
//! so the command surface is unit-testable; `tests/cli_compat.rs` pins
//! the end-to-end contract (subcommand names, the bare-corpus alias,
//! per-subcommand `--help`) over this binary.

fn main() {
    if let Err(e) = pw2v::cli::run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
