//! Training configuration.
//!
//! Defaults follow the paper's 1B-benchmark setting (Sec. IV-A): `dim=300,
//! negative=5, window=5, sample=1e-4`, starting `lr=0.025` (the original
//! word2vec skip-gram default), input batch `B=16` (the paper's "10–20"),
//! superbatch `W=64` (our PJRT call-amortisation knob, ablated in
//! `benches/ablations.rs`).
//!
//! Configs load from a simple `key = value` file (TOML-subset; the full
//! toml crate is not vendored offline) and/or CLI overrides, so every
//! example and bench is driven by the same config surface.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::linalg::simd::SimdMode;
use crate::runtime::topology::NumaMode;
use crate::train::route::RouteMode;
use crate::util::args::Args;

/// Which trainer back-end executes the SGNS updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Mikolov's original Hogwild scalar scheme (Algorithm 1) — level-1 BLAS.
    Scalar,
    /// BIDMach's scheme (paper Sec. III-D): separate positive/negative
    /// matrix-vector passes — level-2 BLAS.
    Bidmach,
    /// The paper's contribution: minibatched, shared-negative GEMM scheme —
    /// level-3 BLAS, native rust kernels.
    Gemm,
    /// Same scheme, executing the AOT-compiled JAX/Pallas artifact through
    /// the PJRT CPU client.
    Pjrt,
}

impl FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "original" => Ok(Backend::Scalar),
            "bidmach" => Ok(Backend::Bidmach),
            "gemm" | "ours" => Ok(Backend::Gemm),
            "pjrt" | "xla" => Ok(Backend::Pjrt),
            other => anyhow::bail!(
                "unknown backend '{other}' (scalar|bidmach|gemm|pjrt)"
            ),
        }
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Backend::Scalar => "scalar",
            Backend::Bidmach => "bidmach",
            Backend::Gemm => "gemm",
            Backend::Pjrt => "pjrt",
        };
        f.write_str(s)
    }
}

/// Learning-rate schedule selector (paper Sec. III-E ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LrSchedule {
    /// Original word2vec: linear decay with corpus progress.
    Linear,
    /// Paper's distributed trick: scaled start, sharper decay with node count.
    DistScaled,
    /// AdaGrad (rejected by the paper for memory/bandwidth cost; implemented
    /// for the ablation).
    Adagrad,
    /// RMSProp (ditto).
    Rmsprop,
}

impl FromStr for LrSchedule {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Ok(LrSchedule::Linear),
            "dist" | "dist-scaled" => Ok(LrSchedule::DistScaled),
            "adagrad" => Ok(LrSchedule::Adagrad),
            "rmsprop" => Ok(LrSchedule::Rmsprop),
            other => anyhow::bail!(
                "unknown lr schedule '{other}' (linear|dist|adagrad|rmsprop)"
            ),
        }
    }
}

/// Which kernel organisation the GEMM backend runs per window
/// (`--kernel`; the fused-kernel PR's ablation axis).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// The fused single-pass kernel wherever it applies (exact sigmoid);
    /// falls back to the gemm3 chain under `--sigmoid table`.
    #[default]
    Auto,
    /// Require the fused single-pass kernel (`simd::sgns_fused`).
    /// Rejected in combination with `--sigmoid table` (the fused kernel
    /// evaluates the exact sigmoid only).
    Fused,
    /// The three-GEMM chain (`gemm_nt → sgns_err → gemm_nn → gemm_tn`),
    /// preserved bit-for-bit from the pre-fusion crate for ablations.
    Gemm3,
}

impl FromStr for KernelMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelMode::Auto),
            "fused" => Ok(KernelMode::Fused),
            "gemm3" => Ok(KernelMode::Gemm3),
            other => anyhow::bail!(
                "unknown kernel mode '{other}' (auto|fused|gemm3)"
            ),
        }
    }
}

impl fmt::Display for KernelMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            KernelMode::Auto => "auto",
            KernelMode::Fused => "fused",
            KernelMode::Gemm3 => "gemm3",
        })
    }
}

/// Cross-window negative-reuse policy in the GEMM backend (`--reuse`;
/// the FULL-W2V lever, arxiv 2312.07743): how long one drawn negative
/// set stays live across a sentence's consecutive windows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReuseMode {
    /// Fresh negatives every window, one kernel call per window — the
    /// PR-2 fused kernel bit for bit.
    #[default]
    Off,
    /// Negatives still drawn per window, but execution goes through the
    /// run-grouping driver with every run pinned to length 1.  Bitwise
    /// equal to `Off`; exists to ablate the driver overhead separately
    /// from the reuse payoff.
    Window,
    /// One negative set per SENTENCE, shared by all its windows; the run
    /// kernel keeps those `Wo` rows and `dWo` accumulators live in
    /// registers/L1 across the window sequence (bitwise-equal to the
    /// scalar reference on single-thread runs).
    Sentence,
}

impl FromStr for ReuseMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(ReuseMode::Off),
            "window" => Ok(ReuseMode::Window),
            "sentence" => Ok(ReuseMode::Sentence),
            other => anyhow::bail!(
                "unknown reuse mode '{other}' (off|window|sentence)"
            ),
        }
    }
}

impl fmt::Display for ReuseMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ReuseMode::Off => "off",
            ReuseMode::Window => "window",
            ReuseMode::Sentence => "sentence",
        })
    }
}

/// Where the trainer reads sentences from (`--corpus-cache`): the
/// streaming text path, or the pre-encoded `u32` cache
/// (`corpus::encoded`) that deletes per-epoch tokenization and vocab
/// hashing from the hot loop.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum CorpusCacheMode {
    /// Stream the text corpus every epoch (the pre-cache behavior,
    /// bit-for-bit).
    #[default]
    Off,
    /// Build (or reuse) `<corpus>.pw2v.u32` next to the input: built iff
    /// missing, stale, or vocab-fingerprint-mismatched, then train from
    /// it.
    Auto,
    /// Like `Auto` but the cache lives at this explicit path.
    Path(PathBuf),
}

impl FromStr for CorpusCacheMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "" => anyhow::bail!("--corpus-cache needs off|auto|<path>"),
            "off" | "none" => Ok(CorpusCacheMode::Off),
            "auto" => Ok(CorpusCacheMode::Auto),
            // Anything else is a cache path.  (A file literally named
            // `off` or `auto` can be addressed as `./off`.)
            _ => Ok(CorpusCacheMode::Path(PathBuf::from(s))),
        }
    }
}

impl fmt::Display for CorpusCacheMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CorpusCacheMode::Off => f.write_str("off"),
            CorpusCacheMode::Auto => f.write_str("auto"),
            CorpusCacheMode::Path(p) => write!(f, "{}", p.display()),
        }
    }
}

/// Which sigmoid the GEMM trainer's fused error kernel evaluates
/// (ablation: the original's EXP_TABLE approximation vs the exact form).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SigmoidMode {
    /// Numerically exact sigmoid (SIMD-dispatched in the GEMM backend).
    #[default]
    Exact,
    /// word2vec's precomputed table with round-to-nearest-bin lookup.
    Table,
}

impl FromStr for SigmoidMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Ok(SigmoidMode::Exact),
            "table" => Ok(SigmoidMode::Table),
            other => anyhow::bail!("unknown sigmoid mode '{other}' (exact|table)"),
        }
    }
}

impl fmt::Display for SigmoidMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            SigmoidMode::Exact => "exact",
            SigmoidMode::Table => "table",
        })
    }
}

/// The serve engine's `--quant` knob: scan the f32 unit rows, or an
/// int8 symmetric-quantized copy (per-row scale; ~4× less scan
/// bandwidth, recall-gated against the f32 scan in `tests/serve_parity`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantMode {
    /// f32 scan only (bitwise-equal to the eval oracle).
    #[default]
    Off,
    /// Build the int8 row store and answer queries from it.
    Int8,
}

impl FromStr for QuantMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(QuantMode::Off),
            "int8" => Ok(QuantMode::Int8),
            other => anyhow::bail!("unknown quant mode '{other}' (off|int8)"),
        }
    }
}

impl fmt::Display for QuantMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            QuantMode::Off => "off",
            QuantMode::Int8 => "int8",
        })
    }
}

/// Full training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Embedding dimension D.
    pub dim: usize,
    /// Max context window c (actual window per position is 1..=c, drawn
    /// uniformly, as in the original code).
    pub window: usize,
    /// Number of negative samples K.
    pub negative: usize,
    /// Frequent-word subsampling threshold t (0 disables).
    pub sample: f32,
    /// Discard words with corpus count below this.
    pub min_count: u64,
    /// Starting learning rate alpha.
    pub lr: f32,
    /// Floor for the decayed learning rate, as a fraction of `lr`.
    pub lr_min_frac: f32,
    /// Training epochs over the corpus.
    pub epochs: usize,
    /// Worker threads (shared-memory parallelism).
    pub threads: usize,
    /// Input batch size B: max context words batched per window.
    pub batch: usize,
    /// Superbatch width W: windows per kernel/artifact call.
    pub superbatch: usize,
    /// Trainer back-end.
    pub backend: Backend,
    /// LR schedule.
    pub lr_schedule: LrSchedule,
    /// RNG seed.
    pub seed: u64,
    /// Directory holding AOT artifacts (for `Backend::Pjrt`).
    pub artifacts_dir: String,
    /// Unigram table exponent (0.75 in the paper/original).
    pub unigram_power: f32,
    /// Kernel dispatch policy for the GEMM hot path (`--simd`); `Auto`
    /// picks AVX2+FMA when the CPU has it, `Scalar` pins the portable
    /// kernels for ablations.
    pub simd: SimdMode,
    /// Sigmoid evaluation in the GEMM backend (`--sigmoid`).
    pub sigmoid_mode: SigmoidMode,
    /// Kernel organisation in the GEMM backend (`--kernel`): the fused
    /// single-pass window kernel vs the ablation-preserved gemm3 chain.
    pub kernel: KernelMode,
    /// Cross-window negative reuse in the GEMM backend (`--reuse
    /// {off,window,sentence}`): `off` = per-window negatives, PR-2 path
    /// bit-for-bit; `sentence` = one negative set per sentence held
    /// register-resident across its windows (FULL-W2V).  Changes which
    /// negatives are drawn, so it participates in the config
    /// fingerprint (when not `Off`).
    pub reuse: ReuseMode,
    /// Corpus ingest backend (`--corpus-cache {off,auto,<path>}`): stream
    /// the text file per epoch, or train from the pre-encoded `u32`
    /// cache.
    pub corpus_cache: CorpusCacheMode,
    /// NUMA policy (`--numa {off,auto,<nodes>}`): `off` = flat model +
    /// unpinned workers (the pre-NUMA path bit-for-bit); `auto` = shard
    /// model rows across the detected node topology and pin workers
    /// node-locally; `<nodes>` = force a synthetic node count (ablations,
    /// tests).  The shared-memory trainer holds the flat model AND the
    /// sharded copy while training (transient 2x model memory; see
    /// EXPERIMENTS.md §NUMA).
    pub numa: NumaMode,
    /// Reserved embedding rows for STREAMING vocabulary admission
    /// (`--vocab-reserve <N>`): the model is allocated with this many
    /// extra rows past the initial vocabulary, pre-initialised from the
    /// same sequential RNG stream as the base rows, and admissions
    /// consume them in order.  0 (the default) freezes the vocabulary —
    /// batch training ignores the knob entirely.
    pub vocab_reserve: usize,
    /// Window routing by output-row ownership (`--route
    /// {off,owner,head=<K>}`): `off` = every worker processes its own
    /// windows (the pre-routing path bit-for-bit); `owner` = steer
    /// windows whose target is in the Zipf-derived hot head to the
    /// worker on the target row's home node (bounded mailboxes,
    /// local-fallback backpressure); `head=<K>` = explicit cutoff
    /// (ablations, tests).  Composes with `--numa`; without it, routing
    /// degenerates to per-row worker ownership within the node.
    pub route: RouteMode,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            dim: 300,
            window: 5,
            negative: 5,
            sample: 1e-4,
            min_count: 5,
            lr: 0.025,
            lr_min_frac: 1e-4,
            epochs: 1,
            threads: 1,
            batch: 16,
            superbatch: 64,
            backend: Backend::Gemm,
            lr_schedule: LrSchedule::Linear,
            seed: 1,
            artifacts_dir: "artifacts".to_string(),
            unigram_power: 0.75,
            simd: SimdMode::Auto,
            sigmoid_mode: SigmoidMode::Exact,
            kernel: KernelMode::Auto,
            reuse: ReuseMode::Off,
            corpus_cache: CorpusCacheMode::Off,
            vocab_reserve: 0,
            numa: NumaMode::Off,
            route: RouteMode::Off,
        }
    }
}

impl TrainConfig {
    /// Number of output rows per window: 1 positive + K shared negatives.
    pub fn samples(&self) -> usize {
        1 + self.negative
    }

    /// A small config for unit tests: tiny dims, deterministic.
    pub fn test_tiny() -> Self {
        Self {
            dim: 32,
            window: 3,
            negative: 5,
            sample: 0.0,
            min_count: 1,
            epochs: 1,
            batch: 8,
            superbatch: 4,
            ..Self::default()
        }
    }

    /// FNV-1a digest of every field that shapes the training COMPUTATION
    /// (dims, windows, schedules, seeds, kernel organisation).  Stamped
    /// into checkpoint headers so `--resume` under a changed config is
    /// rejected with a diagnostic instead of silently continuing a
    /// different run.  Knobs that are parity-guaranteed no-ops on the
    /// numbers (`--corpus-cache`, `--numa`, `--route`) are deliberately
    /// excluded: resuming across them is sound.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        for v in [
            self.dim as u64,
            self.window as u64,
            self.negative as u64,
            self.sample.to_bits() as u64,
            self.min_count,
            self.lr.to_bits() as u64,
            self.lr_min_frac.to_bits() as u64,
            self.epochs as u64,
            self.batch as u64,
            self.superbatch as u64,
            self.seed,
            self.unigram_power.to_bits() as u64,
            self.backend as u64,
            self.lr_schedule as u64,
            self.kernel as u64,
            self.sigmoid_mode as u64,
        ] {
            h.update(&v.to_le_bytes());
        }
        // Reserved rows change the model allocation (and therefore what
        // a checkpoint holds), but only when non-zero — mixing the field
        // conditionally preserves every pre-streaming digest.
        if self.vocab_reserve != 0 {
            h.update(&(self.vocab_reserve as u64).to_le_bytes());
        }
        // Sentence reuse changes which negatives each window sees (one
        // draw per sentence instead of per window), so resuming across
        // it would silently continue a different run — mixed
        // conditionally, like vocab_reserve, to preserve every pre-reuse
        // digest.  `Window` is a parity-guaranteed no-op on the numbers
        // (same draws, same kernels bit-for-bit) and stays excluded.
        if self.reuse == ReuseMode::Sentence {
            h.update(&(self.reuse as u64).to_le_bytes());
        }
        h.digest()
    }

    /// Apply `--key value` CLI overrides (shared across all subcommands).
    pub fn apply_args(&mut self, a: &Args) -> anyhow::Result<()> {
        self.dim = a.get("dim", self.dim)?;
        self.window = a.get("window", self.window)?;
        self.negative = a.get("negative", self.negative)?;
        self.sample = a.get("sample", self.sample)?;
        self.min_count = a.get("min-count", self.min_count)?;
        self.lr = a.get("lr", self.lr)?;
        self.epochs = a.get("epochs", self.epochs)?;
        self.threads = a.get("threads", self.threads)?;
        self.batch = a.get("batch", self.batch)?;
        self.superbatch = a.get("superbatch", self.superbatch)?;
        self.seed = a.get("seed", self.seed)?;
        if let Some(b) = a.opt::<Backend>("backend")? {
            self.backend = b;
        }
        if let Some(l) = a.opt::<LrSchedule>("lr-schedule")? {
            self.lr_schedule = l;
        }
        if let Some(d) = a.opt::<String>("artifacts-dir")? {
            self.artifacts_dir = d;
        }
        if let Some(s) = a.opt::<SimdMode>("simd")? {
            self.simd = s;
        }
        if let Some(s) = a.opt::<SigmoidMode>("sigmoid")? {
            self.sigmoid_mode = s;
        }
        if let Some(k) = a.opt::<KernelMode>("kernel")? {
            self.kernel = k;
        }
        if let Some(r) = a.opt::<ReuseMode>("reuse")? {
            self.reuse = r;
        }
        if let Some(c) = a.opt::<CorpusCacheMode>("corpus-cache")? {
            self.corpus_cache = c;
        }
        self.vocab_reserve = a.get("vocab-reserve", self.vocab_reserve)?;
        if let Some(nm) = a.opt::<NumaMode>("numa")? {
            self.numa = nm;
        }
        if let Some(r) = a.opt::<RouteMode>("route")? {
            self.route = r;
        }
        self.validate()
    }

    /// Load `key = value` lines (TOML subset: comments with `#`, no
    /// sections) and apply them over the current values.
    pub fn load_file<P: AsRef<Path>>(&mut self, path: P) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(&path)?;
        let kv = parse_kv(&text)?;
        let mut flat: Vec<String> = Vec::new();
        for (k, v) in kv {
            flat.push(format!("--{k}"));
            flat.push(v);
        }
        self.apply_args(&Args::parse(flat))
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.dim > 0, "dim must be > 0");
        anyhow::ensure!(self.window > 0, "window must be > 0");
        anyhow::ensure!(self.negative > 0, "negative must be > 0");
        anyhow::ensure!(self.batch > 0, "batch must be > 0");
        anyhow::ensure!(self.superbatch > 0, "superbatch must be > 0");
        anyhow::ensure!(self.threads > 0, "threads must be > 0");
        anyhow::ensure!(self.epochs > 0, "epochs must be > 0");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.sample),
            "sample must be in [0,1]"
        );
        anyhow::ensure!(self.lr > 0.0, "lr must be > 0");
        anyhow::ensure!(
            !(self.kernel == KernelMode::Fused
                && self.sigmoid_mode == SigmoidMode::Table),
            "--kernel fused evaluates the exact sigmoid; \
             use --kernel gemm3 with --sigmoid table"
        );
        // Reuse lives in the GEMM backend's run-grouping driver; the
        // scalar/bidmach/pjrt paths have no superbatch arena to group.
        anyhow::ensure!(
            self.reuse == ReuseMode::Off || self.backend == Backend::Gemm,
            "--reuse {} requires --backend gemm",
            self.reuse
        );
        // Same bound as NumaMode's FromStr: programmatically built
        // configs must not reach the per-node allocation/thread spawn
        // with an absurd count either.
        if let NumaMode::Nodes(n) = self.numa {
            anyhow::ensure!(
                (1..=1024).contains(&n),
                "numa nodes must be in 1..=1024 (got {n})"
            );
        }
        // Same discipline for the routed-head cutoff: FromStr enforces
        // the bound, programmatically built configs must too (ids are
        // u32, so a larger head can never match a row).
        if let RouteMode::Head(k) = self.route {
            anyhow::ensure!(
                (1..=u32::MAX as usize).contains(&k),
                "route head must be in 1..=2^32-1 (got {k})"
            );
        }
        Ok(())
    }
}

/// Parse `key = value` lines; `#` starts a comment; quotes optional.
pub fn parse_kv(text: &str) -> anyhow::Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("config line {}: expected key = value", lineno + 1)
        })?;
        let v = v.trim().trim_matches('"').trim_matches('\'');
        out.insert(k.trim().to_string(), v.to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.dim, 300);
        assert_eq!(c.negative, 5);
        assert_eq!(c.window, 5);
        assert!((c.sample - 1e-4).abs() < 1e-9);
        assert_eq!(c.samples(), 6);
    }

    #[test]
    fn fingerprint_tracks_compute_shape_only() {
        let a = TrainConfig::default();
        let mut b = TrainConfig::default();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Compute-shaping fields move the digest...
        b.dim = 128;
        assert_ne!(a.fingerprint(), b.fingerprint());
        b = TrainConfig::default();
        b.seed = 2;
        assert_ne!(a.fingerprint(), b.fingerprint());
        b = TrainConfig::default();
        b.kernel = KernelMode::Gemm3;
        assert_ne!(a.fingerprint(), b.fingerprint());
        // ...parity-guaranteed knobs do not (resume across them is fine).
        b = TrainConfig::default();
        b.corpus_cache = CorpusCacheMode::Auto;
        b.numa = NumaMode::Auto;
        b.route = RouteMode::Owner;
        b.threads = 7;
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Reserved rows reshape the model allocation, so they move the
        // digest — but only when non-zero (old digests preserved).
        b = TrainConfig::default();
        b.vocab_reserve = 64;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn vocab_reserve_knob_parses() {
        let mut c = TrainConfig::default();
        assert_eq!(c.vocab_reserve, 0);
        let a = Args::parse(
            "--vocab-reserve 128".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.vocab_reserve, 128);
    }

    #[test]
    fn cli_overrides() {
        let mut c = TrainConfig::default();
        let a = Args::parse(
            "--dim 64 --backend scalar --lr 0.05 --lr-schedule adagrad"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.dim, 64);
        assert_eq!(c.backend, Backend::Scalar);
        assert_eq!(c.lr_schedule, LrSchedule::Adagrad);
    }

    #[test]
    fn kv_file_parsing() {
        let kv = parse_kv("dim = 128  # comment\nbackend = \"gemm\"\n\n# x\n")
            .unwrap();
        assert_eq!(kv["dim"], "128");
        assert_eq!(kv["backend"], "gemm");
    }

    #[test]
    fn kv_rejects_bad_line() {
        assert!(parse_kv("not a kv line").is_err());
    }

    #[test]
    fn validation_rejects_zero_dim() {
        let mut c = TrainConfig::default();
        c.dim = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn backend_parsing() {
        assert_eq!("ours".parse::<Backend>().unwrap(), Backend::Gemm);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Pjrt);
        assert!("nope".parse::<Backend>().is_err());
    }

    #[test]
    fn kernel_knob_parsing_and_validation() {
        let mut c = TrainConfig::default();
        assert_eq!(c.kernel, KernelMode::Auto);
        let a = Args::parse(
            "--kernel gemm3".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.kernel, KernelMode::Gemm3);
        assert_eq!("fused".parse::<KernelMode>().unwrap(), KernelMode::Fused);
        assert!("4gemm".parse::<KernelMode>().is_err());
        assert_eq!(KernelMode::Gemm3.to_string(), "gemm3");

        // Fused + EXP_TABLE sigmoid is contradictory and rejected; Auto +
        // table silently takes the gemm3 path instead.
        let mut c = TrainConfig::default();
        c.kernel = KernelMode::Fused;
        c.sigmoid_mode = SigmoidMode::Table;
        assert!(c.validate().is_err());
        c.kernel = KernelMode::Auto;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn corpus_cache_knob_parsing() {
        let mut c = TrainConfig::default();
        assert_eq!(c.corpus_cache, CorpusCacheMode::Off);
        let a = Args::parse(
            "--corpus-cache auto".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.corpus_cache, CorpusCacheMode::Auto);
        assert_eq!(
            "OFF".parse::<CorpusCacheMode>().unwrap(),
            CorpusCacheMode::Off
        );
        assert_eq!(
            "/tmp/c.u32".parse::<CorpusCacheMode>().unwrap(),
            CorpusCacheMode::Path(PathBuf::from("/tmp/c.u32"))
        );
        assert!("".parse::<CorpusCacheMode>().is_err());
        assert_eq!(CorpusCacheMode::Auto.to_string(), "auto");
    }

    #[test]
    fn numa_knob_parsing() {
        let mut c = TrainConfig::default();
        assert_eq!(c.numa, NumaMode::Off);
        let a = Args::parse(
            "--numa auto".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.numa, NumaMode::Auto);
        let a = Args::parse("--numa 2".split_whitespace().map(String::from));
        c.apply_args(&a).unwrap();
        assert_eq!(c.numa, NumaMode::Nodes(2));
        let a = Args::parse(
            "--numa banana".split_whitespace().map(String::from),
        );
        assert!(c.apply_args(&a).is_err());
        // validate() enforces the node bound for programmatically built
        // configs too (FromStr is not the only entry point).
        let mut c = TrainConfig::default();
        c.numa = NumaMode::Nodes(500_000);
        assert!(c.validate().is_err());
        c.numa = NumaMode::Nodes(8);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn route_knob_parsing() {
        let mut c = TrainConfig::default();
        assert_eq!(c.route, RouteMode::Off);
        let a = Args::parse(
            "--route owner".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.route, RouteMode::Owner);
        let a = Args::parse(
            "--route head=256".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.route, RouteMode::Head(256));
        let a = Args::parse(
            "--route hot".split_whitespace().map(String::from),
        );
        assert!(c.apply_args(&a).is_err());
        // validate() enforces the head bound for programmatically built
        // configs too.
        let mut c = TrainConfig::default();
        c.route = RouteMode::Head(0);
        assert!(c.validate().is_err());
        c.route = RouteMode::Head(4096);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn simd_and_sigmoid_knobs() {
        let mut c = TrainConfig::default();
        assert_eq!(c.simd, SimdMode::Auto);
        assert_eq!(c.sigmoid_mode, SigmoidMode::Exact);
        let a = Args::parse(
            "--simd scalar --sigmoid table"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.simd, SimdMode::Scalar);
        assert_eq!(c.sigmoid_mode, SigmoidMode::Table);
        // The 16-lane tier is a first-class mode since the AVX-512 PR
        // (it used to be a parse error; runtime availability is checked
        // by simd::configure, not the parser).
        assert_eq!("avx512".parse::<SimdMode>().unwrap(), SimdMode::Avx512);
        assert!("lut".parse::<SigmoidMode>().is_err());
        let a = Args::parse(
            "--simd avx512".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.simd, SimdMode::Avx512);
    }

    #[test]
    fn reuse_knob_parsing_and_validation() {
        let mut c = TrainConfig::default();
        assert_eq!(c.reuse, ReuseMode::Off);
        let a = Args::parse(
            "--reuse sentence".split_whitespace().map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.reuse, ReuseMode::Sentence);
        assert_eq!("window".parse::<ReuseMode>().unwrap(), ReuseMode::Window);
        assert_eq!("OFF".parse::<ReuseMode>().unwrap(), ReuseMode::Off);
        assert!("epoch".parse::<ReuseMode>().is_err());
        assert_eq!(ReuseMode::Sentence.to_string(), "sentence");

        // Reuse needs the GEMM backend's run-grouping driver.
        let mut c = TrainConfig::default();
        c.reuse = ReuseMode::Sentence;
        c.backend = Backend::Scalar;
        assert!(c.validate().is_err());
        c.backend = Backend::Gemm;
        assert!(c.validate().is_ok());
        // Window mode is a driver ablation, same backend requirement.
        c.reuse = ReuseMode::Window;
        c.backend = Backend::Pjrt;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fingerprint_tracks_sentence_reuse_only() {
        let a = TrainConfig::default();
        // Sentence reuse changes the negative draws → digest moves.
        let mut b = TrainConfig::default();
        b.reuse = ReuseMode::Sentence;
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Window mode is bitwise-equal to off → digest preserved
        // (resuming across it is sound), as is the default itself.
        b.reuse = ReuseMode::Window;
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.reuse = ReuseMode::Off;
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn quant_knob_parsing() {
        assert_eq!(QuantMode::default(), QuantMode::Off);
        assert_eq!("off".parse::<QuantMode>().unwrap(), QuantMode::Off);
        assert_eq!("INT8".parse::<QuantMode>().unwrap(), QuantMode::Int8);
        assert!("fp16".parse::<QuantMode>().is_err());
        assert_eq!(QuantMode::Int8.to_string(), "int8");
        assert_eq!(QuantMode::Off.to_string(), "off");
    }
}
