//! PJRT CPU client wrapper: HLO text → compiled executable.
//!
//! One [`Runtime`] per process; compiled [`StepExecutable`]s are cheap
//! handles that can be used from the training loop.  The interchange
//! format is HLO *text* — the vendored xla_extension 0.5.1 rejects
//! jax≥0.5's serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;

use super::executable::StepExecutable;
use super::manifest::{Manifest, Variant};

pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the PJRT CPU client.
    pub fn cpu() -> anyhow::Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text file with known step geometry.
    pub fn compile_step<P: AsRef<Path>>(
        &self,
        path: P,
        w: usize,
        b: usize,
        s: usize,
        d: usize,
    ) -> anyhow::Result<StepExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            anyhow::anyhow!("parse {}: {e}", path.as_ref().display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile: {e}"))?;
        Ok(StepExecutable::new(exe, w, b, s, d))
    }

    /// Compile a manifest variant.
    pub fn compile_variant(
        &self,
        manifest: &Manifest,
        v: &Variant,
    ) -> anyhow::Result<StepExecutable> {
        self.compile_step(manifest.path_of(v), v.w, v.b, v.s, v.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end artifact round trip: python-lowered Pallas kernel → HLO
    /// text → PJRT compile → execute → matches the rust-side oracle.
    /// Skipped when artifacts are absent (run `make artifacts`).
    #[test]
    fn compile_and_run_test_artifact() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let v = m.by_name("test_w4_b8_s6_d32").unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.compile_variant(&m, v).unwrap();

        let (w, b, s, d) = (v.w, v.b, v.s, v.d);
        let mut rng = crate::util::rng::Xoshiro256ss::new(1);
        let wi: Vec<f32> = (0..w * b * d).map(|_| rng.next_f32() * 0.2 - 0.1).collect();
        let wo: Vec<f32> = (0..w * s * d).map(|_| rng.next_f32() * 0.2 - 0.1).collect();
        let lr = 0.025f32;
        let (dwi, dwo) = exe.run(&wi, &wo, lr).unwrap();
        assert_eq!(dwi.len(), w * b * d);
        assert_eq!(dwo.len(), w * s * d);

        // Oracle: per-window three-GEMM chain in rust.
        let mut want_dwi = vec![0.0f32; w * b * d];
        let mut want_dwo = vec![0.0f32; w * s * d];
        for win in 0..w {
            let wi_w = &wi[win * b * d..(win + 1) * b * d];
            let wo_w = &wo[win * s * d..(win + 1) * s * d];
            let mut logits = vec![0.0f32; b * s];
            crate::linalg::gemm_nt(b, s, d, 1.0, wi_w, wo_w, 0.0, &mut logits);
            let mut err = vec![0.0f32; b * s];
            for i in 0..b {
                for j in 0..s {
                    let label = if j == 0 { 1.0 } else { 0.0 };
                    let sig = 1.0 / (1.0 + (-logits[i * s + j]).exp());
                    err[i * s + j] = (label - sig) * lr;
                }
            }
            crate::linalg::gemm_nn(
                b, d, s, 1.0, &err, wo_w, 0.0,
                &mut want_dwi[win * b * d..(win + 1) * b * d],
            );
            crate::linalg::gemm_tn(
                s, d, b, 1.0, &err, wi_w, 0.0,
                &mut want_dwo[win * s * d..(win + 1) * s * d],
            );
        }
        for (i, (g, w_)) in dwi.iter().zip(&want_dwi).enumerate() {
            assert!((g - w_).abs() < 1e-4, "dwi[{i}]: {g} vs {w_}");
        }
        for (i, (g, w_)) in dwo.iter().zip(&want_dwo).enumerate() {
            assert!((g - w_).abs() < 1e-4, "dwo[{i}]: {g} vs {w_}");
        }
    }
}
