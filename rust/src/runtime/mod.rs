//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text; see the recipe notes there) and executes them on the CPU
//! PJRT client from the training hot path.  Python is never invoked here —
//! the rust binary is self-contained once `artifacts/` exists.

pub mod client;
pub mod executable;
pub mod manifest;

pub use client::Runtime;
pub use executable::StepExecutable;
pub use manifest::{Manifest, Variant};
