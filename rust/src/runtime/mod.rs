//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text; see the recipe notes there) and executes them on the CPU
//! PJRT client from the training hot path.  Python is never invoked here —
//! the rust binary is self-contained once `artifacts/` exists.
//!
//! The PJRT client itself needs the vendored `xla` crate, which is only
//! present on the AOT build hosts; everything XLA-facing is therefore
//! compiled under the `pjrt` cargo feature.  Without the feature, stub
//! types with the same surface are provided so every call site (trainer,
//! benches, `pw2v info`) compiles and reports "pjrt support not compiled
//! in" at runtime instead.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executable;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;
pub mod topology;

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use executable::StepExecutable;
pub use manifest::{Manifest, Variant};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Runtime, StepExecutable};
