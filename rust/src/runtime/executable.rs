//! A compiled SGNS step with fixed geometry `(W, B, S, D)`:
//! `run(wi, wo, lr) -> (dwi, dwo)` over flat f32 buffers.

use std::sync::Mutex;

pub struct StepExecutable {
    /// The compiled executable.  All PJRT interaction happens under this
    /// lock: the `xla` crate's `PjRtClient` is `Rc`-based, so buffer
    /// creation/drop must not race across threads; serialising calls
    /// makes the cross-thread sharing below sound (the CPU client runs
    /// the computation on its own thread pool regardless).
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub w: usize,
    pub b: usize,
    pub s: usize,
    pub d: usize,
}

// SAFETY: every use of the inner executable (and of the Rc-based client
// handles created from it) is serialised by the Mutex above, so no Rc
// refcount or PJRT state is ever touched concurrently.  PJRT itself is
// thread-safe for execution.
unsafe impl Send for StepExecutable {}
unsafe impl Sync for StepExecutable {}

impl StepExecutable {
    pub fn new(
        exe: xla::PjRtLoadedExecutable,
        w: usize,
        b: usize,
        s: usize,
        d: usize,
    ) -> Self {
        Self {
            exe: Mutex::new(exe),
            w,
            b,
            s,
            d,
        }
    }

    /// Number of f32s in the `wi` input.
    pub fn wi_len(&self) -> usize {
        self.w * self.b * self.d
    }

    /// Number of f32s in the `wo` input.
    pub fn wo_len(&self) -> usize {
        self.w * self.s * self.d
    }

    /// Execute one superbatch step.  `wi`/`wo` are row-major
    /// `[W,B,D]`/`[W,S,D]`; returns `(dwi, dwo)` with the same layouts.
    ///
    /// Inputs are staged as PJRT buffers and executed via `execute_b`:
    /// the crate's literal-taking `execute` leaks its device-side input
    /// buffers (`buffer.release()` without a matching free in the C shim
    /// — ~1.1 MB/call at paper geometry; see EXPERIMENTS.md §Perf),
    /// whereas buffers we create ourselves are properly dropped.
    pub fn run(&self, wi: &[f32], wo: &[f32], lr: f32) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(wi.len() == self.wi_len(), "wi length mismatch");
        anyhow::ensure!(wo.len() == self.wo_len(), "wo length mismatch");
        let exe = self.exe.lock().unwrap();
        let client = exe.client();
        let b_wi = client
            .buffer_from_host_buffer(wi, &[self.w, self.b, self.d], None)
            .map_err(wrap)?;
        let b_wo = client
            .buffer_from_host_buffer(wo, &[self.w, self.s, self.d], None)
            .map_err(wrap)?;
        let b_lr = client
            .buffer_from_host_buffer(&[lr], &[], None)
            .map_err(wrap)?;
        let result = exe.execute_b(&[b_wi, b_wo, b_lr]).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: a 2-tuple (dwi, dwo).
        let (l_dwi, l_dwo) = out.to_tuple2().map_err(wrap)?;
        let dwi = l_dwi.to_vec::<f32>().map_err(wrap)?;
        let dwo = l_dwo.to_vec::<f32>().map_err(wrap)?;
        Ok((dwi, dwo))
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}
