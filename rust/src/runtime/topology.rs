//! NUMA topology discovery + thread pinning — the substrate of the
//! NUMA-aware sharding PR.
//!
//! The paper's shared-memory results (Sec. IV, dual-socket Broadwell)
//! depend on Hogwild scatters staying socket-local; the follow-up work
//! (arXiv:1611.06172) makes the same argument for KNL/multi-socket
//! scaling.  Before this layer existed the trainer allocated one flat
//! `M_in`/`M_out` pair from the main thread — under Linux first-touch
//! policy the ENTIRE model landed on the main thread's node, so on a
//! multi-socket box every worker on the other socket crossed the
//! interconnect for every row gather and scatter.
//!
//! Discovery order (`Topology::detect`):
//!
//! 1. `PW2V_TOPOLOGY` env override — a `;`-separated list of cpulists,
//!    one per synthetic node (e.g. `0-3,8;4-7`), for tests and CI
//!    matrices on machines whose real topology is a single node;
//! 2. `/sys/devices/system/node/node*/cpulist` on Linux;
//! 3. a single synthetic node holding cpu `0..available_parallelism`
//!    (non-Linux, or `/sys` unreadable).
//!
//! Pinning goes through a raw `sched_setaffinity(2)` declaration against
//! the libc std already links — the same no-new-crates discipline as the
//! corpus cache's raw `mmap(2)` (see `corpus::encoded`).  Pinning is
//! best-effort everywhere: a cpu list that names offline cpus (synthetic
//! test topologies) or a non-Linux host simply leaves the thread
//! unpinned, and the sharded-model math is identical either way (only
//! page placement and cache traffic change).

use std::fmt;
use std::str::FromStr;

use crate::util::split_point;

/// The `--numa` config knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NumaMode {
    /// Flat model, unpinned workers — bit-for-bit the pre-NUMA path.
    #[default]
    Off,
    /// Shard the model across the detected topology and pin workers.
    Auto,
    /// Shard across exactly N synthetic nodes (the detected cpu set is
    /// split into N contiguous groups) — the ablation/test knob.
    Nodes(usize),
}

impl FromStr for NumaMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Ok(NumaMode::Off),
            "auto" => Ok(NumaMode::Auto),
            other => {
                let n: usize = other.parse().map_err(|_| {
                    anyhow::anyhow!("unknown numa mode '{other}' (off|auto|<nodes>)")
                })?;
                // Upper bound: the sharded store spawns one init thread
                // and one boundary entry per node, so an absurd count
                // must fail here as a config error, not abort later in
                // allocation or thread spawn.  1024 comfortably exceeds
                // any real machine's node count (matches the pinning
                // mask's cpu width).
                anyhow::ensure!(
                    (1..=1024).contains(&n),
                    "--numa <nodes> must be in 1..=1024 (got {n})"
                );
                Ok(NumaMode::Nodes(n))
            }
        }
    }
}

impl fmt::Display for NumaMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NumaMode::Off => f.write_str("off"),
            NumaMode::Auto => f.write_str("auto"),
            NumaMode::Nodes(n) => write!(f, "{n}"),
        }
    }
}

/// One NUMA node: its id and the cpus that live on it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    pub id: usize,
    pub cpus: Vec<usize>,
}

/// The machine's node/cpu geometry (real, overridden, or synthetic).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    nodes: Vec<NumaNode>,
}

impl Topology {
    /// Discover the topology: `PW2V_TOPOLOGY` override, else sysfs, else
    /// one synthetic node.  A malformed override is a hard error (a
    /// silently ignored test matrix would test nothing).
    pub fn detect() -> anyhow::Result<Self> {
        if let Ok(spec) = std::env::var("PW2V_TOPOLOGY") {
            return Self::from_spec(&spec)
                .map_err(|e| anyhow::anyhow!("PW2V_TOPOLOGY: {e}"));
        }
        Ok(Self::from_sysfs().unwrap_or_else(Self::single_node))
    }

    /// Parse a synthetic topology spec: cpulists separated by `;`, one
    /// per node (`0-3,8;4-7` = node0 {0,1,2,3,8}, node1 {4,5,6,7}).
    pub fn from_spec(spec: &str) -> anyhow::Result<Self> {
        let mut nodes = Vec::new();
        for (id, part) in spec.split(';').enumerate() {
            let cpus = parse_cpulist(part)?;
            anyhow::ensure!(!cpus.is_empty(), "node {id}: empty cpulist");
            nodes.push(NumaNode { id, cpus });
        }
        anyhow::ensure!(!nodes.is_empty(), "empty topology spec");
        Ok(Self { nodes })
    }

    /// `/sys/devices/system/node/node<k>/cpulist`; `None` when the sysfs
    /// tree is absent/unreadable (non-Linux, restricted containers).
    fn from_sysfs() -> Option<Self> {
        let dir = std::path::Path::new("/sys/devices/system/node");
        let mut ids: Vec<usize> = std::fs::read_dir(dir)
            .ok()?
            .filter_map(|e| {
                let name = e.ok()?.file_name().into_string().ok()?;
                name.strip_prefix("node")?.parse::<usize>().ok()
            })
            .collect();
        ids.sort_unstable();
        let mut nodes = Vec::new();
        for id in ids {
            let list =
                std::fs::read_to_string(dir.join(format!("node{id}/cpulist")))
                    .ok()?;
            let cpus = parse_cpulist(list.trim()).ok()?;
            if !cpus.is_empty() {
                nodes.push(NumaNode { id, cpus });
            }
        }
        if nodes.is_empty() {
            None
        } else {
            Some(Self { nodes })
        }
    }

    /// The fallback geometry: everything on one synthetic node.
    pub fn single_node() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self {
            nodes: vec![NumaNode {
                id: 0,
                cpus: (0..n).collect(),
            }],
        }
    }

    /// Regroup into exactly `n` synthetic nodes (`--numa <n>`): the full
    /// cpu list, in node order, split into `n` contiguous groups with
    /// the shared [`split_point`] rule corpus shards use.  Groups may be
    /// empty when `n` exceeds the cpu count — those nodes simply train
    /// unpinned.
    pub fn regroup(&self, n: usize) -> Self {
        assert!(n >= 1);
        let all: Vec<usize> =
            self.nodes.iter().flat_map(|nd| nd.cpus.iter().copied()).collect();
        let len = all.len() as u64;
        let nodes = (0..n)
            .map(|i| NumaNode {
                id: i,
                cpus: all[split_point(len, n as u64, i as u64) as usize
                    ..split_point(len, n as u64, i as u64 + 1) as usize]
                    .to_vec(),
            })
            .collect();
        Self { nodes }
    }

    /// The first `n` REAL nodes, boundaries intact — the `--numa auto`
    /// low-thread clamp: unlike [`regroup`](Self::regroup), a group here
    /// never straddles two physical nodes, so first-touch placement
    /// stays node-pure.
    pub fn take_nodes(&self, n: usize) -> Self {
        assert!(n >= 1);
        Self {
            nodes: self.nodes.iter().take(n).cloned().collect(),
        }
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Cpus of node `i` (empty slice for out-of-range / cpu-less nodes).
    pub fn cpus(&self, i: usize) -> &[usize] {
        self.nodes.get(i).map(|n| n.cpus.as_slice()).unwrap_or(&[])
    }

    pub fn total_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// Pin the CALLING thread to node `i`'s cpus.  Best-effort: returns
    /// `false` (thread left as-is) on non-Linux hosts, empty/out-of-range
    /// cpu sets, or kernel rejection (offline cpus in a synthetic spec).
    pub fn pin_to_node(&self, i: usize) -> bool {
        pin_to_cpus(self.cpus(i))
    }
}

/// Resolve a `--numa` mode to the topology the sharded path should use
/// (`None` = flat path).
pub fn resolve(mode: NumaMode) -> anyhow::Result<Option<Topology>> {
    Ok(match mode {
        NumaMode::Off => None,
        NumaMode::Auto => Some(Topology::detect()?),
        NumaMode::Nodes(n) => Some(Topology::detect()?.regroup(n)),
    })
}

/// Parse a kernel-style cpulist: `0-3,8,10-11`.
fn parse_cpulist(s: &str) -> anyhow::Result<Vec<usize>> {
    let mut cpus = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().map_err(|_| {
                    anyhow::anyhow!("bad cpulist range start '{part}'")
                })?;
                let hi: usize = hi.trim().parse().map_err(|_| {
                    anyhow::anyhow!("bad cpulist range end '{part}'")
                })?;
                anyhow::ensure!(lo <= hi, "inverted cpulist range '{part}'");
                cpus.extend(lo..=hi);
            }
            None => cpus.push(part.parse().map_err(|_| {
                anyhow::anyhow!("bad cpulist entry '{part}'")
            })?),
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    Ok(cpus)
}

/// Pin the calling thread to `cpus` via raw `sched_setaffinity(2)` (pid 0
/// = calling thread on Linux).  `std` already links libc, so a direct
/// declaration keeps the offline build dependency-free — the same
/// discipline as `corpus::encoded`'s raw `mmap(2)`.
#[cfg(target_os = "linux")]
pub fn pin_to_cpus(cpus: &[usize]) -> bool {
    // Fixed-width 1024-cpu mask (glibc's default cpu_set_t size).
    const SETSIZE: usize = 1024;
    let mut mask = [0u64; SETSIZE / 64];
    let mut any = false;
    for &c in cpus {
        if c < SETSIZE {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    extern "C" {
        fn sched_setaffinity(
            pid: i32,
            cpusetsize: usize,
            mask: *const u64,
        ) -> i32;
    }
    // SAFETY: mask is a valid, initialised buffer of the passed size; the
    // call only reads it and mutates kernel-side scheduler state for the
    // calling thread.
    unsafe {
        sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0
    }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_to_cpus(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_grammar() {
        assert_eq!(parse_cpulist("0-3,8,10-11").unwrap(), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5").unwrap(), vec![5]);
        assert_eq!(parse_cpulist(" 1 , 0 ").unwrap(), vec![0, 1]);
        // Duplicates collapse; empty segments are tolerated (sysfs files
        // end with a newline-stripped but sometimes trailing comma).
        assert_eq!(parse_cpulist("2,2,1,").unwrap(), vec![1, 2]);
        assert!(parse_cpulist("3-1").is_err());
        assert!(parse_cpulist("x").is_err());
        assert!(parse_cpulist("1-y").is_err());
    }

    #[test]
    fn spec_parsing() {
        let t = Topology::from_spec("0-3,8;4-7").unwrap();
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.cpus(0), &[0, 1, 2, 3, 8]);
        assert_eq!(t.cpus(1), &[4, 5, 6, 7]);
        assert_eq!(t.total_cpus(), 9);
        // Out-of-range node: empty, unpinnable, but not a panic.
        assert_eq!(t.cpus(7), &[] as &[usize]);
        assert!(!t.pin_to_node(7));
        assert!(Topology::from_spec("").is_err());
        assert!(Topology::from_spec("0-3;;4").is_err());
        assert!(Topology::from_spec("0-3;oops").is_err());
    }

    #[test]
    fn detect_always_yields_a_node() {
        // Whatever the host looks like (real sysfs, env override from the
        // CI matrix, or the fallback), detection must produce >= 1 node
        // with >= 1 cpu.
        let t = Topology::detect().unwrap();
        assert!(t.nodes() >= 1);
        assert!(t.total_cpus() >= 1);
    }

    #[test]
    fn regroup_splits_contiguously() {
        let t = Topology::from_spec("0-7").unwrap();
        let r = t.regroup(2);
        assert_eq!(r.nodes(), 2);
        assert_eq!(r.cpus(0), &[0, 1, 2, 3]);
        assert_eq!(r.cpus(1), &[4, 5, 6, 7]);
        // Uneven split balances within one cpu.
        let r = t.regroup(3);
        let sizes: Vec<usize> = (0..3).map(|i| r.cpus(i).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // More nodes than cpus: empty groups are legal (train unpinned).
        let r = Topology::from_spec("0").unwrap().regroup(3);
        assert_eq!(r.nodes(), 3);
        assert_eq!(r.total_cpus(), 1);
    }

    #[test]
    fn take_nodes_keeps_real_boundaries() {
        let t = Topology::from_spec("0-3;4-7;8-11").unwrap();
        let clamped = t.take_nodes(2);
        assert_eq!(clamped.nodes(), 2);
        // Unlike regroup, the kept groups ARE the physical nodes.
        assert_eq!(clamped.cpus(0), t.cpus(0));
        assert_eq!(clamped.cpus(1), t.cpus(1));
        // Clamping above the node count is a no-op.
        assert_eq!(t.take_nodes(9), t);
    }

    #[test]
    fn numa_mode_parsing_and_display() {
        assert_eq!("off".parse::<NumaMode>().unwrap(), NumaMode::Off);
        assert_eq!("AUTO".parse::<NumaMode>().unwrap(), NumaMode::Auto);
        assert_eq!("2".parse::<NumaMode>().unwrap(), NumaMode::Nodes(2));
        assert_eq!("1024".parse::<NumaMode>().unwrap(), NumaMode::Nodes(1024));
        assert!("0".parse::<NumaMode>().is_err());
        // Absurd node counts must die at config parse, not in the
        // sharded store's per-node allocation/thread spawn.
        assert!("1025".parse::<NumaMode>().is_err());
        assert!("4000000000".parse::<NumaMode>().is_err());
        assert!("sockets".parse::<NumaMode>().is_err());
        assert_eq!(NumaMode::Off.to_string(), "off");
        assert_eq!(NumaMode::Nodes(4).to_string(), "4");
        assert_eq!(NumaMode::default(), NumaMode::Off);
    }

    #[test]
    fn resolve_modes() {
        assert!(resolve(NumaMode::Off).unwrap().is_none());
        let t = resolve(NumaMode::Nodes(2)).unwrap().unwrap();
        assert_eq!(t.nodes(), 2);
        assert!(resolve(NumaMode::Auto).unwrap().is_some());
    }

    /// Pinning to the current topology's node 0 must either succeed (Linux
    /// with online cpus) or degrade to a clean `false` — never panic.
    #[test]
    fn pinning_is_best_effort() {
        let t = Topology::detect().unwrap();
        let _ = t.pin_to_node(0);
        assert!(!pin_to_cpus(&[]));
        // Cpus beyond the fixed mask width are ignored, not UB.
        assert!(!pin_to_cpus(&[100_000]));
    }
}
