//! `artifacts/manifest.json` parsing: the index of AOT-compiled step
//! variants (one per superbatch geometry), written by `python -m compile.aot`.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT-lowered `step(wi[W,B,D], wo[W,S,D], lr)` variant.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    pub name: String,
    pub file: String,
    /// "pallas" (fused L1 kernel) or "jnp" (pure-jnp L2 reference).
    pub kind: String,
    pub w: usize,
    pub b: usize,
    pub s: usize,
    pub d: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Variant>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(dir: P) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        let j = Json::parse(&text)?;
        anyhow::ensure!(
            j.field("format")?.as_str() == Some("hlo-text"),
            "unsupported artifact format"
        );
        let mut entries = Vec::new();
        for e in j.field("entries")?.as_arr().unwrap_or(&[]) {
            entries.push(Variant {
                name: req_str(e, "name")?,
                file: req_str(e, "file")?,
                kind: req_str(e, "kind")?,
                w: req_usize(e, "w")?,
                b: req_usize(e, "b")?,
                s: req_usize(e, "s")?,
                d: req_usize(e, "d")?,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Self { dir, entries })
    }

    pub fn by_name(&self, name: &str) -> anyhow::Result<&Variant> {
        self.entries
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| anyhow::anyhow!("no artifact variant named '{name}'"))
    }

    /// Find a variant with the exact geometry, preferring `kind` (fall
    /// back to any kind with the right shape).
    pub fn by_geometry_kind(
        &self,
        kind: &str,
        w: usize,
        b: usize,
        s: usize,
        d: usize,
    ) -> anyhow::Result<&Variant> {
        let matches = |v: &&Variant| (v.w, v.b, v.s, v.d) == (w, b, s, d);
        self.entries
            .iter()
            .find(|v| v.kind == kind && matches(v))
            .or_else(|| self.entries.iter().find(matches))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for geometry W={w} B={b} S={s} D={d}; \
                     available: {:?}",
                    self.entries
                        .iter()
                        .map(|v| (v.name.as_str(), v.w, v.b, v.s, v.d))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Find the variant the CPU trainer should run: the "jnp" lowering of
    /// the step (numerically identical to the Pallas kernel — tested —
    /// and ~9× faster under the CPU PJRT client, whose interpret-mode
    /// grid loop is serial; see EXPERIMENTS.md §Perf).  The "pallas"
    /// artifact remains the TPU-structured build.
    pub fn by_geometry(
        &self,
        w: usize,
        b: usize,
        s: usize,
        d: usize,
    ) -> anyhow::Result<&Variant> {
        self.by_geometry_kind("jnp", w, b, s, d)
    }

    pub fn path_of(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.file)
    }
}

fn req_str(j: &Json, k: &str) -> anyhow::Result<String> {
    Ok(j.field(k)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("field '{k}' not a string"))?
        .to_string())
}

fn req_usize(j: &Json, k: &str) -> anyhow::Result<usize> {
    j.field(k)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("field '{k}' not a number"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_and_selects() {
        let dir = std::env::temp_dir().join("pw2v_manifest_test");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","entries":[
                {"name":"a","file":"a.hlo.txt","kind":"pallas","w":4,"b":8,"s":6,"d":32},
                {"name":"j","file":"j.hlo.txt","kind":"jnp","w":4,"b":8,"s":6,"d":32}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.by_name("a").unwrap().d, 32);
        // Default geometry lookup prefers the jnp kind (CPU execution)...
        assert_eq!(m.by_geometry(4, 8, 6, 32).unwrap().name, "j");
        // ...explicit kind selection works, with fallback across kinds.
        assert_eq!(m.by_geometry_kind("pallas", 4, 8, 6, 32).unwrap().name, "a");
        assert!(m.by_geometry(1, 1, 1, 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load("/nonexistent_dir_xyz").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_artifacts_if_present() {
        // Validates the actual repo artifacts when they exist (CI runs
        // after `make artifacts`).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let v = m.by_name("test_w4_b8_s6_d32").unwrap();
            assert_eq!((v.w, v.b, v.s, v.d), (4, 8, 6, 32));
            assert!(m.path_of(v).exists());
        }
    }
}
