//! Surface-compatible stand-ins for the PJRT runtime, compiled when the
//! `pjrt` cargo feature is off (i.e. the vendored `xla` crate is absent).
//!
//! Every constructor fails with a clear message, so call sites keep their
//! ordinary error handling: `pw2v info` prints "pjrt unavailable", the
//! trainer refuses `--backend pjrt`, and the PJRT benches skip.  No stub
//! value can ever be constructed, so the methods below are unreachable at
//! runtime — they exist purely to satisfy the type surface of
//! `runtime::client` / `runtime::executable`.

use std::path::Path;

use super::manifest::{Manifest, Variant};

const UNAVAILABLE: &str =
    "pjrt support not compiled in (rebuild with `--features pjrt` and the vendored xla crate)";

/// Stub of [`crate::runtime::client::Runtime`].
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails: there is no PJRT client in this build.
    pub fn cpu() -> anyhow::Result<Self> {
        anyhow::bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Unreachable (no `Runtime` can exist), kept for signature parity.
    pub fn compile_step<P: AsRef<Path>>(
        &self,
        _path: P,
        _w: usize,
        _b: usize,
        _s: usize,
        _d: usize,
    ) -> anyhow::Result<StepExecutable> {
        anyhow::bail!(UNAVAILABLE)
    }

    /// Unreachable, kept for signature parity.
    pub fn compile_variant(
        &self,
        _manifest: &Manifest,
        _variant: &Variant,
    ) -> anyhow::Result<StepExecutable> {
        anyhow::bail!(UNAVAILABLE)
    }
}

/// Stub of [`crate::runtime::executable::StepExecutable`].
pub struct StepExecutable {
    pub w: usize,
    pub b: usize,
    pub s: usize,
    pub d: usize,
    _private: (),
}

impl StepExecutable {
    /// Number of f32s in the `wi` input.
    pub fn wi_len(&self) -> usize {
        self.w * self.b * self.d
    }

    /// Number of f32s in the `wo` input.
    pub fn wo_len(&self) -> usize {
        self.w * self.s * self.d
    }

    /// Unreachable (no `StepExecutable` can exist in this build).
    pub fn run(
        &self,
        _wi: &[f32],
        _wo: &[f32],
        _lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::cpu().unwrap_err().to_string();
        assert!(err.contains("pjrt support not compiled in"), "{err}");
    }
}
