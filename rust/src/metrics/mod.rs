//! Training metrics: lock-free counters shared across worker threads, and
//! a progress reporter matching the original's "Alpha / progress / words/sec"
//! log line.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Global counters shared by every worker thread / node.
#[derive(Debug)]
pub struct Counters {
    /// Tokens processed (drives lr decay + throughput).
    pub words: AtomicU64,
    /// Windows (superbatch elements) processed.
    pub windows: AtomicU64,
    /// Kernel / artifact calls issued.
    pub calls: AtomicU64,
    /// Model-synchronisation rounds completed (distributed).
    pub syncs: AtomicU64,
    /// Bytes sent over the (simulated or real) transport.
    pub bytes_sent: AtomicU64,
    /// Vocabulary admissions performed (streaming ingest).
    pub admissions: AtomicU64,
    start: Instant,
}

impl Default for Counters {
    fn default() -> Self {
        Self::new()
    }
}

impl Counters {
    pub fn new() -> Self {
        Self {
            words: AtomicU64::new(0),
            windows: AtomicU64::new(0),
            calls: AtomicU64::new(0),
            syncs: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
            start: Instant::now(),
        }
    }

    #[inline]
    pub fn add_words(&self, n: u64) -> u64 {
        self.words.fetch_add(n, Ordering::Relaxed) + n
    }

    #[inline]
    pub fn add_windows(&self, n: u64) {
        self.windows.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_calls(&self, n: u64) {
        self.calls.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_syncs(&self, n: u64) {
        self.syncs.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_bytes(&self, n: u64) {
        self.bytes_sent.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_admissions(&self, n: u64) {
        self.admissions.fetch_add(n, Ordering::Relaxed);
    }

    pub fn words_now(&self) -> u64 {
        self.words.load(Ordering::Relaxed)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Words per second since construction.
    pub fn throughput(&self) -> f64 {
        let s = self.elapsed_secs();
        if s <= 0.0 {
            0.0
        } else {
            self.words_now() as f64 / s
        }
    }

    /// Snapshot for reports.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            words: self.words_now(),
            windows: self.windows.load(Ordering::Relaxed),
            calls: self.calls.load(Ordering::Relaxed),
            syncs: self.syncs.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            admissions: self.admissions.load(Ordering::Relaxed),
            secs: self.elapsed_secs(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Snapshot {
    pub words: u64,
    pub windows: u64,
    pub calls: u64,
    pub syncs: u64,
    pub bytes_sent: u64,
    pub admissions: u64,
    pub secs: f64,
}

impl Snapshot {
    pub fn words_per_sec(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.words as f64 / self.secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn counters_accumulate_across_threads() {
        let c = Counters::new();
        thread::scope(|s| {
            for _ in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.add_words(3);
                        c.add_windows(1);
                    }
                });
            }
        });
        assert_eq!(c.words_now(), 12_000);
        let snap = c.snapshot();
        assert_eq!(snap.windows, 4_000);
        assert!(snap.words_per_sec() > 0.0);
    }
}
